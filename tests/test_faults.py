"""ISSUE-7 fault-tolerant round execution: deterministic FaultPlan
draws, over-schedule + first-k collect in the lifecycle, quorum
retry/backoff -> DEGRADED, reputation/pool timing-failure bookkeeping,
pinned-schedule deregister deferral, scheduler backpressure and
wedged-tenant eviction, and the no-fault bit-identity contract."""
import numpy as np
import pytest

from repro.core import (FaultPlan, FLServiceProvider, InFlightError,
                        RejectedTask, ServiceScheduler, TaskPhase,
                        TaskRequest, as_run_result, collect, dispatch,
                        drain, load_state, random_profiles, save_state,
                        step, submit)
from repro.core.faults import _u01
from repro.core.policy import selection_policy
from repro.core.pool import ClientPoolState


def _profiles(n=60, seed=0):
    return random_profiles(n, 10, np.random.default_rng(seed))


def _round_result(rnd, subset, fail_mod=7):
    subset = np.asarray(subset)
    returned = (subset + rnd) % fail_mod != 0
    q = np.where(returned, 0.5 + 0.4 * np.cos(subset + rnd), 0.0)
    return returned, q, {"round": rnd, "loss": 1.0 / (rnd + 1)}


class FaultyChunkStub:
    """Deterministic sync Trainer carrying a fault plan. Arrival-aware:
    the lifecycle hands it per-round arrival masks in fault mode (it
    ignores them — host-side masking in _settle_chunk is under test)."""

    accepts_arrivals = True

    def __init__(self, fault_plan=None):
        self.fault_plan = fault_plan

    def run_rounds(self, start_round, subsets, weights, arrivals=None):
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]

    def __call__(self, rnd, subset, weights):
        return self.run_rounds(rnd, [subset], [weights])[0]


class AsyncStub:
    """Async trainer whose dispatch just parks the chunk (lazy)."""

    def dispatch_rounds(self, start_round, subsets, weights):
        return (start_round, [list(s) for s in subsets])

    def collect(self, handle):
        start_round, subsets = handle
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]

    def run_rounds(self, start_round, subsets, weights):
        return self.collect(self.dispatch_rounds(start_round, subsets,
                                                 weights))


class WedgedStub(AsyncStub):
    """Async trainer whose in-flight chunk never becomes ready."""

    def poll(self, handle):
        return False

    def collect(self, handle):                      # pragma: no cover
        raise AssertionError("a wedged handle must never be collected")


def _task(**kw):
    base = dict(budget=400.0, n_star=10, subset_size=5, subset_delta=2,
                max_periods=3, seed=3)
    base.update(kw)
    return TaskRequest(**base)


def _events_digest(events):
    return [(e.period, e.round_index, tuple(e.subset),
             tuple(np.asarray(e.weights).tolist())) for e in events]


# ---------------------------------------------------------------------------
# FaultPlan: deterministic counter-based draws
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_u01_deterministic_and_order_free(self):
        ids = np.arange(50)
        a = _u01(7, 2, ids, extra=3)
        b = _u01(7, 2, ids, extra=3)
        np.testing.assert_array_equal(a, b)
        # per-id evaluation == batch evaluation (counter-based)
        solo = np.array([_u01(7, 2, [i], extra=3)[0] for i in ids])
        np.testing.assert_array_equal(a, solo)
        assert ((a >= 0) & (a < 1)).all()
        # different stream/extra/seed decorrelate
        assert not np.array_equal(a, _u01(7, 3, ids, extra=3))
        assert not np.array_equal(a, _u01(7, 2, ids, extra=4))
        assert not np.array_equal(a, _u01(8, 2, ids, extra=3))

    def test_inactive_plan(self):
        assert not FaultPlan().active
        assert FaultPlan(straggler_frac=0.2).active
        assert FaultPlan(crash_prob=0.1).active
        assert FaultPlan(outage_prob=0.1).active

    def test_straggler_trait_is_fixed(self):
        plan = FaultPlan(seed=5, straggler_frac=0.3)
        ids = np.arange(500)
        trait = plan.is_straggler(ids)
        np.testing.assert_array_equal(trait, plan.is_straggler(ids))
        assert 0.2 < trait.mean() < 0.4            # ~30%
        lat = plan.latency(ids, 4)
        # stragglers are straggler_slowdown x slower (up to jitter)
        assert lat[trait].min() > lat[~trait].max()

    def test_death_is_permanent(self):
        plan = FaultPlan(seed=1, crash_prob=0.2, permanent_frac=0.5)
        ids = np.arange(200)
        death = plan.death_round(ids)
        assert (death >= 0).all()
        dead_by_10 = death <= 10
        assert dead_by_10.any()
        for rnd in range(11, 15):       # once dead, dead forever
            assert not plan.alive(ids[dead_by_10], rnd).any()

    def test_round_outcome_first_k(self):
        plan = FaultPlan(seed=2, straggler_frac=0.5,
                         straggler_slowdown=10.0, latency_jitter=0.0)
        ids = np.arange(10)
        strag = plan.is_straggler(ids)
        out = plan.round_outcome(ids, 0, deadline=0.0,
                                 target_k=int((~strag).sum()), quorum_k=1)
        # closes at the k-th (= last healthy) arrival: all healthy in,
        # all stragglers (10x latency) out
        np.testing.assert_array_equal(out.arrival, ~strag)
        assert out.close_time == pytest.approx(1.0)
        assert out.quorum_met

    def test_round_outcome_deadline_cut(self):
        plan = FaultPlan(seed=2, straggler_frac=0.5,
                         straggler_slowdown=10.0, latency_jitter=0.0)
        ids = np.arange(10)
        out = plan.round_outcome(ids, 0, deadline=2.0, target_k=10,
                                 quorum_k=8)
        assert out.close_time == pytest.approx(2.0)   # cut by deadline
        np.testing.assert_array_equal(out.arrival, ~plan.is_straggler(ids))
        assert not out.quorum_met                     # ~5 < 8

    def test_round_outcome_never_hangs(self):
        # everyone crashed: no arrivals, close at the deadline (or 0)
        plan = FaultPlan(seed=0, crash_prob=1.0)
        out = plan.round_outcome(np.arange(8), 0, deadline=3.0,
                                 target_k=8, quorum_k=1)
        assert out.n_arrived == 0 and not out.quorum_met
        assert out.close_time == pytest.approx(3.0)
        out = plan.round_outcome(np.arange(8), 0, deadline=0.0,
                                 target_k=8, quorum_k=1)
        assert out.close_time == 0.0


# ---------------------------------------------------------------------------
# Lifecycle fault mode
# ---------------------------------------------------------------------------

_PLAN = FaultPlan(seed=11, straggler_frac=0.2, straggler_slowdown=8.0,
                  crash_prob=0.05, permanent_frac=0.2, outage_prob=0.1,
                  outage_len=5)


def _mitigated_task(**kw):
    return _task(overschedule_factor=1.5, quorum_frac=0.6,
                 collect_deadline=2.0, **kw)


class TestFaultLifecycle:
    def test_no_fault_bit_identity(self):
        """A trainer with an inactive FaultPlan takes the exact no-plan
        code path: identical events, schedules and reputation."""
        runs = []
        for plan in (None, FaultPlan()):
            sp = FLServiceProvider(_profiles())
            state = submit(sp, _task())
            state, _ = drain(sp, state, FaultyChunkStub(fault_plan=plan))
            runs.append((as_run_result(state), state))
        a, b = runs[0][0], runs[1][0]
        assert _events_digest(a.rounds) == _events_digest(b.rounds)
        assert a.reputation == b.reputation
        assert [s.subsets for s in a.schedules] == \
               [s.subsets for s in b.schedules]
        for ea, eb in zip(a.rounds, b.rounds):
            assert ea.metrics == eb.metrics
            assert "round_latency" not in ea.metrics

    def test_mitigated_rounds_close_at_quorum(self):
        sp = FLServiceProvider(_profiles())
        task = _mitigated_task()
        state = submit(sp, task)
        state, events = drain(sp, state, FaultyChunkStub(fault_plan=_PLAN))
        assert state.phase == TaskPhase.DONE
        assert events
        for ev in events:
            assert ev.metrics["n_scheduled"] == len(ev.subset)
            # every committed round met its quorum (quorum_k is over the
            # BASE subset size; members = ceil(base * 1.5), so base =
            # floor(members / 1.5))
            base_n = int(np.floor(ev.metrics["n_scheduled"] / 1.5))
            quorum_k = max(1, int(np.ceil(task.quorum_frac * base_n)))
            assert ev.metrics["n_arrived"] >= quorum_k
            # the deadline bounds every close (retry penalty rides on
            # top of the committed round that follows the misses)
            lat = ev.metrics["round_latency"]
            assert lat <= task.collect_deadline + \
                ev.metrics.get("retry_penalty", 0.0) + 1e-9
        big = [ev for ev in events
               if ev.metrics["n_scheduled"] > task.subset_size]
        assert big, "no round was over-scheduled"

    def test_timing_failures_recorded(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _mitigated_task())
        state, _ = drain(sp, state, FaultyChunkStub(fault_plan=_PLAN))
        tf = state.tracker.timeout_counts()
        assert sum(tf.values()) > 0
        assert sp.pool_state.dispatch_counts.sum() > 0
        assert sp.pool_state.timeout_counts.sum() > 0
        rate = sp.pool_state.timeout_rate()
        assert ((rate >= 0) & (rate <= 1)).all()
        # arrival-masked reputation: non-arrived clients got b_t = 0
        assert any(v > 0 for v in tf.values())

    def test_all_pins_released(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _mitigated_task())
        state, _ = drain(sp, state, FaultyChunkStub(fault_plan=_PLAN))
        assert sp.pool_state._pins == {}
        assert sp.pool_state._deferred_dereg == set()

    def test_quorum_starvation_degrades(self):
        """Universal crashes: no round can meet quorum, the task retries
        with backoff then lands in terminal DEGRADED (never hangs)."""
        sp = FLServiceProvider(_profiles())
        task = _task(quorum_frac=0.5, collect_deadline=2.0,
                     max_retries=2, retry_backoff=1.0)
        plan = FaultPlan(seed=0, crash_prob=1.0)
        state = submit(sp, task)
        state, events = drain(sp, state, FaultyChunkStub(fault_plan=plan))
        assert state.phase == TaskPhase.DEGRADED
        assert state.phase.terminal
        assert events == []
        assert state.retry_count == task.max_retries + 1
        # exponential backoff accumulated: deadline + 1, +2, +4
        assert state.retry_latency == pytest.approx(
            3 * task.collect_deadline + 1.0 + 2.0 + 4.0)
        # stepping a DEGRADED state is a no-op
        state2, ev = step(sp, state, FaultyChunkStub(fault_plan=plan))
        assert state2.phase == TaskPhase.DEGRADED and ev == []


# ---------------------------------------------------------------------------
# Checkpoint/restore of retry/backoff and DEGRADED states
# ---------------------------------------------------------------------------

class TestFaultCheckpoint:
    def _drive_to_retry(self, sp, state, trainer, max_steps=500):
        """Step until the first quorum miss leaves retry state behind."""
        for _ in range(max_steps):
            if state.phase.terminal:
                return state, False
            state, _ = step(sp, state, trainer)
            if state.retry_count > 0:
                return state, True
        return state, False

    def test_resume_mid_backoff_identical(self, tmp_path):
        # a plan harsh enough to miss quorum sometimes, mild enough to
        # commit rounds after a retry
        plan = FaultPlan(seed=4, straggler_frac=0.5,
                         straggler_slowdown=8.0, crash_prob=0.3)
        task = _task(overschedule_factor=1.1, quorum_frac=0.8,
                     collect_deadline=1.5, max_retries=10,
                     retry_backoff=0.5)
        sp = FLServiceProvider(_profiles())
        state = submit(sp, task)
        trainer = FaultyChunkStub(fault_plan=plan)
        state, hit = self._drive_to_retry(sp, state, trainer)
        assert hit, "plan never missed quorum; pick harsher knobs"
        assert state.pending is None           # mid-backoff: serializable
        path = str(tmp_path / "mid_backoff.ckpt")
        save_state(path, state)
        restored = load_state(path)
        assert restored.retry_count == state.retry_count
        assert restored.retry_latency == state.retry_latency
        # both continuations replay identically (fresh-draw retries come
        # from the checkpointed rng)
        sp2 = FLServiceProvider(_profiles())
        state, ev_a = drain(sp, state, trainer)
        restored, ev_b = drain(sp2, restored,
                               FaultyChunkStub(fault_plan=plan))
        assert state.phase == restored.phase
        assert _events_digest(ev_a) == _events_digest(ev_b)
        for ea, eb in zip(ev_a, ev_b):
            assert ea.metrics == eb.metrics

    def test_degraded_roundtrip(self, tmp_path):
        sp = FLServiceProvider(_profiles())
        task = _task(quorum_frac=0.5, collect_deadline=2.0, max_retries=1)
        plan = FaultPlan(seed=0, crash_prob=1.0)
        state = submit(sp, task)
        state, _ = drain(sp, state, FaultyChunkStub(fault_plan=plan))
        assert state.phase == TaskPhase.DEGRADED
        path = str(tmp_path / "degraded.ckpt")
        save_state(path, state)
        restored = load_state(path)
        assert restored.phase == TaskPhase.DEGRADED
        assert restored.phase.terminal
        assert restored.retry_count == state.retry_count
        assert restored.task_id == state.task_id
        restored, ev = step(sp, restored,
                            FaultyChunkStub(fault_plan=plan))
        assert restored.phase == TaskPhase.DEGRADED and ev == []

    def test_fault_knobs_roundtrip(self, tmp_path):
        sp = FLServiceProvider(_profiles())
        task = _mitigated_task(max_retries=5, retry_backoff=0.25)
        state = submit(sp, task)
        path = str(tmp_path / "knobs.ckpt")
        save_state(path, state)
        t = load_state(path).task
        assert t.overschedule_factor == task.overschedule_factor
        assert t.quorum_frac == task.quorum_frac
        assert t.collect_deadline == task.collect_deadline
        assert t.max_retries == 5 and t.retry_backoff == 0.25


# ---------------------------------------------------------------------------
# Satellite: InFlightError names the task + pending rounds
# ---------------------------------------------------------------------------

class TestInFlightContext:
    def test_to_arrays_error_names_task_and_rounds(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _task(round_chunk=3))
        state.task_id = 42
        while state.phase != TaskPhase.SCHEDULED:
            state, _ = step(sp, state, AsyncStub())
        dispatch(sp, state, AsyncStub())
        assert state.pending is not None
        with pytest.raises(InFlightError, match=r"task id 42"):
            state.to_arrays()
        with pytest.raises(InFlightError,
                           match=r"pending rounds 0\.\.2"):
            state.to_arrays()
        with pytest.raises(InFlightError, match=r"task id 42"):
            dispatch(sp, state, AsyncStub())
        collect(state)                              # leave it clean

    def test_save_state_error_names_task(self, tmp_path):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _task())
        while state.phase != TaskPhase.SCHEDULED:
            state, _ = step(sp, state, AsyncStub())
        dispatch(sp, state, AsyncStub())
        with pytest.raises(InFlightError, match=r"task id unassigned"):
            save_state(str(tmp_path / "x.ckpt"), state)
        collect(state)


# ---------------------------------------------------------------------------
# Satellite: deregister vs in-flight PendingChunk schedules
# ---------------------------------------------------------------------------

class TestDeregisterPinGuard:
    def test_deregister_deferred_while_pinned(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _task())
        while state.phase != TaskPhase.SCHEDULED:
            state, _ = step(sp, state, AsyncStub())
        dispatch(sp, state, AsyncStub())
        cid = int(state.pending.chunk[0][0])
        assert sp.pool_state.is_pinned(cid)
        sp.pool_state.deregister([cid])
        # still registered: the in-flight schedule references the row
        assert sp.pool_state.is_registered([cid]).all()
        assert cid in sp.pool_state._deferred_dereg
        collect(state)                       # unpin -> deferred applied
        assert not sp.pool_state.is_registered([cid]).any()
        assert not sp.pool_state.is_pinned(cid)

    def test_rejoin_after_deferred_deregister(self):
        """A deferred-deregistered client is still registered (so it
        cannot double-register); once the pin releases and the removal
        lands, a normal rejoin reactivates the row and resets its
        timing stats."""
        sp = FLServiceProvider(_profiles())
        state = submit(sp, _task())
        while state.phase != TaskPhase.SCHEDULED:
            state, _ = step(sp, state, AsyncStub())
        dispatch(sp, state, AsyncStub())
        cid = int(state.pending.chunk[0][0])
        sp.pool_state.deregister([cid])
        row = int(sp.pool_state.positions(
            [cid], include_deregistered=True)[0])
        # still registered while pinned: a re-register is rejected
        with pytest.raises(ValueError, match="already registered"):
            sp.pool_state.register_arrays(
                [cid], sp.pool_state.scores[row:row + 1],
                sp.pool_state.histograms[row:row + 1],
                sp.pool_state.costs[row:row + 1])
        sp.pool_state.timeout_counts[row] = 5
        sp.pool_state.dispatch_counts[row] = 5
        collect(state)                 # unpin -> deferred dereg applied
        assert not sp.pool_state.is_registered([cid]).any()
        sp.pool_state.register_arrays(
            [cid], sp.pool_state.scores[row:row + 1],
            sp.pool_state.histograms[row:row + 1],
            sp.pool_state.costs[row:row + 1])
        assert sp.pool_state.is_registered([cid]).all()
        assert cid not in sp.pool_state._deferred_dereg
        # a rejoin is a new device: timing stats reset
        assert sp.pool_state.timeout_counts[row] == 0
        assert sp.pool_state.dispatch_counts[row] == 0

    def test_unpinned_deregister_still_immediate(self):
        sp = FLServiceProvider(_profiles())
        cid = int(sp.pool_state.client_ids[0])
        sp.pool_state.deregister([cid])
        assert not sp.pool_state.is_registered([cid]).any()


# ---------------------------------------------------------------------------
# ServiceScheduler: backpressure + wedged-tenant eviction
# ---------------------------------------------------------------------------

class TestSchedulerRobustness:
    def test_submit_backpressure(self):
        sp = FLServiceProvider(_profiles())
        sched = ServiceScheduler(sp, max_queue=2)
        t0 = sched.submit(_task(seed=0), AsyncStub())
        t1 = sched.submit(_task(seed=1), AsyncStub())
        assert isinstance(t0, int) and isinstance(t1, int)
        rej = sched.submit(_task(seed=2), AsyncStub())
        assert isinstance(rej, RejectedTask)
        assert rej.queued == 2 and "intake queue full" in rej.reason
        assert rej.task.seed == 2
        sched.sweep()                    # drains the intake backlog
        t2 = sched.submit(rej.task, AsyncStub())
        assert isinstance(t2, int)
        res = sched.run()
        assert set(res) == {t0, t1, t2}
        assert all(r.rounds for r in res.values())

    def test_wedged_tenant_cannot_starve_the_window(self):
        sp = FLServiceProvider(_profiles(n=80))
        sched = ServiceScheduler(sp, max_inflight=2, inflight_deadline=2)
        healthy = [sched.submit(_task(seed=s), AsyncStub())
                   for s in (0, 1)]
        wedged = sched.submit(_task(seed=2), WedgedStub())
        res = sched.run()
        for tid in healthy:
            assert sched.state(tid).phase == TaskPhase.DONE
            assert res[tid].rounds
        assert sched.state(wedged).phase == TaskPhase.DEGRADED
        assert sched.state(wedged).pending is None
        assert sp.pool_state._pins == {}      # eviction unpinned

    def test_without_deadline_wedged_raises_max_sweeps(self):
        sp = FLServiceProvider(_profiles(n=80))
        sched = ServiceScheduler(sp, max_inflight=2)
        sched.submit(_task(seed=0), AsyncStub())
        wedged = sched.submit(_task(seed=2), WedgedStub())
        with pytest.raises(RuntimeError, match="still active"):
            sched.run(max_sweeps=25)
        assert sched.state(wedged).phase == TaskPhase.TRAINING

    def test_task_id_assigned(self):
        sp = FLServiceProvider(_profiles())
        sched = ServiceScheduler(sp)
        tid = sched.submit(_task(), AsyncStub())
        assert sched.state(tid).task_id == tid


# ---------------------------------------------------------------------------
# straggler_aware selection policy
# ---------------------------------------------------------------------------

class TestStragglerAwareSelection:
    def _pool(self, n=30, seed=0):
        return ClientPoolState.from_profiles(_profiles(n=n, seed=seed))

    def test_matches_greedy_without_history(self):
        pool = self._pool()
        task = _task(selection_policy="straggler_aware")
        rng = np.random.default_rng(0)
        ours = selection_policy("straggler_aware").select(pool, task, rng)
        ref = selection_policy("paper_greedy").select(pool, task, rng)
        assert sorted(ours.selected) == sorted(ref.selected)
        assert ours.total_cost == pytest.approx(ref.total_cost)

    def test_chronic_stragglers_priced_out(self):
        pool = self._pool()
        task = _task(budget=60.0, n_star=1,
                     selection_policy="straggler_aware")
        rng = np.random.default_rng(0)
        baseline = selection_policy("straggler_aware").select(
            pool, task, rng)
        victim = int(baseline.selected[0])
        row = pool.positions([victim])[0]
        pool.note_timing(np.repeat(row, 10), np.repeat(row, 10))
        assert pool.timeout_rate()[row] == 1.0
        after = selection_policy("straggler_aware").select(pool, task, rng)
        assert victim not in after.selected
        # the reference greedy still picks it (no timing awareness)
        ref = selection_policy("paper_greedy").select(pool, task, rng)
        assert victim in ref.selected


# ---------------------------------------------------------------------------
# Device/host arrival masking
# ---------------------------------------------------------------------------

class TestArrivalMask:
    def test_dropout_mask_default_path_unchanged(self):
        import jax.numpy as jnp
        from repro.fl import device_data
        mask_u = jnp.asarray(np.linspace(0.0, 1.0, 8))
        active = jnp.ones(8)
        a = device_data.dropout_mask(mask_u, active, 0.3)
        b = device_data.dropout_mask(mask_u, active, 0.3, arrival=None)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_dropout_mask_arrival_masks_and_falls_back(self):
        import jax.numpy as jnp
        from repro.fl import device_data
        mask_u = jnp.asarray(np.full(6, 0.9))
        active = jnp.ones(6)
        arrival = jnp.asarray([0.0, 0.0, 1.0, 1.0, 0.0, 1.0])
        out = np.asarray(device_data.dropout_mask(
            mask_u, active, 0.0, arrival=arrival))
        np.testing.assert_array_equal(out, np.asarray(arrival))
        # all-drop: fallback is the first ARRIVED slot, not slot 0
        out = np.asarray(device_data.dropout_mask(
            jnp.zeros(6), active, 0.5, arrival=arrival))
        np.testing.assert_array_equal(out,
                                      [0.0, 0.0, 1.0, 0.0, 0.0, 0.0])

    def test_fault_mode_masks_q_and_returned(self):
        """Host-side settle masks non-arrived clients out of reputation:
        their b_t is 0 even when the stub says they returned."""
        sp = FLServiceProvider(_profiles())
        task = _mitigated_task(max_periods=1)
        plan = FaultPlan(seed=11, straggler_frac=0.5,
                         straggler_slowdown=50.0, latency_jitter=0.0)
        state = submit(sp, task)
        state, events = drain(sp, state, FaultyChunkStub(fault_plan=plan))
        stragglers = {int(c) for c in np.arange(60)[
            plan.is_straggler(np.arange(60))]}
        missed = 0
        for ev in events:
            for cid in ev.subset:
                if cid in stragglers:
                    rec = state.tracker.records[cid]
                    assert not rec.b_rounds.any()
                    missed += 1
        assert missed > 0
