"""MKP solver tests: feasibility always, optimality-gap vs exact B&B."""
import numpy as np
import pytest

from repro.core import mkp as M


def rand_instance(rng, n, m, tightness=0.5):
    weights = rng.integers(0, 30, size=(n, m)).astype(float)
    values = weights.sum(axis=1) + rng.uniform(0, 5, n)  # like paper: value=|h|_1
    capacities = tightness * weights.sum(axis=0)
    return values, weights, capacities


class TestGreedy:
    def test_feasible_always(self):
        rng = np.random.default_rng(0)
        for _ in range(30):
            v, w, c = rand_instance(rng, int(rng.integers(3, 60)), int(rng.integers(2, 12)))
            res = M.solve_mkp_greedy(v, w, c)
            assert M.is_feasible(w, c, res.selected)
            assert res.value == pytest.approx(v[res.selected].sum() if res.selected else 0.0)

    def test_max_size_respected(self):
        rng = np.random.default_rng(1)
        v, w, c = rand_instance(rng, 40, 5, tightness=2.0)
        res = M.solve_mkp_greedy(v, w, c, max_size=7)
        assert len(res.selected) <= 7

    def test_zero_capacity_selects_zero_weight_only(self):
        v = np.array([5.0, 3.0])
        w = np.array([[1.0, 0.0], [0.0, 0.0]])
        c = np.zeros(2)
        res = M.solve_mkp_greedy(v, w, c)
        assert res.selected == [1]

    def test_no_duplicates(self):
        rng = np.random.default_rng(2)
        v, w, c = rand_instance(rng, 50, 4)
        res = M.solve_mkp_greedy(v, w, c)
        assert len(res.selected) == len(set(res.selected))


class TestExact:
    def test_bnb_matches_bruteforce(self):
        rng = np.random.default_rng(3)
        for _ in range(10):
            n, m = 10, 3
            v, w, c = rand_instance(rng, n, m)
            best = 0.0
            for mask in range(1 << n):
                idx = [i for i in range(n) if mask >> i & 1]
                if M.is_feasible(w, c, idx):
                    best = max(best, float(v[idx].sum()))
            res = M.solve_mkp_bnb(v, w, c)
            assert res.optimal
            assert res.value == pytest.approx(best, abs=1e-9)

    def test_bnb_with_max_size(self):
        rng = np.random.default_rng(4)
        n, m = 9, 2
        v, w, c = rand_instance(rng, n, m, tightness=1.5)
        k = 3
        best = 0.0
        for mask in range(1 << n):
            idx = [i for i in range(n) if mask >> i & 1]
            if len(idx) <= k and M.is_feasible(w, c, idx):
                best = max(best, float(v[idx].sum()))
        res = M.solve_mkp_bnb(v, w, c, max_size=k)
        assert res.value == pytest.approx(best, abs=1e-9)
        assert len(res.selected) <= k


class TestGap:
    def test_greedy_gap_small(self):
        """Greedy+LS should stay within 20% of optimal on paper-like
        instances (value = data size, weights = histograms)."""
        rng = np.random.default_rng(5)
        gaps = []
        for _ in range(15):
            v, w, c = rand_instance(rng, 16, int(rng.integers(3, 10)))
            g = M.solve_mkp_greedy(v, w, c)
            e = M.solve_mkp_bnb(v, w, c)
            if e.value > 0:
                gaps.append((e.value - g.value) / e.value)
        assert np.mean(gaps) < 0.1
        assert max(gaps) < 0.25

    def test_dispatch(self):
        rng = np.random.default_rng(6)
        v, w, c = rand_instance(rng, 10, 3)
        assert M.solve_mkp(v, w, c).optimal           # small -> exact
        v, w, c = rand_instance(rng, 100, 3)
        assert not M.solve_mkp(v, w, c).optimal       # big -> greedy


class TestValidation:
    def test_bad_shapes(self):
        with pytest.raises(ValueError):
            M.solve_mkp_greedy(np.ones(3), np.ones((2, 2)), np.ones(2))
        with pytest.raises(ValueError):
            M.solve_mkp_greedy(np.ones(3), np.ones((3, 2)), np.ones(3))
        with pytest.raises(ValueError):
            M.solve_mkp_greedy(np.ones(2), -np.ones((2, 2)), np.ones(2))
