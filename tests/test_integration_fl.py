"""End-to-end integration: selection -> scheduling -> real federated CNN
training on partitioned synthetic data (small scale)."""
import numpy as np
import pytest

from repro.fl import run_fl_experiment
from repro.fl.simulation import SimConfig


@pytest.mark.slow
class TestEndToEnd:
    def test_mkp_scheduled_training_runs(self):
        out = run_fl_experiment(
            "mnist", "type1", n_clients=20, rounds=6, scheduler="mkp",
            n_train=1200, n_test=400, subset_size=5,
            sim=SimConfig(batch_size=8, local_steps=2, eval_every=2, seed=0))
        assert len(out["history"]) == 6
        assert 0.0 <= out["final_accuracy"] <= 1.0
        # every pooled client participated in period 0
        svc = out["service"]
        assert svc.pool.feasible
        p0 = {c for r in svc.rounds if r.period == 0 for c in r.subset}
        assert p0 == set(svc.pool.selected)
        # scheduled subsets have low integrated Nid vs worst-case 1.0
        assert np.mean([r.nid for r in svc.rounds]) < 0.6

    def test_random_scheduler_baseline_runs(self):
        out = run_fl_experiment(
            "mnist", "type1", n_clients=20, rounds=4, scheduler="random",
            n_train=800, n_test=200, subset_size=5,
            sim=SimConfig(batch_size=8, local_steps=1, eval_every=2, seed=0))
        assert len(out["history"]) == 4

    def test_loss_decreases_over_rounds(self):
        out = run_fl_experiment(
            "mnist", "type2", n_clients=16, rounds=12, scheduler="mkp",
            n_train=1600, n_test=400, subset_size=8,
            sim=SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                          eval_every=100, dropout_rate=0.0, seed=1))
        losses = [h["loss"] for h in out["history"]]
        assert np.mean(losses[-3:]) < np.mean(losses[:3])
