"""Equivalence tests: array-native engine vs the legacy Python-loop
implementations (PR acceptance criterion), plus ClientPoolState adapters
and the batched multi-task paths."""
import numpy as np
import pytest

from repro.core import engine
from repro.core import mkp as M
from repro.core import scheduling as Sch
from repro.core import selection as S
from repro.core.criteria import random_histograms, random_profiles
from repro.core.pool import ClientPoolState
from repro.core.service import FLServiceProvider, TaskRequest
from test_core_scheduling import make_pool
from test_core_selection import BUDGET, PAPER_COSTS, PAPER_SCORES


def rand_knapsack(rng, n=None):
    n = int(rng.integers(3, 200)) if n is None else n
    scores = rng.uniform(1, 10, n)
    costs = np.rint(rng.uniform(3, 25, n))
    budget = float(rng.integers(10, 900))
    return scores, costs, budget


class TestGreedyEquivalence:
    def test_paper_instance(self):
        vec = S.select_greedy(PAPER_SCORES, PAPER_COSTS, BUDGET)
        leg = S.select_greedy_legacy(PAPER_SCORES, PAPER_COSTS, BUDGET)
        assert vec.selected == leg.selected
        assert sorted(vec.selected) == [0, 2, 3, 4, 5]   # paper Table III
        assert vec.total_score == pytest.approx(leg.total_score)

    @pytest.mark.parametrize("skip", [False, True])
    def test_randomized_identical(self, skip):
        rng = np.random.default_rng(0)
        for _ in range(40):
            s, c, B = rand_knapsack(rng)
            vec = S.select_greedy(s, c, B, skip_unaffordable=skip)
            leg = S.select_greedy_legacy(s, c, B, skip_unaffordable=skip)
            assert vec.selected == leg.selected
            assert vec.total_score == pytest.approx(leg.total_score, abs=1e-9)
            assert vec.total_cost == pytest.approx(leg.total_cost, abs=1e-9)

    def test_ids_and_empty(self):
        s, c = np.array([2.0, 1.0]), np.array([5.0, 5.0])
        res = S.select_greedy(s, c, 5.0, ids=[7, 9])
        assert res.selected == [7]
        assert S.select_greedy(np.zeros(0), np.zeros(0), 10.0).selected == []

    def test_batch_matches_single(self):
        rng = np.random.default_rng(1)
        n = 300
        s = rng.uniform(1, 10, n).astype(np.float32)
        c = np.rint(rng.uniform(3, 25, n)).astype(np.float32)
        budgets = np.array([50.0, 400.0, 2000.0, 1e6], np.float32)
        masks, ts, tc = engine.greedy_knapsack_batch(s, c, budgets)
        for t, B in enumerate(budgets):
            # compare against the single-task vectorized path in f32
            chosen, _, _ = engine.greedy_knapsack(
                s.astype(np.float64), c.astype(np.float64), float(B))
            want = np.zeros(n, bool)
            want[chosen] = True
            np.testing.assert_array_equal(masks[t], want)
            assert ts[t] == pytest.approx(s[want].sum(), rel=1e-5)

    def test_batch_respects_validity(self):
        rng = np.random.default_rng(2)
        n = 100
        s = rng.uniform(1, 10, n)
        c = np.rint(rng.uniform(3, 25, n))
        valid = rng.uniform(size=(3, n)) < 0.5
        budgets = np.full(3, 200.0)
        masks, _, _ = engine.greedy_knapsack_batch(s, c, budgets, valid)
        assert not np.any(masks & ~valid)
        for t in range(3):
            chosen, _, _ = engine.greedy_knapsack(
                s[valid[t]], c[valid[t]], 200.0)
            want = np.zeros(n, bool)
            want[np.flatnonzero(valid[t])[chosen]] = True
            np.testing.assert_array_equal(masks[t], want)


class TestMKPEquivalence:
    def rand_instance(self, rng, n=60, m=7):
        w = rng.integers(0, 30, size=(n, m)).astype(float)
        v = w.sum(axis=1) + rng.uniform(0, 5, n)
        cap = 0.4 * w.sum(axis=0)
        return v, w, cap

    def test_pseudo_utility_matches_inline_formula(self):
        rng = np.random.default_rng(3)
        v, w, cap = self.rand_instance(rng)
        residual = cap * rng.uniform(0.2, 1.0, cap.shape)
        selectable = rng.uniform(size=v.shape) < 0.8
        util, fits = engine.mkp_pseudo_utility(v, w, residual, selectable)
        # the legacy loop's exact computation
        scarcity = 1.0 / np.maximum(residual, 1e-12)
        want_fits = selectable & np.all(w <= residual + 1e-12, axis=1)
        want = np.where(want_fits,
                        v / np.maximum(w @ scarcity, 1e-12), -np.inf)
        np.testing.assert_array_equal(fits, want_fits)
        np.testing.assert_allclose(util, want)

    def test_jax_greedy_matches_legacy_greedy_phase(self):
        rng = np.random.default_rng(4)
        for _ in range(10):
            v, w, cap = self.rand_instance(rng, n=int(rng.integers(20, 80)))
            leg = M.solve_mkp_greedy(v, w, cap, local_search=False)
            mask, used = engine.solve_mkp_greedy_jax(v, w, cap)
            assert sorted(int(j) for j in np.flatnonzero(mask)) == leg.selected
            np.testing.assert_allclose(used, leg.used, rtol=1e-5, atol=1e-4)

    def test_jax_greedy_max_size(self):
        rng = np.random.default_rng(5)
        v, w, cap = self.rand_instance(rng, n=50)
        mask, _ = engine.solve_mkp_greedy_jax(v, w, cap, max_size=7)
        assert mask.sum() <= 7

    def test_solve_mkp_jax_backend_feasible(self):
        rng = np.random.default_rng(6)
        v, w, cap = self.rand_instance(rng, n=40)
        res = M.solve_mkp(v, w, cap, backend="jax")
        assert M.is_feasible(w, cap, res.selected, slack=1e-3)

    def test_pallas_kernel_matches_ref(self):
        import jax.numpy as jnp
        from repro.kernels import ops, ref
        rng = np.random.default_rng(7)
        for n, m in [(64, 8), (37, 10), (200, 3)]:
            v = jnp.asarray(rng.uniform(1, 10, n))
            w = jnp.asarray(rng.integers(0, 30, (n, m)).astype(float))
            r = jnp.asarray(0.3 * np.asarray(w).sum(0))
            sel = jnp.asarray(rng.uniform(size=n) < 0.7)
            out_k = ops.mkp_utility(v, w, r, sel, interpret=True)
            out_r = ref.mkp_utility_ref(v, w, r, sel)
            finite = np.isfinite(np.asarray(out_r))
            np.testing.assert_array_equal(np.isfinite(np.asarray(out_k)),
                                          finite)
            np.testing.assert_allclose(np.asarray(out_k)[finite],
                                       np.asarray(out_r)[finite], rtol=1e-6)


class TestSchedulerEquivalence:
    @pytest.mark.parametrize("kind", ["type1", "type2", "type3", "iid"])
    def test_identical_schedules(self, kind):
        hists = make_pool(kind, n_clients=60)
        new = Sch.generate_subsets(hists, n=10, delta=3, x_star=3)
        leg = Sch.generate_subsets_legacy(hists, n=10, delta=3, x_star=3)
        assert new.subsets == leg.subsets
        assert new.counts == leg.counts
        np.testing.assert_allclose(new.nids, leg.nids, rtol=1e-12)
        np.testing.assert_array_equal(new.capacities, leg.capacities)

    def test_identical_on_random_pools(self):
        rng = np.random.default_rng(8)
        for trial in range(5):
            P = int(rng.integers(15, 70))
            H = random_histograms(P, int(rng.integers(3, 12)), rng)
            hists = {i: H[i] for i in range(P)}
            n = int(rng.integers(4, 12))
            delta = int(rng.integers(1, 4))
            new = Sch.generate_subsets(hists, n=n, delta=delta, x_star=3)
            leg = Sch.generate_subsets_legacy(hists, n=n, delta=delta,
                                              x_star=3)
            assert new.subsets == leg.subsets, (trial, P, n, delta)
            assert new.counts == leg.counts

    def test_pool_state_input(self):
        hists = make_pool("type2", n_clients=40)
        pool = ClientPoolState.from_histograms(hists)
        via_pool = Sch.generate_subsets(pool, n=8, delta=2)
        via_dict = Sch.generate_subsets(hists, n=8, delta=2)
        assert via_pool.subsets == via_dict.subsets


class TestPoolState:
    def test_profile_round_trip(self):
        profs = random_profiles(25, 6, np.random.default_rng(9))
        pool = ClientPoolState.from_profiles(profs)
        back = pool.to_profiles()
        assert [p.client_id for p in back] == [p.client_id for p in profs]
        for a, b in zip(back, profs):
            np.testing.assert_array_equal(a.scores, b.scores)
            np.testing.assert_array_equal(a.histogram, b.histogram)
            assert a.cost == b.cost

    def test_threshold_mask_matches_filter(self):
        profs = random_profiles(40, 6, np.random.default_rng(10))
        pool = ClientPoolState.from_profiles(profs)
        th = np.full(9, 0.3)
        kept_legacy = {p.client_id for p in S.threshold_filter(profs, th)}
        mask = pool.threshold_mask(th)
        assert set(pool.client_ids[mask].tolist()) == kept_legacy

    def test_budget_floor_matches(self):
        profs = random_profiles(30, 6, np.random.default_rng(11))
        pool = ClientPoolState.from_profiles(profs)
        assert pool.budget_floor(5) == pytest.approx(S.budget_floor(profs, 5))

    def test_select_initial_pool_profile_vs_pool(self):
        profs = random_profiles(50, 8, np.random.default_rng(12))
        pool = ClientPoolState.from_profiles(profs)
        a = S.select_initial_pool(profs, budget=300.0, n_star=3)
        b = S.select_initial_pool(pool, budget=300.0, n_star=3)
        assert a.selected == b.selected
        assert a.total_score == pytest.approx(b.total_score)

    def test_random_pool_shapes(self):
        pool = ClientPoolState.random(1000, 10, np.random.default_rng(13))
        assert pool.n == 1000 and pool.num_classes == 10
        assert (pool.data_sizes() > 0).all()
        assert np.isfinite(pool.overall).all()

    def test_positions(self):
        pool = ClientPoolState(np.array([5, 2, 9]), np.zeros((3, 11)),
                               np.ones((3, 4)), np.ones(3))
        np.testing.assert_array_equal(pool.positions([9, 5]), [2, 0])


class TestServiceBatch:
    def test_select_pools_batch_matches_single(self):
        sp = FLServiceProvider(random_profiles(80, 10,
                                               np.random.default_rng(14)))
        tasks = [TaskRequest(budget=b, n_star=2,
                             thresholds=th)
                 for b, th in [(150.0, None), (600.0, np.full(9, 0.2)),
                               (50.0, None), (1e6, np.full(9, 0.4))]]
        batch = sp.select_pools_batch(tasks)
        for task, got in zip(tasks, batch):
            single = sp.select_pool(task)
            assert got.feasible == single.feasible
            assert sorted(got.selected) == sorted(single.selected)
            assert got.total_cost == pytest.approx(single.total_cost,
                                                   rel=1e-5)

    def test_infeasible_task_in_batch(self):
        sp = FLServiceProvider(random_profiles(10, 5,
                                               np.random.default_rng(15)))
        res = sp.select_pools_batch(
            [TaskRequest(budget=1e6, n_star=99)])[0]
        assert not res.feasible
