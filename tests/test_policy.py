"""ISSUE-5 pluggable selection & scheduling policy API.

Covers the registry (lookup, registration errors, protocol checks),
bit-equivalence of the default policies against the pre-registry
``select_pool`` / ``select_pools_batch`` / ``generate_subsets`` paths,
the behaviour of the shipped alternatives (random / score_prop
selection, fair_ema scheduling), and mixed-policy multi-tenant serving
(batched intake groups by policy and threads the tenants' rngs).
"""
import numpy as np
import pytest

from repro.core import (FLServiceProvider, ServiceScheduler, TaskRequest,
                        as_run_result, drain, generate_subsets,
                        random_profiles, random_subsets, select_initial_pool,
                        select_random, select_score_prop, submit)
from repro.core import policy as P
from repro.core.pool import ClientPoolState


def _pool(n=60, seed=0):
    return ClientPoolState.from_profiles(
        random_profiles(n, 10, np.random.default_rng(seed)))


def _stub(rnd, subset, weights):
    subset = np.asarray(subset)
    returned = (subset + rnd) % 7 != 0
    q = np.where(returned, 0.5 + 0.4 * np.cos(subset + rnd), 0.0)
    return returned, q, {"round": rnd, "loss": 1.0 / (rnd + 1)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_shipped_policies_registered(self):
        assert {"paper_greedy", "dp", "random", "score_prop"} <= \
            set(P.available_selection_policies())
        assert {"iid_subsets", "random_partition", "fair_ema"} <= \
            set(P.available_scheduling_policies())

    def test_instances_satisfy_protocols(self):
        for name in P.available_selection_policies():
            assert isinstance(P.selection_policy(name), P.SelectionPolicy)
        for name in P.available_scheduling_policies():
            assert isinstance(P.scheduling_policy(name), P.SchedulingPolicy)

    def test_unknown_name_lists_registered(self):
        with pytest.raises(KeyError, match="paper_greedy"):
            P.selection_policy("nope")
        with pytest.raises(KeyError, match="iid_subsets"):
            P.scheduling_policy("nope")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            P.register_selection_policy(P.PaperGreedySelection)
        with pytest.raises(ValueError, match="already registered"):
            P.register_scheduling_policy(P.FairEMAScheduling)

    def test_non_policy_rejected(self):
        with pytest.raises(TypeError):
            P.register_selection_policy(object())
        with pytest.raises(TypeError):
            P.register_scheduling_policy(object())

    def test_custom_policy_end_to_end(self):
        class CheapestFirst:
            """Smallest-cost-first; enough budget handling to be usable."""
            name = "_test_cheapest"

            def select(self, pool, task, rng):
                from repro.core.selection import SelectionResult
                mask = pool.threshold_mask(task.thresholds)
                rows = np.flatnonzero(mask)
                rows = rows[np.argsort(pool.costs[rows], kind="stable")]
                chosen, rem = [], float(task.budget)
                for r in rows:
                    if pool.costs[r] <= rem:
                        chosen.append(int(r))
                        rem -= float(pool.costs[r])
                return SelectionResult(
                    pool.client_ids[chosen].tolist(),
                    float(pool.overall[chosen].sum()),
                    float(pool.costs[chosen].sum()),
                    feasible=len(chosen) >= task.n_star)

            def select_batch(self, pool, tasks, rngs):
                return [self.select(pool, t, r)
                        for t, r in zip(tasks, rngs)]

        P.register_selection_policy(CheapestFirst)
        try:
            sp = FLServiceProvider(_pool())
            task = TaskRequest(budget=50.0, n_star=3, subset_size=4,
                               subset_delta=2, max_periods=1,
                               selection_policy="_test_cheapest")
            state = submit(sp, task)
            state, _ = drain(sp, state, _stub)
            res = as_run_result(state)
            assert res.pool.feasible and res.num_rounds > 0
            # cheapest-first spends less per client than the greedy
            greedy = sp.select_pool(TaskRequest(budget=50.0, n_star=3))
            assert len(res.pool.selected) >= len(greedy.selected)
        finally:
            P._SELECTION.pop("_test_cheapest", None)

    def test_resolve_legacy_method_and_scheduler(self):
        task = TaskRequest(budget=1.0)
        assert P.resolve_selection_policy(task).name == "paper_greedy"
        assert P.resolve_selection_policy(task, "dp").name == "dp"
        assert P.resolve_selection_policy(task, "random").name == "random"
        # an explicitly passed method always wins — including "greedy"
        t2 = TaskRequest(budget=1.0, selection_policy="score_prop")
        assert P.resolve_selection_policy(t2).name == "score_prop"
        assert P.resolve_selection_policy(t2, "greedy").name == "paper_greedy"
        assert P.resolve_scheduling_policy(task).name == "iid_subsets"
        t3 = TaskRequest(budget=1.0, scheduler="random")
        assert P.resolve_scheduling_policy(t3).name == "random_partition"
        # an explicitly set field beats the legacy alias — even when it
        # names the default policy
        t4 = TaskRequest(budget=1.0, scheduler="random",
                         scheduling_policy="fair_ema")
        assert P.resolve_scheduling_policy(t4).name == "fair_ema"
        t5 = TaskRequest(budget=1.0, scheduler="random",
                         scheduling_policy="iid_subsets")
        assert P.resolve_scheduling_policy(t5).name == "iid_subsets"


# ---------------------------------------------------------------------------
# Default policies are bit-identical to the pre-registry paths
# ---------------------------------------------------------------------------

class TestDefaultEquivalence:
    @pytest.mark.parametrize("budget,n_star,th", [
        (150.0, 5, None), (80.0, 3, 0.2), (400.0, 10, 0.02), (3.0, 10, None)])
    def test_paper_greedy_select(self, budget, n_star, th):
        pool = _pool()
        thresholds = None if th is None else np.full(9, th)
        task = TaskRequest(budget=budget, n_star=n_star,
                           thresholds=thresholds)
        got = P.selection_policy("paper_greedy").select(pool, task, None)
        ref = select_initial_pool(pool, budget=budget, n_star=n_star,
                                  thresholds=thresholds, method="greedy")
        assert got.selected == ref.selected
        assert got.total_score == ref.total_score
        assert got.total_cost == ref.total_cost
        assert got.feasible == ref.feasible and got.note == ref.note

    def test_provider_select_pool_unchanged(self):
        sp = FLServiceProvider(_pool())
        task = TaskRequest(budget=200.0, n_star=5)
        got = sp.select_pool(task)
        ref = select_initial_pool(sp.pool_state, budget=200.0, n_star=5,
                                  method="greedy")
        assert got.selected == ref.selected
        assert got.total_score == ref.total_score

    def test_batch_default_matches_per_task(self):
        sp = FLServiceProvider(_pool(50, seed=4))
        tasks = [TaskRequest(budget=b, n_star=n, thresholds=th)
                 for b, n, th in [(150.0, 5, None),
                                  (80.0, 3, np.full(9, 0.2)),
                                  (3.0, 10, None)]]
        batch = sp.select_pools_batch(tasks)
        for task, b in zip(tasks, batch):
            s = sp.select_pool(task)
            assert sorted(s.selected) == sorted(b.selected)
            assert s.total_score == pytest.approx(b.total_score)
            assert s.feasible == b.feasible and s.note == b.note

    def test_iid_subsets_schedule_bit_identical(self):
        pool = _pool(40, seed=2)
        ids, H = pool.client_ids, pool.histograms
        task = TaskRequest(budget=0.0, subset_size=6, subset_delta=2,
                           x_star=3, nid_threshold=0.35)
        got = P.scheduling_policy("iid_subsets").schedule(
            ids, H, task, np.random.default_rng(0), {})
        ref = generate_subsets((ids, H), n=6, delta=2, x_star=3,
                               nid_threshold=0.35)
        assert got.subsets == ref.subsets
        assert got.nids == ref.nids
        assert got.counts == ref.counts
        np.testing.assert_array_equal(got.capacities, ref.capacities)

    def test_random_partition_matches_legacy_scheduler_field(self):
        sp = FLServiceProvider(_pool(40, seed=2))
        ids = sp.pool_state.client_ids.tolist()
        legacy_task = TaskRequest(budget=0.0, subset_size=6,
                                  scheduler="random")
        got = sp.schedule_period(ids, legacy_task,
                                 np.random.default_rng(7))
        hists = {int(c): sp.pool_state.histograms[i]
                 for i, c in enumerate(sp.pool_state.client_ids)}
        ref = random_subsets(hists, 6, np.random.default_rng(7))
        assert got.subsets == ref.subsets
        assert got.nids == ref.nids


# ---------------------------------------------------------------------------
# Alternative selection policies
# ---------------------------------------------------------------------------

class TestAlternativeSelection:
    def test_all_policies_respect_budget(self):
        pool = _pool()
        task = TaskRequest(budget=120.0, n_star=3)
        for name in P.available_selection_policies():
            res = P.selection_policy(name).select(
                pool, task, np.random.default_rng(0))
            assert res.total_cost <= task.budget + 1e-9, name
            assert res.total_cost == pytest.approx(
                float(pool.costs[pool.positions(res.selected)].sum()))

    def test_score_prop_biased_toward_high_scores(self):
        pool = _pool(200, seed=1)
        task = TaskRequest(budget=150.0, n_star=1)
        mean_sp, mean_rnd = [], []
        for seed in range(12):
            rng = np.random.default_rng(seed)
            sp_res = P.selection_policy("score_prop").select(pool, task, rng)
            rnd_res = P.selection_policy("random").select(
                pool, task, np.random.default_rng(seed))
            rows = pool.positions(sp_res.selected)
            mean_sp.append(pool.overall[rows].mean())
            rows = pool.positions(rnd_res.selected)
            mean_rnd.append(pool.overall[rows].mean())
        assert np.mean(mean_sp) > np.mean(mean_rnd)

    def test_score_prop_zero_scores_still_randomize(self):
        # regression: u**(1/1e-12) underflowed every key to 0.0, so
        # zero-score pools degenerated to a deterministic
        # lowest-index-first pick; the log-space keys must keep the
        # draw a genuine permutation
        scores = np.zeros(10)
        costs = np.ones(10)
        picks = {tuple(select_score_prop(scores, costs, 3.0,
                                         np.random.default_rng(s)).selected)
                 for s in range(8)}
        assert len(picks) > 1
        assert any(p != tuple(sorted(p)) or p != (0, 1, 2) for p in picks)

    def test_score_prop_deterministic_given_rng(self):
        pool = _pool()
        a = select_score_prop(pool.overall, pool.costs, 100.0,
                              np.random.default_rng(3))
        b = select_score_prop(pool.overall, pool.costs, 100.0,
                              np.random.default_rng(3))
        assert a.selected == b.selected

    def test_score_prop_stop_rule_matches_random_baseline(self):
        # equal scores => the weighted order is a uniform permutation;
        # the budget scan must stop at the first unaffordable client,
        # exactly like select_random
        costs = np.array([5.0, 50.0, 5.0, 5.0])
        scores = np.ones(4)
        res = select_score_prop(scores, costs, 12.0,
                                np.random.default_rng(0))
        assert res.total_cost <= 12.0
        ref = select_random(scores, costs, 12.0, np.random.default_rng(0))
        assert len(res.selected) <= 3 and len(ref.selected) <= 3


# ---------------------------------------------------------------------------
# fair_ema scheduling
# ---------------------------------------------------------------------------

class TestFairEMA:
    def _schedule(self, ids, H, state, n=5, delta=2, x_star=3):
        task = TaskRequest(budget=0.0, subset_size=n, subset_delta=delta,
                           x_star=x_star)
        return P.scheduling_policy("fair_ema").schedule(
            np.asarray(ids, np.int64), np.asarray(H, np.float64), task,
            np.random.default_rng(0), state)

    def _random_pool(self, n, seed=0):
        rng = np.random.default_rng(seed)
        ids = np.arange(n, dtype=np.int64)
        return ids, rng.integers(1, 50, size=(n, 10)).astype(np.float64)

    def test_under_served_get_compensation_slots(self):
        ids, H = self._random_pool(20)
        # clients 0..9 chronically over-served, 10..19 never served
        state = {"fair_ema/ids": ids.copy(),
                 "fair_ema/ema": np.concatenate([np.full(10, 3.0),
                                                 np.zeros(10)])}
        res = self._schedule(ids, H, state)
        counts = np.array([res.counts[int(c)] for c in ids])
        assert np.all(counts[:10] == 1)        # penalized: exactly once
        assert counts[10:].sum() > 10          # compensated: extras
        assert counts.max() <= 3               # x_star bound

    def test_under_served_scheduled_first(self):
        ids, H = self._random_pool(20)
        state = {"fair_ema/ids": ids.copy(),
                 "fair_ema/ema": np.concatenate([np.full(10, 3.0),
                                                 np.zeros(10)])}
        res = self._schedule(ids, H, state)
        # the first subset is drawn entirely from the never-served half
        assert set(res.subsets[0]) <= set(range(10, 20))

    def test_ema_state_written_and_updated(self):
        ids, H = self._random_pool(12)
        state = {}
        res1 = self._schedule(ids, H, state)
        np.testing.assert_array_equal(state["fair_ema/ids"], ids)
        counts1 = np.array([res1.counts[int(c)] for c in ids], float)
        np.testing.assert_allclose(state["fair_ema/ema"], 0.5 * counts1)
        # a second period sees the first period's EMAs
        before = state["fair_ema/ema"].copy()
        self._schedule(ids, H, state)
        assert not np.array_equal(state["fair_ema/ema"], before)

    def test_compensation_rotates_across_periods(self):
        # with a persistent state, cumulative counts even out: nobody
        # keeps receiving extras period after period
        ids, H = self._random_pool(20)
        state = {}
        total = np.zeros(20, dtype=np.int64)
        for _ in range(6):
            res = self._schedule(ids, H, state)
            total += np.array([res.counts[int(c)] for c in ids])
        assert total.max() - total.min() <= 3

    def test_joiner_gets_priority(self):
        ids, H = self._random_pool(10)
        state = {}
        self._schedule(ids, H, state)
        ids2 = np.concatenate([ids, [99]])
        H2 = np.concatenate([H, H[:1]], axis=0)
        res = self._schedule(ids2, H2, state)
        assert 99 in res.subsets[0]            # unseen => EMA 0 => first

    def test_stateless_call_is_deterministic(self):
        ids, H = self._random_pool(15, seed=3)
        a = self._schedule(ids, H, {})
        b = self._schedule(ids, H, {})
        assert a.subsets == b.subsets and a.counts == b.counts


# ---------------------------------------------------------------------------
# Policies through the full service (mixed-tenant, batched intake)
# ---------------------------------------------------------------------------

class TestMixedPolicyService:
    PAIRS = [("paper_greedy", "iid_subsets"),
             ("random", "random_partition"),
             ("score_prop", "fair_ema"),
             ("dp", "fair_ema"),
             ("paper_greedy", "random_partition"),
             ("score_prop", "iid_subsets")]

    def _tasks(self):
        return [TaskRequest(budget=250.0 + 20 * t, n_star=5, subset_size=4,
                            subset_delta=2, max_periods=2, seed=t,
                            selection_policy=sel, scheduling_policy=sch)
                for t, (sel, sch) in enumerate(self.PAIRS)]

    def test_scheduler_matches_serial_per_policy(self):
        profiles = random_profiles(60, 10, np.random.default_rng(0))
        tasks = self._tasks()
        serial = {}
        for tid, task in enumerate(tasks):
            sp = FLServiceProvider(profiles)
            st = submit(sp, task)
            st, _ = drain(sp, st, _stub)
            serial[tid] = as_run_result(st)

        sched = ServiceScheduler(FLServiceProvider(profiles))
        for task in tasks:
            sched.submit(task, _stub)
        conc = sched.run()
        for tid, task in enumerate(tasks):
            a, b = serial[tid], conc[tid]
            assert sorted(a.pool.selected) == sorted(b.pool.selected), \
                self.PAIRS[tid]
            assert [(r.period, r.round_index, r.subset) for r in a.rounds] \
                == [(r.period, r.round_index, r.subset) for r in b.rounds], \
                self.PAIRS[tid]
            assert a.reputation == b.reputation

    def test_policies_differ_on_same_pool(self):
        # the seam exists so strategies can be A/B'd: on one pool with
        # a binding budget, the paper greedy and the uniform baseline
        # must actually pick different pools (else the test is vacuous)
        profiles = random_profiles(80, 10, np.random.default_rng(1))
        sp = FLServiceProvider(profiles)
        base = dict(budget=120.0, n_star=3, seed=0)
        greedy = submit(sp, TaskRequest(**base,
                                        selection_policy="paper_greedy"))
        rnd = submit(sp, TaskRequest(**base, selection_policy="random"))
        assert sorted(greedy.pool) != sorted(rnd.pool)
        assert greedy.pool_selected.total_score > \
            rnd.pool_selected.total_score
