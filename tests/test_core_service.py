"""Reputation, fairness guarantees, and end-to-end service orchestration
(driven through the lifecycle API; the deprecated run_task shim has its
own equivalence suite in test_lifecycle.py)."""
import numpy as np
import pytest

from repro.core import (ClientProfile, FLServiceProvider, ReputationTracker,
                        TaskRequest, as_run_result, drain, fairness_report,
                        jain_index, model_quality_batch, random_profiles,
                        submit)
from repro.core import generate_subsets
from test_core_scheduling import make_pool


class TestReputation:
    def test_record_and_aggregate(self):
        tr = ReputationTracker([0, 1])
        tr.record_round(0, True, q_value=0.8)
        tr.record_round(0, True, q_value=0.6)
        tr.record_round(0, False)
        rec = tr.records[0]
        assert rec.b_task == pytest.approx(2 / 3)
        assert rec.q_task == pytest.approx((0.8 + 0.6 + 0.0) / 3)
        assert rec.s_rep == pytest.approx(rec.q_task + rec.b_task)

    def test_q_from_vectors(self):
        tr = ReputationTracker([0])
        tr.record_round(0, True, local_update=np.ones(4), global_update=np.ones(4))
        assert tr.records[0].q_rounds[-1] == pytest.approx(1.0)
        with pytest.raises(ValueError):
            tr.record_round(0, True)

    def test_suspension_and_readd(self):
        tr = ReputationTracker([0, 1], suspension_periods=1, rep_threshold=0.5)
        tr.record_round(0, False)   # bad behavior -> s_rep = 0
        tr.record_round(1, True, q_value=0.9)
        pool = tr.update_pool({0, 1})
        assert pool == {1}          # 0 suspended
        pool = tr.update_pool(pool)
        assert 0 in pool            # re-added after one period (paper step 4)

    def test_unavailable_removed(self):
        tr = ReputationTracker([0, 1])
        tr.record_round(0, True, q_value=1.0)
        tr.record_round(1, True, q_value=1.0)
        pool = tr.update_pool({0, 1}, availability={0: False, 1: True})
        assert pool == {1}

    def test_model_quality_batch(self):
        g = np.array([1.0, 0.0, 0.0])
        L = np.stack([g, -g, np.array([0.0, 1.0, 0.0])])
        q = model_quality_batch(L, g)
        np.testing.assert_allclose(q, [1.0, -1.0, 0.0], atol=1e-12)


class TestFairness:
    def test_report_on_schedule(self):
        hists = make_pool("type1")
        res = generate_subsets(hists, n=10, delta=3, x_star=3)
        rep = fairness_report(res, list(hists), x_star=3)
        assert rep["coverage"] and rep["bounded"]
        assert 0.5 < rep["jain_index"] <= 1.0
        assert rep["max_count"] <= 3

    def test_jain_index(self):
        assert jain_index(np.ones(10)) == pytest.approx(1.0)
        assert jain_index(np.array([1, 0, 0, 0])) == pytest.approx(0.25)
        assert jain_index(np.zeros(0)) == 1.0


def _stub_trainer(fail_ids=(), q=0.9):
    def trainer(rnd, subset, weights):
        returned = np.array([cid not in fail_ids for cid in subset])
        q_vals = np.where(returned, q, 0.0)
        return returned, q_vals, {"round": rnd, "loss": 1.0 / (rnd + 1)}
    return trainer


def _serve(sp, task, trainer, **kw):
    """submit + drain + result (the run_task replacement)."""
    state = submit(sp, task)
    state, _ = drain(sp, state, trainer, **kw)
    return as_run_result(state)


class TestService:
    def _provider(self, n=60, seed=0):
        return FLServiceProvider(random_profiles(n, 10, np.random.default_rng(seed)))

    def test_run_task_end_to_end(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3)
        res = _serve(sp, task, _stub_trainer())
        assert res.pool.feasible
        assert res.num_rounds > 0
        # every pool client participated in period 0
        period0 = {cid for r in res.rounds if r.period == 0 for cid in r.subset}
        assert period0 == set(res.pool.selected)
        # weights are FedAvg-normalized per round
        for r in res.rounds:
            assert r.weights.sum() == pytest.approx(1.0)

    def test_bad_clients_get_suspended(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2, rep_threshold=0.5)
        bad = set(sp.registry)  # fail everyone? no — fail three specific ids
        bad = set(list(sp.registry)[:3])
        res = _serve(sp, task, _stub_trainer(fail_ids=bad))
        p0 = {cid for r in res.rounds if r.period == 0 for cid in r.subset}
        p1 = {cid for r in res.rounds if r.period == 1 for cid in r.subset}
        for cid in bad & p0:
            assert cid not in p1   # suspended in the next period

    def test_availability_respected(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2)
        gone = set(list(sp.registry)[:5])
        res = _serve(sp, task, _stub_trainer(),
                     availability_fn=lambda cid, period: cid not in gone)
        p1 = {cid for r in res.rounds if r.period == 1 for cid in r.subset}
        assert not (gone & p1)

    def test_stop_fn(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=5)
        res = _serve(sp, task, _stub_trainer(),
                     stop_fn=lambda m: m["round"] >= 3)
        assert res.num_rounds == 4

    def test_infeasible_task(self):
        sp = self._provider()
        task = TaskRequest(budget=1.0, n_star=50)
        res = _serve(sp, task, _stub_trainer())
        assert not res.pool.feasible and res.num_rounds == 0

    def test_random_scheduler_baseline(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=1, scheduler="random")
        res = _serve(sp, task, _stub_trainer())
        assert res.num_rounds > 0

    def test_run_task_shim_still_works(self):
        sp = self._provider()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2)
        with pytest.warns(DeprecationWarning, match="run_task"):
            res = sp.run_task(task, _stub_trainer())
        assert res.num_rounds > 0
