"""Unit tests for core.criteria (paper §IV)."""
import numpy as np
import pytest

from repro.core import criteria as C


class TestNid:
    def test_uniform_is_zero(self):
        assert C.nid(np.full(10, 50.0)) == 0.0

    def test_single_label_is_one(self):
        h = np.zeros(10)
        h[3] = 100
        assert C.nid(h) == pytest.approx(1.0)

    def test_paper_example_direction(self):
        # two labels 9:1 should be more non-iid than three labels 5:4:1
        h2 = np.array([90, 10, 0, 0, 0, 0, 0, 0, 0, 0], dtype=float)
        h3 = np.array([50, 40, 10, 0, 0, 0, 0, 0, 0, 0], dtype=float)
        # with the range definition both have min 0 over all classes;
        # restrict to the support (classes owned by client)
        assert C.nid(h2[:2]) > C.nid(h3[:3])

    def test_batch_shape(self):
        h = np.random.default_rng(0).integers(0, 10, size=(7, 5)).astype(float)
        out = C.nid(h)
        assert out.shape == (7,)
        assert np.all((out >= 0) & (out <= 1))

    def test_empty_histogram(self):
        assert C.nid(np.zeros(4)) == 1.0

    def test_data_dist_score_complement(self):
        h = np.array([10.0, 30.0, 20.0])
        assert C.data_dist_score(h) == pytest.approx(1.0 - C.nid(h))


class TestNidVariants:
    @pytest.mark.parametrize("fn", [C.nid_l2, C.nid_hellinger, C.nid_kl])
    def test_uniform_zero_onehot_one(self, fn):
        c = 8
        uniform = np.full(c, 10.0)
        onehot = np.zeros(c); onehot[0] = 80.0
        assert fn(uniform) == pytest.approx(0.0, abs=1e-9)
        assert fn(onehot) == pytest.approx(1.0, abs=1e-6)

    @pytest.mark.parametrize("name", list(C.NID_VARIANTS))
    def test_monotone_in_skew(self, name):
        fn = C.NID_VARIANTS[name]
        c = 10
        vals = []
        for alpha in [0.0, 0.3, 0.6, 0.9]:
            h = np.full(c, 10.0)
            h[0] += alpha * 200
            vals.append(float(fn(h)))
        assert vals == sorted(vals)


class TestResourceScores:
    def test_meets_minimums(self):
        raw = np.array([[2.0, 4.0], [0.5, 8.0]])
        mins = np.array([1.0, 2.0])
        np.testing.assert_array_equal(C.meets_minimums(raw, mins), [True, False])

    def test_scores_in_unit_interval(self):
        rng = np.random.default_rng(1)
        raw = rng.uniform(0.1, 10, size=(20, 7))
        mins = rng.uniform(0.1, 2, size=7)
        s = C.resource_scores(raw, mins)
        assert np.all(s > 0) and np.all(s <= 1.0)

    def test_requires_positive_minimums(self):
        with pytest.raises(ValueError):
            C.resource_scores(np.ones((2, 2)), np.array([0.0, 1.0]))


class TestScoreCost:
    def test_overall_score_weighted(self):
        s = np.ones(C.NUM_CRITERIA) * 0.5
        assert C.overall_score(s) == pytest.approx(0.5 * C.NUM_CRITERIA)
        w = np.zeros(C.NUM_CRITERIA); w[0] = 2.0
        assert C.overall_score(s, w) == pytest.approx(1.0)

    def test_linear_cost_paper_constants(self):
        # Experiment 1: Cost = 2*Score + 5 rounded; client 0: 6.92 -> 18.84 -> 19?
        # Table II says 18 for 6.92: 2*6.92+5 = 18.84 -> rounds to 19. The
        # paper's table evidently truncates/rounds its displayed scores; we
        # verify the formula itself on exact values.
        assert C.linear_cost(6.5, 2, 5, integer=True) == 18
        assert C.linear_cost(4.5, 2, 5) == pytest.approx(14.0)

    def test_cost_requires_positive_a(self):
        with pytest.raises(ValueError):
            C.linear_cost(1.0, a=0.0)

    def test_history_scores(self):
        assert C.per_task_average([1.0, 0.0, 1.0]) == pytest.approx(2 / 3)
        assert C.history_score([0.2, 0.4, 0.9], window=2) == pytest.approx(0.65)
        assert C.per_task_average([]) == 0.0

    def test_cosine_similarity(self):
        a = np.array([1.0, 0.0]); b = np.array([1.0, 0.0])
        assert C.cosine_similarity(a, b) == pytest.approx(1.0)
        assert C.cosine_similarity(a, -b) == pytest.approx(-1.0)
        assert C.cosine_similarity(a, np.zeros(2)) == 0.0


class TestProfiles:
    def test_random_profiles_consistent(self):
        rng = np.random.default_rng(7)
        profs = C.random_profiles(50, 10, rng)
        assert len(profs) == 50
        for p in profs:
            assert p.scores.shape == (C.NUM_CRITERIA,)
            assert p.data_size > 0
            assert p.cost >= 5  # b=5 floor
            # data-driven criteria coherent
            assert p.criterion("data_dist") == pytest.approx(
                C.data_dist_score(p.histogram))

    def test_build_profiles_validates(self):
        with pytest.raises(ValueError):
            C.build_profiles(np.ones((3, C.NUM_CRITERIA)), np.ones((2, 4)),
                             np.ones(3))
