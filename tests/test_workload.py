"""ISSUE-8 online workload harness: trace determinism, the driver's
no-silent-drop and no-trace-identity properties, SLA telemetry, and the
deadline_aware scheduling policy's observability plumbing.

(The §VII fairness property checks for deadline_aware run via the
registry parametrization in tests/test_fairness.py.)
"""
import numpy as np
import pytest

from repro.core import (FaultPlan, FLServiceProvider, RejectedTask,
                        ServiceScheduler, TaskPhase, TaskRequest, drain,
                        submit)
from repro.core import policy as P
from repro.core.criteria import random_histograms
from repro.core.driver import OnlineDriver
from repro.core.lifecycle import TaskState
from repro.core.pool import ClientPoolState
from repro.core.workload import (ArrivalTrace, DeviceSpeedProfile,
                                 DiurnalAvailability, HeterogeneousFaultPlan,
                                 WorkloadTrace, make_workload)


def _round_result(rnd, subset):
    subset = np.asarray(subset)
    returned = (subset + rnd) % 7 != 0
    q = np.where(returned, 0.5 + 0.4 * np.cos(subset + rnd), 0.0)
    return returned, q, {"round": rnd}


class ChunkStub:
    accepts_arrivals = True

    def __init__(self, fault_plan=None):
        self.fault_plan = fault_plan

    def run_rounds(self, start_round, subsets, weights, arrivals=None):
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]


def _pool(n=40, seed=0):
    return ClientPoolState.random(n, 10, np.random.default_rng(seed))


def _budget(pool, frac=0.5):
    return float(np.round(frac * pool.costs.sum()))


# ---------------------------------------------------------------------------
# trace determinism: replay-exact, order/chunking-independent
# ---------------------------------------------------------------------------

def test_arrivals_chunking_and_order_independent():
    tr = ArrivalTrace(seed=3, rate=0.7, window=8.0,
                      burst_rate=4.0, burst_prob=0.3)
    full = tr.arrivals(96.0)
    # per-window queries, in reverse order, concatenated back
    parts = {w: tr.window_arrivals(w) for w in reversed(range(12))}
    rebuilt = np.concatenate([parts[w] for w in range(12)])
    assert np.array_equal(full, rebuilt[rebuilt < 96.0])
    # counts batched vs one by one
    ws = np.arange(12)
    assert np.array_equal(tr.counts(ws),
                          np.array([int(tr.counts(w)[0]) for w in ws]))
    # a longer horizon only appends, never rewrites history
    longer = tr.arrivals(192.0)
    assert np.array_equal(full, longer[longer < 96.0])
    # replay-exact across instances
    assert np.array_equal(full, ArrivalTrace(seed=3, rate=0.7, window=8.0,
                                             burst_rate=4.0,
                                             burst_prob=0.3).arrivals(96.0))


def test_arrivals_seed_sensitivity_and_sorted():
    a = ArrivalTrace(seed=1, rate=1.0).arrivals(64.0)
    b = ArrivalTrace(seed=2, rate=1.0).arrivals(64.0)
    assert not np.array_equal(a, b)
    assert np.all(np.diff(a) >= 0), "arrivals must be ascending"


def test_arrival_rate_within_tolerance():
    tr = ArrivalTrace(seed=5, rate=0.5, window=4.0)
    n = tr.arrivals(4000.0).size
    # Poisson(0.5 * 4000): sd ~ 45, allow 4 sigma
    assert abs(n - 2000) < 180, n


def test_mmpp_burst_overdispersion():
    quiet = ArrivalTrace(seed=7, rate=0.25, window=8.0)
    burst = ArrivalTrace(seed=7, rate=0.25, window=8.0,
                         burst_rate=4.0, burst_prob=0.25)
    cq = quiet.counts(np.arange(200)).astype(float)
    cb = burst.counts(np.arange(200)).astype(float)
    assert cb.mean() > cq.mean()           # bursts add mass
    # index of dispersion: Poisson ~1, MMPP >> 1
    assert cb.var() / cb.mean() > 2.0 * (cq.var() / cq.mean())


def test_availability_cellwise_independent_and_tolerance():
    av = DiurnalAvailability(seed=9, base=0.7, amp_min=0.1, amp_max=0.3,
                             day=96.0, tick=4.0)
    ids = np.arange(64)
    batch = av.available(ids, 30.0)
    single = np.array([bool(av.available([c], 30.0)[0]) for c in ids])
    assert np.array_equal(batch, single)
    # duty averaged over a full day ~ base (the sinusoid cancels)
    days = np.linspace(0.0, 96.0, 97)
    duty = np.mean([av.duty(np.arange(256), t).mean() for t in days])
    assert abs(duty - 0.7) < 0.03, duty
    # realized availability over a day tracks the duty
    frac = np.mean([av.available(np.arange(256), t).mean()
                    for t in np.arange(0.0, 96.0, 4.0)])
    assert abs(frac - 0.7) < 0.05, frac
    # constant within a tick window
    assert np.array_equal(av.available(ids, 8.0), av.available(ids, 11.9))


def test_speed_profile_stats_and_independence():
    sp = DeviceSpeedProfile(seed=11, class_mults=(1.0, 2.0, 4.0),
                            class_weights=(0.5, 0.35, 0.15), sigma=0.2)
    ids = np.arange(4000)
    cls = sp.speed_class(ids)
    freqs = np.bincount(cls, minlength=3) / ids.size
    assert np.allclose(freqs, [0.5, 0.35, 0.15], atol=0.03), freqs
    m = sp.multiplier(ids)
    assert np.all(m > 0)
    # query order / chunking independence
    perm = np.random.default_rng(0).permutation(ids.size)
    assert np.array_equal(m[perm], sp.multiplier(ids[perm]))
    # lognormal jitter: class-1 medians sit near the class multiplier
    med = np.median(m[cls == 1])
    assert abs(med - 2.0) < 0.2, med


def test_heterogeneous_plan_scales_latency():
    sp = DeviceSpeedProfile(seed=2, class_mults=(1.0, 3.0),
                            class_weights=(0.5, 0.5), sigma=0.0)
    base = FaultPlan(seed=4)                 # inactive: no failure rates
    het = HeterogeneousFaultPlan(seed=4, speed=sp)
    ids = np.arange(32)
    assert not base.active
    assert het.active, "a speed profile must activate the fault path"
    ratio = het.latency(ids, 0) / base.latency(ids, 0)
    assert np.allclose(ratio, sp.multiplier(ids))
    # without a profile the subclass degrades to the parent exactly
    plain = HeterogeneousFaultPlan(seed=4, straggler_frac=0.2)
    ref = FaultPlan(seed=4, straggler_frac=0.2)
    assert plain.active
    assert np.array_equal(plain.latency(ids, 3), ref.latency(ids, 3))


def test_make_workload_regimes():
    for regime in ("light", "saturating", "bursty", "steady", "diurnal"):
        w = make_workload(regime, seed=1)
        assert w.horizon > 0
    assert make_workload("steady").arrivals.arrivals(8.0).size == 0
    assert make_workload("diurnal").availability is not None
    with pytest.raises(ValueError):
        make_workload("nope")


# ---------------------------------------------------------------------------
# RejectedTask: the echo carries everything needed to resubmit
# ---------------------------------------------------------------------------

def test_rejected_task_echo_and_queue_depth():
    pool = _pool()
    provider = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    sched = ServiceScheduler(provider, max_queue=2)
    b = _budget(pool)
    t0 = TaskRequest(budget=b, seed=0)
    t1 = TaskRequest(budget=b, seed=1)
    spill = TaskRequest(budget=b, seed=2)
    assert isinstance(sched.submit(t0, ChunkStub()), int)
    assert isinstance(sched.submit(t1, ChunkStub()), int)
    r = sched.submit(spill, ChunkStub())
    assert isinstance(r, RejectedTask)
    assert r.task is spill, "rejection must echo the submitted request"
    assert r.queued == 2, "queued must report the INTAKE backlog depth"
    # the echo alone suffices to resubmit: drain one sweep, resubmit it
    sched.sweep()
    assert isinstance(sched.submit(r.task, ChunkStub()), int)


def test_driver_requeues_every_rejected_task_to_terminal():
    """Property: under heavy backpressure no task is silently dropped —
    every arrival (including multiply-rejected ones) reaches a terminal
    phase, exactly once."""
    pool = _pool()
    b = _budget(pool)

    def template(i, t):
        return TaskRequest(budget=b, n_star=8, subset_size=8,
                           subset_delta=2, max_periods=2, max_rounds=4,
                           round_chunk=2, seed=i)

    trace = make_workload("saturating", seed=1, template=template,
                          horizon=16.0)
    provider = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    sched = ServiceScheduler(provider, max_inflight=2, max_queue=1)
    drv = OnlineDriver(sched, trace, ChunkStub, backoff=0.5)
    log = drv.run()
    s = log.summary()
    assert s["rejects"] > 0, "the property needs backpressure to fire"
    assert s["tasks_finished"] == s["tasks_submitted"]
    n = s["tasks_submitted"]
    assert sorted(drv.phases) == list(range(n))
    assert all(p in ("DONE", "DEGRADED", "INFEASIBLE")
               for p in drv.phases.values()), drv.phases
    # rejected task indexes are a subset of terminal ones
    rejected = {e.task for e in log.of_kind("reject")}
    assert rejected <= set(drv.phases)
    # monotone virtual clock
    times = [e.time for e in log.events]
    assert all(b >= a for a, b in zip(times, times[1:]))


# ---------------------------------------------------------------------------
# driver: no-trace bit-identity with the offline scheduler
# ---------------------------------------------------------------------------

def test_driver_notrace_identity():
    pool = _pool()
    b = _budget(pool)
    tasks = [TaskRequest(budget=b, n_star=8, subset_size=8, subset_delta=2,
                         max_periods=2, max_rounds=4, round_chunk=2, seed=i)
             for i in range(3)]
    digest = lambda evs: [(e.period, e.round_index, tuple(e.subset),
                           tuple(np.asarray(e.weights).tolist()), e.metrics)
                          for e in evs]

    provider = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    sched = ServiceScheduler(provider, max_inflight=4)
    tids = [sched.submit(TaskRequest(**vars(t)), ChunkStub())
            for t in tasks]
    offline = {tid: [] for tid in tids}
    while sched.active:
        for tid, evs in sched.sweep().items():
            offline[tid].extend(evs)

    provider2 = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    sched2 = ServiceScheduler(provider2, max_inflight=4)
    trace = WorkloadTrace(ArrivalTrace(rate=0.0), template=None,
                          horizon=0.0)
    drv = OnlineDriver(sched2, trace, ChunkStub)
    drv.run(initial_tasks=[TaskRequest(**vars(t)) for t in tasks])
    for i, tid in enumerate(tids):
        assert digest(offline[tid]) == digest(drv.results[i]), i
    assert all(drv.phases[i] == "DONE" for i in range(len(tasks)))


def test_driver_diurnal_and_fault_trace_completes():
    pool = _pool()
    b = _budget(pool)

    def template(i, t):
        return TaskRequest(budget=b, n_star=8, subset_size=8,
                           subset_delta=2, max_periods=2, max_rounds=4,
                           round_chunk=2, seed=i,
                           overschedule_factor=1.5, quorum_frac=0.25,
                           collect_deadline=4.0, max_retries=5)

    trace = make_workload("diurnal", seed=3, template=template,
                          horizon=32.0)
    provider = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    sched = ServiceScheduler(provider, max_inflight=4, max_queue=4)
    drv = OnlineDriver(sched, trace, ChunkStub)
    s = drv.run().summary()
    assert s["tasks_finished"] == s["tasks_submitted"] > 0
    assert s["round_latency_p99"] is not None   # fault path engaged


# ---------------------------------------------------------------------------
# observability columns + the deadline_aware policy
# ---------------------------------------------------------------------------

def _drain_faulty(pool, plan, **task_kw):
    base = dict(budget=_budget(pool), n_star=8, subset_size=8,
                subset_delta=2, max_periods=3, max_rounds=6,
                round_chunk=2, seed=3)
    base.update(task_kw)
    provider = FLServiceProvider(
        ClientPoolState.from_profiles(pool.to_profiles()))
    state = submit(provider, TaskRequest(**base))
    state, events = drain(provider, state, ChunkStub(fault_plan=plan))
    return state, events


def test_lifecycle_publishes_obs_columns():
    pool = _pool()
    plan = FaultPlan(seed=7, straggler_frac=0.3, straggler_slowdown=8.0)
    state, events = _drain_faulty(pool, plan)
    ps = state.policy_state
    for key in ("obs/ids", "obs/timeouts", "obs/rounds", "obs/latency"):
        assert key in ps, key
    assert ps["obs/ids"].size == ps["obs/timeouts"].size \
        == ps["obs/rounds"].size
    assert ps["obs/latency"].size == len(
        [e for e in events if "round_latency" in e.metrics])
    # the window content is the tail of the per-event latencies
    lats = np.array([e.metrics["round_latency"] for e in events])
    assert np.array_equal(ps["obs/latency"], lats[-128:])
    # no-fault runs publish the reputation columns but never latency
    state0, _ = _drain_faulty(pool, None)
    assert "obs/ids" in state0.policy_state
    assert "obs/latency" not in state0.policy_state


def test_obs_columns_survive_checkpoint_roundtrip():
    pool = _pool()
    plan = FaultPlan(seed=7, straggler_frac=0.3, straggler_slowdown=8.0)
    state, _ = _drain_faulty(pool, plan)
    arrays = state.to_arrays()
    restored = TaskState.from_arrays(arrays)
    for key in ("obs/ids", "obs/timeouts", "obs/rounds", "obs/latency"):
        assert np.array_equal(restored.policy_state[key],
                              state.policy_state[key]), key


def test_deadline_aware_demotes_chronic_stragglers():
    rng = np.random.default_rng(0)
    ids = np.arange(12)
    H = np.stack(random_histograms(12, 5, rng))
    task = TaskRequest(budget=0.0, subset_size=4, subset_delta=1, x_star=3)
    slow = np.zeros(12, dtype=np.int64)
    slow[[2, 5, 9]] = 20                     # chronic timeouts
    state = {"obs/ids": ids.copy(), "obs/timeouts": slow,
             "obs/rounds": np.full(12, 10, dtype=np.int64)}
    res = P.scheduling_policy("deadline_aware").schedule(
        ids, H, task, rng, state)
    assert len(res.subsets) == 3
    assert sorted(res.subsets[-1]) == [2, 5, 9] + [res.subsets[-1][-1]] \
        or set([2, 5, 9]) <= set(res.subsets[-1]), res.subsets
    # each client exactly once (partition)
    assert sorted(c for s in res.subsets for c in s) == list(range(12))
    assert all(v == 1 for v in res.counts.values())


def test_deadline_aware_cold_start_orders_by_id():
    rng = np.random.default_rng(0)
    ids = np.arange(9)
    H = np.stack(random_histograms(9, 4, rng))
    task = TaskRequest(budget=0.0, subset_size=3, subset_delta=1, x_star=2)
    res = P.scheduling_policy("deadline_aware").schedule(
        ids, H, task, rng, {})
    assert res.subsets == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]


def test_deadline_aware_tightens_and_relaxes_overschedule():
    rng = np.random.default_rng(0)
    ids = np.arange(8)
    H = np.stack(random_histograms(8, 4, rng))
    pol = P.scheduling_policy("deadline_aware")
    task = TaskRequest(budget=0.0, subset_size=4, collect_deadline=2.0,
                       overschedule_factor=1.5)
    state = {"obs/latency": np.full(16, 1.9)}   # p99 >= 0.8 * deadline
    pol.schedule(ids, H, task, rng, state)
    assert task.overschedule_factor == pytest.approx(1.5 * 1.25)
    assert float(state["deadline_aware/base_os"][0]) == 1.5
    # repeated pressure saturates at the cap
    for _ in range(8):
        pol.schedule(ids, H, task, rng, state)
    assert task.overschedule_factor == pytest.approx(3.0)
    # calm latencies decay the factor back toward the submitted base
    state["obs/latency"] = np.full(16, 0.4)     # p99 < 0.5 * deadline
    for _ in range(8):
        pol.schedule(ids, H, task, rng, state)
    assert task.overschedule_factor == pytest.approx(1.5)
    # no deadline -> the adaptation is inert
    task2 = TaskRequest(budget=0.0, subset_size=4, overschedule_factor=1.0)
    pol.schedule(ids, H, task2, rng,
                 {"obs/latency": np.full(16, 100.0)})
    assert task2.overschedule_factor == 1.0


def test_deadline_aware_end_to_end_beats_default_p99():
    """The acceptance direction at test scale: mitigated deadline_aware
    completes tasks with a better p99 round latency than the default
    policy under the same straggler-heavy plan."""
    pool = _pool()
    plan = HeterogeneousFaultPlan(
        seed=7, straggler_frac=0.25, straggler_slowdown=8.0,
        speed=DeviceSpeedProfile(seed=8))
    _, base_events = _drain_faulty(pool, plan)
    _, mit_events = _drain_faulty(
        pool, plan, scheduling_policy="deadline_aware",
        overschedule_factor=1.5, quorum_frac=0.5, collect_deadline=3.0,
        max_retries=5, retry_backoff=0.5)
    p99 = lambda evs: float(np.percentile(
        [e.metrics["round_latency"] for e in evs], 99))
    assert p99(mit_events) < p99(base_events)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_summary_and_format():
    from repro.core.telemetry import TelemetryLog
    log = TelemetryLog()
    log.record("submit", 0.0, 0, arrival=0.0)
    log.record("reject", 0.0, 0, queued=2, reason="full", attempt=0,
               retry_at=1.0)
    log.record("accept", 1.0, 0, tid=0, attempt=1)

    class _Ev:
        period, round_index, subset = 0, 0, [1, 2]
        metrics = {"round_latency": 2.5}
    log.record_round(3.5, 0, _Ev())
    log.record("done", 3.5, 0, tid=0, phase="DONE", periods=1)
    s = log.summary()
    assert s["tasks_submitted"] == s["tasks_finished"] == 1
    assert s["rejects"] == 1 and s["rounds"] == 1
    assert s["queue_wait_p50"] == 1.0
    assert s["completion_p50"] == 3.5
    assert s["round_latency_p99"] == 2.5
    assert s["degraded_rate"] == 0.0
    assert s["jain_fairness"] == 1.0      # both clients participated once
    assert "p99" in log.format_summary() or "p50" in log.format_summary()
    # empty log: percentiles are None, nothing crashes
    empty = TelemetryLog().summary()
    assert empty["round_latency_p50"] is None
    assert empty["jain_fairness"] == 1.0
