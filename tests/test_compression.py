"""Compressed client-update plane validation (ISSUE-9).

Three layers, each anchored to an oracle:

  1. kernels — interpret-mode Pallas ``topk_sparsify`` /
     ``quantize_i8`` / ``dequantize_i8`` / ``fedavg_agg_quality_i8``
     against their jnp references (ref.py), swept over ragged shapes
     and dtypes. Top-k selection must match ``lax.top_k`` over |x|
     exactly (ties to the lowest index); int8 values may differ by at
     most one quantization step from the oracle (the kernel's chunk-max
     reduction can land 1 ulp off the oracle's, which legitimately
     moves a value on a rounding boundary).
  2. codec — spec grammar, wire-byte accounting, round-trip error
     bounds (int8 error <= scale/2 per chunk; top-k exact on kept
     coordinates and zero elsewhere), quantize∘dequantize idempotence.
  3. round plane — ``compression="none"`` is bit-identical to the
     uncompressed scan, and a mid-period save→kill→restore with an
     active codec reproduces the remaining rounds exactly.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lifecycle
from repro.core.service import FLServiceProvider
from repro.fl.compression import (CompressionSpec, aggregate_compressed,
                                  bytes_per_client, compress, decompress,
                                  roundtrip)
from repro.kernels import ops, ref
from repro.kernels.compression import (fedavg_agg_quality_i8, quantize_i8,
                                       dequantize_i8, topk_sparsify)

SHAPES = [(13, 1000), (3, 130), (8, 50), (1, 7), (5, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


def rk(i):
    return jax.random.PRNGKey(i)


def scale_bound(x, chunk):
    """Per-element dequantization error bound: half an int8 step of the
    element's chunk scale (plus float slack)."""
    _, scales = ref.quantize_i8_ref(x.astype(jnp.float32), chunk)
    per_elem = jnp.repeat(scales, chunk, axis=1)[:, : x.shape[1]]
    return np.asarray(per_elem) * 0.5 * (1 + 1e-5) + 1e-8


# ---------------------------------------------------------------------------
# 1. kernels vs oracles
# ---------------------------------------------------------------------------

class TestTopkSparsifyKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("K,P", SHAPES)
    def test_matches_lax_topk_exactly(self, K, P, dtype):
        x = jax.random.normal(rk(0), (K, P), dtype)
        k = max(1, P // 10)
        vals, idx = topk_sparsify(x, k, interpret=True)
        rvals, ridx = ref.topk_sparsify_ref(x, k)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))

    def test_tie_break_is_lowest_index(self):
        # constant-|x| rows: selection must be the first k lanes, in
        # order, with the original signs — deterministic across runs
        x = jnp.array([[1.0, -1.0, 1.0, -1.0, 1.0, -1.0]])
        for k in (1, 3, 6):
            vals, idx = topk_sparsify(x, k, interpret=True)
            np.testing.assert_array_equal(np.asarray(idx[0]), np.arange(k))
            np.testing.assert_array_equal(np.asarray(vals),
                                          np.asarray(x[:, :k]))
            rvals, ridx = ref.topk_sparsify_ref(x, k)
            np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))

    def test_k_clamps_to_row_width(self):
        x = jax.random.normal(rk(1), (2, 5))
        vals, idx = topk_sparsify(x, 9, interpret=True)
        assert vals.shape == (2, 5)
        # every column selected exactly once
        assert sorted(np.asarray(idx[0]).tolist()) == list(range(5))

    def test_signed_values_kept(self):
        x = jnp.array([[-3.0, 1.0, 2.0, -0.5]])
        vals, idx = topk_sparsify(x, 2, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx[0]), [0, 2])
        np.testing.assert_array_equal(np.asarray(vals[0]), [-3.0, 2.0])


class TestQuantizeI8Kernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("K,P", SHAPES)
    @pytest.mark.parametrize("chunk", [64, 256])
    def test_matches_oracle_within_one_step(self, K, P, chunk, dtype):
        x = jax.random.normal(rk(2), (K, P), dtype)
        v, s = quantize_i8(x, chunk=chunk, interpret=True)
        rv, rs = ref.quantize_i8_ref(x, chunk)
        assert v.dtype == jnp.int8 and v.shape == (K, P)
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=2e-7)
        # the chunk-max reduction may differ by 1 ulp between kernel
        # and oracle, which can move a value across a rounding
        # boundary: one int8 step is the contract
        diff = np.abs(np.asarray(v, np.int32) - np.asarray(rv, np.int32))
        assert diff.max() <= 1

    @pytest.mark.parametrize("K,P", [(3, 130), (5, 257)])
    def test_dequantize_matches_oracle(self, K, P):
        x = jax.random.normal(rk(3), (K, P))
        v, s = ref.quantize_i8_ref(x, 64)      # shared payload
        d = dequantize_i8(v, s, chunk=64, interpret=True)
        rd = ref.dequantize_i8_ref(v, s, 64)
        np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=2e-7)

    def test_zero_chunks_are_exact(self):
        x = jnp.zeros((2, 100))
        v, s = quantize_i8(x, chunk=32, interpret=True)
        assert np.asarray(v).max() == 0 and np.asarray(s).max() == 0.0
        d = dequantize_i8(v, s, chunk=32, interpret=True)
        assert np.asarray(d).max() == 0.0

    def test_extremes_saturate_at_127(self):
        x = jnp.array([[127.0, -127.0, 63.5, 0.0]])
        v, s = quantize_i8(x, chunk=4, interpret=True)
        np.testing.assert_array_equal(np.asarray(v[0]), [127, -127, 64, 0])
        assert float(s[0, 0]) == pytest.approx(1.0)


class TestAggQualityI8Kernel:
    @pytest.mark.parametrize("K,P", [(13, 1000), (3, 130), (8, 50)])
    @pytest.mark.parametrize("chunk", [64, 256])
    def test_matches_oracle(self, K, P, chunk):
        x = jax.random.normal(rk(4), (K, P))
        w = jax.nn.softmax(jax.random.normal(rk(5), (K,)))
        v, s = ref.quantize_i8_ref(x, chunk)   # shared payload
        out = fedavg_agg_quality_i8(v, s, w, chunk=chunk, interpret=True)
        expect = ref.fedavg_agg_quality_i8_ref(v, s, w, chunk)
        for got, want in zip(out, expect):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_equals_uncompressed_quality_on_decoded(self):
        # the fused kernel must agree with dequantize -> the existing
        # fedavg_agg_quality oracle (same decoded updates)
        K, P = 6, 200
        x = jax.random.normal(rk(6), (K, P))
        w = jnp.full((K,), 1.0 / K)
        v, s = ref.quantize_i8_ref(x, 64)
        u = ref.dequantize_i8_ref(v, s, 64)
        agg, dots, sq, asq = fedavg_agg_quality_i8(v, s, w, chunk=64,
                                                   interpret=True)
        ragg, rdots, rsq, rasq = ref.fedavg_agg_quality_ref(u, w)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(ragg),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dots), np.asarray(rdots),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(sq), np.asarray(rsq),
                                   rtol=1e-4)
        np.testing.assert_allclose(float(asq), float(rasq), rtol=1e-4)

    def test_dispatch_layer_routes_to_oracle_on_cpu(self):
        # interpret=None on CPU must take the jnp reference path and
        # agree with the interpret-mode kernel
        K, P = 4, 90
        x = jax.random.normal(rk(7), (K, P))
        w = jnp.full((K,), 0.25)
        v, s = ops.quantize_i8(x, chunk=32)            # oracle route
        vi, si = quantize_i8(x, chunk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(s), np.asarray(si), rtol=2e-7)
        out = ops.fedavg_agg_quality_i8(v, s, w, chunk=32)
        ki = fedavg_agg_quality_i8(v, s, w, chunk=32, interpret=True)
        for a, b in zip(out, ki):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 2. codec layer
# ---------------------------------------------------------------------------

class TestSpecGrammar:
    @pytest.mark.parametrize("text,kind,frac,chunk", [
        (None, "none", 0.0, 256),
        ("", "none", 0.0, 256),
        ("none", "none", 0.0, 256),
        ("int8", "int8", 0.0, 256),
        ("int8@chunk=64", "int8", 0.0, 64),
        ("topk:0.1", "topk", 0.1, 256),
        ("topk:0.05+int8", "topk_int8", 0.05, 256),
        ("topk:0.05+int8@chunk=128", "topk_int8", 0.05, 128),
    ])
    def test_parse(self, text, kind, frac, chunk):
        spec = CompressionSpec.parse(text)
        assert (spec.kind, spec.topk_frac, spec.chunk) == (kind, frac, chunk)
        # describe() round-trips through parse()
        assert CompressionSpec.parse(spec.describe()) == spec

    @pytest.mark.parametrize("bad", ["gzip", "topk:0", "topk:1.5",
                                     "int8@block=4", "int8@chunk=0"])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            CompressionSpec.parse(bad)
        with pytest.raises(TypeError):
            CompressionSpec.parse(123)

    def test_bytes_accounting(self):
        p = 1000
        assert bytes_per_client(CompressionSpec.parse(None), p) == 4 * p
        assert bytes_per_client(CompressionSpec.parse("int8"), p) == \
            p + 4 * 4                                     # 4 chunks of 256
        assert bytes_per_client(CompressionSpec.parse("topk:0.1"), p) == \
            8 * 100                                       # f32 + i32 per kept
        spec = CompressionSpec.parse("topk:0.05+int8")
        assert bytes_per_client(spec, p) == 50 + 4 * 1 + 4 * 50
        # the ratios the bench asserts: >= 8x for the quantized-sparse
        assert 4 * p / bytes_per_client(spec, p) > 8

    def test_k_for_clamps(self):
        spec = CompressionSpec.parse("topk:0.1")
        assert spec.k_for(1000) == 100
        assert spec.k_for(5) == 1
        assert spec.k_for(0) == 0 or spec.k_for(1) == 1


class TestRoundtripBounds:
    @pytest.mark.parametrize("K,P", [(4, 357), (2, 64), (3, 1000)])
    def test_int8_error_bounded_by_half_step(self, K, P):
        x = jax.random.normal(rk(8), (K, P))
        y = roundtrip(x, CompressionSpec.parse("int8@chunk=64"))
        err = np.abs(np.asarray(x) - np.asarray(y))
        assert (err <= scale_bound(x, 64)).all()

    def test_topk_exact_on_kept_zero_elsewhere(self):
        K, P = 3, 200
        x = jax.random.normal(rk(9), (K, P))
        spec = CompressionSpec.parse("topk:0.1")
        payload = compress(x, spec)
        y = np.asarray(decompress(payload, spec, P))
        idx = np.asarray(payload["indices"])
        for r in range(K):
            kept = idx[r]
            np.testing.assert_array_equal(y[r, kept],
                                          np.asarray(x)[r, kept])
            mask = np.ones(P, bool)
            mask[kept] = False
            assert (y[r, mask] == 0).all()

    def test_quantize_dequantize_idempotent(self):
        # q(deq(q(x))) == q(x): a dequantized payload re-encodes to
        # itself (the grid values are fixed points of the codec)
        x = jax.random.normal(rk(10), (4, 300))
        v1, s1 = ops.quantize_i8(x, chunk=64)
        d1 = ops.dequantize_i8(v1, s1, chunk=64)
        v2, s2 = ops.quantize_i8(d1, chunk=64)
        d2 = ops.dequantize_i8(v2, s2, chunk=64)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("text", ["int8", "topk:0.25", "topk:0.25+int8"])
    def test_aggregate_compressed_matches_decoded_oracle(self, text):
        K, P = 5, 260
        spec = CompressionSpec.parse(text)
        x = jax.random.normal(rk(11), (K, P))
        w = jax.nn.softmax(jax.random.normal(rk(12), (K,)))
        agg, dots, sq, asq = aggregate_compressed(x, w, spec)
        decoded = roundtrip(x, spec)
        ragg, rdots, rsq, rasq = ref.fedavg_agg_quality_ref(decoded, w)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(ragg),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dots), np.asarray(rdots),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(asq), float(rasq), rtol=1e-4)


# ---------------------------------------------------------------------------
# 3. round plane: bit-identity and compressed resume
# ---------------------------------------------------------------------------

def _bundle(compression=None, server_opt=None, seed=0):
    from repro.fl.transformer_task import make_transformer_fl
    return make_transformer_fl(n_clients=10, n_train=100, n_test=30,
                               seq_len=8, seed=seed, compression=compression,
                               server_opt=server_opt)


def _task(compression=None, max_rounds=4, round_chunk=2):
    return lifecycle.TaskRequest(budget=200.0, subset_size=4, subset_delta=2,
                                 x_star=2, max_periods=3,
                                 max_rounds=max_rounds,
                                 round_chunk=round_chunk, seed=0,
                                 compression=compression)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


class TestRoundPlane:
    def test_none_is_bit_identical(self):
        # compression="none" must produce the exact trace of the
        # uncompressed scan — same jaxpr path, same bits out
        runs = {}
        for comp in (None, "none"):
            b = _bundle(compression=comp)
            sp = FLServiceProvider(b["pool"])
            st = lifecycle.submit(sp, _task(compression=comp))
            st, ev = lifecycle.drain(sp, st, b["trainer"])
            runs[comp] = (_leaves(b["trainer"].params), ev)
        for a, b in zip(runs[None][0], runs["none"][0]):
            np.testing.assert_array_equal(a, b)
        assert [e.subset for e in runs[None][1]] == \
            [e.subset for e in runs["none"][1]]
        # no codec -> no bytes column in the round metrics
        assert all("bytes" not in e.metrics for e in runs[None][1])

    def test_bytes_metric_matches_accounting(self):
        comp = "topk:0.25+int8"
        b = _bundle(compression=comp)
        sp = FLServiceProvider(b["pool"])
        st = lifecycle.submit(sp, _task(compression=comp))
        st, ev = lifecycle.drain(sp, st, b["trainer"])
        spec = CompressionSpec.parse(comp)
        flat_p = sum(int(np.prod(np.shape(x)))
                     for x in jax.tree_util.tree_leaves(b["trainer"].params))
        per_client = bytes_per_client(spec, flat_p)
        hist = [h for h in b["trainer"].history if "bytes" in h]
        assert hist, "compressed rounds must report a bytes column"
        for h in hist:
            n_arrived = h.get("arrived", None)
            assert h["bytes"] % per_client == 0
            assert h["bytes"] > 0

    @pytest.mark.parametrize("comp", ["int8", "topk:0.25+int8"])
    def test_compressed_resume_reproduces_rounds(self, comp, tmp_path):
        # reference: straight-through run
        b1 = _bundle(compression=comp)
        p1 = FLServiceProvider(b1["pool"])
        s1 = lifecycle.submit(p1, _task(compression=comp, max_rounds=6,
                                        round_chunk=1))
        s1, ref_ev = lifecycle.drain(p1, s1, b1["trainer"])

        # run 2: stop after 3 rounds, checkpoint with trainer state
        b2 = _bundle(compression=comp)
        p2 = FLServiceProvider(b2["pool"])
        s2 = lifecycle.submit(p2, _task(compression=comp, max_rounds=6,
                                        round_chunk=1))
        got = []
        while len(got) < 3:
            s2, ev = lifecycle.step(p2, s2, b2["trainer"])
            got.extend(ev)
        path = os.path.join(tmp_path, "mid.ckpt")
        got += lifecycle.save_state(path, s2, flush=True,
                                    trainer=b2["trainer"])

        # "fresh process": new trainer, restored control + model state
        s3 = lifecycle.load_state(path)
        assert s3.task.compression == comp
        b3 = _bundle(compression=comp)
        assert lifecycle.restore_trainer_state(s3, b3["trainer"])
        p3 = FLServiceProvider(b3["pool"])
        s3, post = lifecycle.drain(p3, s3, b3["trainer"])

        rounds = got + post
        assert len(rounds) == len(ref_ev)
        for a, b in zip(rounds, ref_ev):
            assert (a.period, a.round_index, a.subset) == \
                (b.period, b.round_index, b.subset)
            assert a.nid == b.nid
        for x, y in zip(_leaves(b1["trainer"].params),
                        _leaves(b3["trainer"].params)):
            np.testing.assert_array_equal(x, y)

    def test_server_opt_state_rides_checkpoint(self, tmp_path):
        b = _bundle(compression="int8", server_opt="fedyogi")
        sp = FLServiceProvider(b["pool"])
        st = lifecycle.submit(sp, _task(compression="int8"))
        st, _ = lifecycle.drain(sp, st, b["trainer"])
        path = os.path.join(tmp_path, "opt.ckpt")
        lifecycle.save_state(path, st, trainer=b["trainer"])
        back = lifecycle.load_state(path)
        b2 = _bundle(compression="int8", server_opt="fedyogi")
        assert lifecycle.restore_trainer_state(back, b2["trainer"])
        for x, y in zip(_leaves(b["trainer"].opt_state),
                        _leaves(b2["trainer"].opt_state)):
            np.testing.assert_array_equal(x, y)
