"""ISSUE-3 resumable service API: submit/step/drain lifecycle vs the
legacy blocking loop (bit-for-bit), checkpoint/resume, multi-tenant
ServiceScheduler, client churn, and the satellite fixes (registry
invalidation, positions KeyError, select_pools_batch edges, run_task
deprecation, struct-of-arrays reputation)."""
import os
import warnings

import numpy as np
import pytest

from repro.core import (AsyncTrainer, FLServiceProvider, InFlightError,
                        ReputationTracker, ServiceScheduler, TaskPhase,
                        TaskRequest, TaskState, Trainer, apply_pool_selection,
                        as_run_result, collect, dispatch, drain, load_state,
                        random_profiles, resolve_trainer, save_state,
                        single_round_adapter, step, submit)
from repro.core.pool import ClientPoolState


# ---------------------------------------------------------------------------
# deterministic stub trainers (stateless -> resumable)
# ---------------------------------------------------------------------------

def _round_result(rnd, subset, fail_mod=7):
    subset = np.asarray(subset)
    returned = (subset + rnd) % fail_mod != 0
    q = np.where(returned, 0.5 + 0.4 * np.cos(subset + rnd), 0.0)
    return returned, q, {"round": rnd, "loss": 1.0 / (rnd + 1)}


def _stub(rnd, subset, weights):
    return _round_result(rnd, subset)


class ChunkStub:
    """Chunk-capable deterministic Trainer (protocol implementation;
    also callable per-round, like DeviceFLSim, so the legacy reference
    loop can drive it at chunk size 1)."""

    def run_rounds(self, start_round, subsets, weights):
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]

    def __call__(self, rnd, subset, weights):
        return self.run_rounds(rnd, [subset], [weights])[0]


class AsyncChunkStub:
    """Deterministic ``AsyncTrainer``: ``dispatch_rounds`` returns a lazy
    handle (nothing computed), ``collect`` materializes. A shared
    ``recorder`` dict tracks how many handles are outstanding across all
    trainer instances (the scheduler's in-flight window)."""

    chunkable = True

    def __init__(self, recorder: dict | None = None):
        self.recorder = recorder if recorder is not None else {
            "inflight": 0, "max_inflight": 0}

    def dispatch_rounds(self, start_round, subsets, weights):
        r = self.recorder
        r["inflight"] += 1
        r["max_inflight"] = max(r["max_inflight"], r["inflight"])
        return (start_round, [list(s) for s in subsets])

    def collect(self, handle):
        self.recorder["inflight"] -= 1
        start_round, subsets = handle
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]

    def run_rounds(self, start_round, subsets, weights):
        return self.collect(self.dispatch_rounds(start_round, subsets,
                                                 weights))


def _profiles(n=60, seed=0):
    return random_profiles(n, 10, np.random.default_rng(seed))


def _assert_results_equal(a, b, *, order_insensitive_pool=False):
    if order_insensitive_pool:
        assert sorted(a.pool.selected) == sorted(b.pool.selected)
        assert a.pool.total_score == pytest.approx(b.pool.total_score)
        assert a.pool.total_cost == pytest.approx(b.pool.total_cost)
    else:
        assert a.pool.selected == b.pool.selected
        assert a.pool.total_score == b.pool.total_score
        assert a.pool.total_cost == b.pool.total_cost
    assert a.pool.feasible == b.pool.feasible
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        assert (ra.period, ra.round_index) == (rb.period, rb.round_index)
        assert ra.subset == rb.subset
        np.testing.assert_array_equal(ra.weights, rb.weights)
        assert ra.nid == rb.nid
    assert [s.subsets for s in a.schedules] == [s.subsets for s in b.schedules]
    assert [s.nids for s in a.schedules] == [s.nids for s in b.schedules]
    assert a.reputation == b.reputation        # bit-for-bit values


# ---------------------------------------------------------------------------
# Equivalence: run_task shim (submit/step/drain) vs the legacy loop
# ---------------------------------------------------------------------------

class TestShimEquivalence:
    @pytest.mark.parametrize("scheduler", ["mkp", "random"])
    @pytest.mark.parametrize("chunked,round_chunk",
                             [(False, 1), (True, 1), (True, 3)])
    @pytest.mark.parametrize("max_rounds", [None, 7])
    @pytest.mark.parametrize("stop_at", [None, 5])
    def test_matrix(self, scheduler, chunked, round_chunk, max_rounds,
                    stop_at):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, scheduler=scheduler,
                           max_rounds=max_rounds, round_chunk=round_chunk,
                           seed=3)
        trainer = ChunkStub() if chunked else _stub
        stop_fn = (lambda m: m["round"] >= stop_at) if stop_at else None
        legacy = sp.run_task_legacy(task, trainer, stop_fn=stop_fn)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            shim = sp.run_task(task, trainer, stop_fn=stop_fn)
        _assert_results_equal(legacy, shim)

    def test_availability_fn(self):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3)
        gone = set(list(sp.registry)[:5])
        fn = lambda cid, period: cid not in gone
        legacy = sp.run_task_legacy(task, _stub, availability_fn=fn)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            shim = sp.run_task(task, _stub, availability_fn=fn)
        _assert_results_equal(legacy, shim)

    def test_random_stage1_method_threads_rng(self):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=300.0, n_star=5, subset_size=5,
                           subset_delta=2, max_periods=2, scheduler="random",
                           seed=11)
        legacy = sp.run_task_legacy(task, _stub, method="random")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            shim = sp.run_task(task, _stub, method="random")
        _assert_results_equal(legacy, shim)

    def test_infeasible(self):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=1.0, n_star=50)
        state = submit(sp, task)
        assert state.phase == TaskPhase.INFEASIBLE
        state, events = drain(sp, state, _stub)
        assert events == [] and state.phase == TaskPhase.INFEASIBLE
        res = as_run_result(state)
        assert not res.pool.feasible and res.num_rounds == 0 \
            and res.reputation == {}

    def test_step_emits_events_only_while_training(self):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=1)
        state = submit(sp, task)
        phases = [state.phase]
        while not state.phase.terminal:
            state, ev = step(sp, state, _stub)
            phases.append(state.phase)
            if ev:
                assert state.phase in (TaskPhase.TRAINING,
                                       TaskPhase.PERIOD_CHECKPOINT)
        assert phases[0] == TaskPhase.POOL_SELECTED
        assert TaskPhase.SCHEDULED in phases
        assert TaskPhase.PERIOD_CHECKPOINT in phases
        assert phases[-1] == TaskPhase.DONE


# ---------------------------------------------------------------------------
# Trainer protocol
# ---------------------------------------------------------------------------

class TestTrainerProtocol:
    def test_chunkstub_is_trainer(self):
        assert isinstance(ChunkStub(), Trainer)
        assert resolve_trainer(ChunkStub()) .__class__ is ChunkStub

    def test_callable_wrapped(self):
        t = resolve_trainer(_stub)
        assert isinstance(t, single_round_adapter)
        assert t.chunkable is False
        out = t.run_rounds(4, [[1, 2], [3, 4]], [np.ones(2), np.ones(2)])
        ref = [_round_result(4, [1, 2]), _round_result(5, [3, 4])]
        for (ra, qa, ma), (rb, qb, mb) in zip(out, ref):
            np.testing.assert_array_equal(ra, rb)
            np.testing.assert_array_equal(qa, qb)
            assert ma == mb

    def test_non_trainer_rejected(self):
        with pytest.raises(TypeError):
            resolve_trainer(object())


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------

class TestCheckpointResume:
    def _reference(self, profiles, task):
        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        state, events = drain(sp, state, ChunkStub())
        return events, as_run_result(state).reputation

    # ISSUE-5: the save->kill->restore matrix carries the policy axis —
    # stochastic selection (rng state), the stateful fair_ema scheduler
    # (policy_state arrays) and the legacy scheduler alias must all
    # resume with identical remaining rounds
    @pytest.mark.parametrize("scheduler,selection,scheduling", [
        ("mkp", None, None),                   # the defaults
        ("random", None, None),                # legacy alias path
        ("mkp", "score_prop", "fair_ema"),
        ("mkp", "random", "random_partition"),
        ("mkp", "dp", "fair_ema"),
    ])
    def test_resume_mid_period(self, tmp_path, scheduler, selection,
                               scheduling):
        profiles = _profiles()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=4, scheduler=scheduler,
                           round_chunk=2, seed=3,
                           selection_policy=selection,
                           scheduling_policy=scheduling)
        ref_events, ref_rep = self._reference(profiles, task)

        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        pre = []
        # step into the middle of period 1 (TRAINING with a chunk done)
        while not (state.phase == TaskPhase.TRAINING and state.period == 1
                   and state.subset_index >= 1):
            state, ev = step(sp, state, ChunkStub())
            pre.extend(ev)
            assert not state.phase.terminal
        path = os.path.join(tmp_path, "task.ckpt")
        save_state(path, state)

        restored = load_state(path)            # "fresh process"
        assert restored.phase == state.phase
        assert restored.pool == state.pool
        assert restored.subset_index == state.subset_index
        sp2 = FLServiceProvider(profiles)      # fresh provider
        restored, post = drain(sp2, restored, ChunkStub())
        got = pre + post
        assert len(got) == len(ref_events)
        for a, b in zip(got, ref_events):
            assert (a.period, a.round_index, a.subset) == \
                (b.period, b.round_index, b.subset)
            np.testing.assert_array_equal(a.weights, b.weights)
            assert a.nid == b.nid
        assert as_run_result(restored).reputation == ref_rep

    def test_resume_at_period_checkpoint(self, tmp_path):
        profiles = _profiles()
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=5)
        ref_events, ref_rep = self._reference(profiles, task)

        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        pre = []
        while state.phase != TaskPhase.PERIOD_CHECKPOINT:
            state, ev = step(sp, state, ChunkStub())
            pre.extend(ev)
        path = os.path.join(tmp_path, "ckpt.ckpt")
        save_state(path, state)
        restored = load_state(path)
        sp2 = FLServiceProvider(profiles)
        restored, post = drain(sp2, restored, ChunkStub())
        assert [(e.period, e.round_index, e.subset) for e in pre + post] == \
            [(e.period, e.round_index, e.subset) for e in ref_events]
        assert as_run_result(restored).reputation == ref_rep

    def test_taskstate_array_roundtrip(self):
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, max_rounds=9,
                           thresholds=np.full(9, 0.02), round_chunk=2,
                           scheduler="random", seed=7)
        state = submit(sp, task)
        state, _ = step(sp, state, _stub)      # generate a schedule
        state, _ = step(sp, state, _stub)      # train one chunk
        back = TaskState.from_arrays(state.to_arrays())
        assert back.phase == state.phase
        assert back.pool == state.pool
        assert back.global_round == state.global_round
        assert back.task.max_rounds == task.max_rounds
        assert back.task.scheduler == task.scheduler
        np.testing.assert_array_equal(back.task.thresholds, task.thresholds)
        assert back.schedule.subsets == state.schedule.subsets
        assert back.tracker.scores() == state.tracker.scores()
        # rng stream continues identically
        np.testing.assert_array_equal(back.rng.random(8), state.rng.random(8))

    def test_large_seed_roundtrips_exactly(self):
        # seeds are integers, not float64: 2**60 + 1 must survive
        task = TaskRequest(budget=100.0, seed=2**60 + 1, max_rounds=2**55)
        state = TaskState(task=task)
        back = TaskState.from_arrays(state.to_arrays())
        assert back.task.seed == 2**60 + 1
        assert back.task.max_rounds == 2**55

    def test_policy_names_and_state_roundtrip(self):
        # ISSUE-5: policy names + policy_state cursor arrays survive
        # to_arrays/from_arrays exactly (the fair_ema EMAs are float64
        # and must not narrow)
        sp = FLServiceProvider(_profiles())
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=7,
                           selection_policy="score_prop",
                           scheduling_policy="fair_ema")
        state = submit(sp, task)
        state, _ = step(sp, state, _stub)      # draws a fair_ema schedule
        assert state.policy_state              # the EMA cursors exist
        back = TaskState.from_arrays(state.to_arrays())
        assert back.task.selection_policy == "score_prop"
        assert back.task.scheduling_policy == "fair_ema"
        assert set(back.policy_state) == set(state.policy_state)
        for k, v in state.policy_state.items():
            assert back.policy_state[k].dtype == np.asarray(v).dtype
            np.testing.assert_array_equal(back.policy_state[k], v)

    def test_format1_payload_still_restores(self):
        # a pre-ISSUE-5 checkpoint (format 1: no policy keys) restores
        # with the default policies and an empty policy_state
        state = TaskState(task=TaskRequest(budget=100.0, seed=5))
        arrays = state.to_arrays()
        arrays["format"] = np.array([1], dtype=np.int64)
        del arrays["task/selection_policy"]
        del arrays["task/scheduling_policy"]
        back = TaskState.from_arrays(arrays)
        assert back.task.selection_policy is None      # unset: resolves
        assert back.task.scheduling_policy is None     # to the defaults
        from repro.core import (resolve_scheduling_policy,
                                resolve_selection_policy)
        assert resolve_selection_policy(back.task).name == "paper_greedy"
        assert resolve_scheduling_policy(back.task).name == "iid_subsets"
        assert back.policy_state == {}
        # pre-format-4 payloads also default the ISSUE-9 fields
        assert back.task.compression is None
        assert back.trainer_state == {}

    def test_format3_payload_still_restores(self):
        # a pre-ISSUE-9 checkpoint (format 3: no compression /
        # trainer_state keys) restores with those fields defaulted
        state = TaskState(task=TaskRequest(budget=100.0, seed=5,
                                           compression="int8"))
        state.trainer_state = {"params/w": np.ones(3, np.float32)}
        arrays = state.to_arrays()
        arrays["format"] = np.array([3], dtype=np.int64)
        del arrays["task/compression"]
        arrays = {k: v for k, v in arrays.items()
                  if not k.startswith("trn/")}
        back = TaskState.from_arrays(arrays)
        assert back.task.compression is None
        assert back.trainer_state == {}
        # fmt-3 fields still round-tripped
        assert back.task.seed == 5

    def test_format4_roundtrip_with_trainer_state(self):
        # format 4 carries the codec spec and the trainer's exported
        # server-state arrays exactly (dtypes and values)
        task = TaskRequest(budget=100.0, seed=9,
                           compression="topk:0.05+int8@chunk=128")
        state = TaskState(task=task)
        state.trainer_state = {
            "params/layers/attn/wq/a": np.arange(6, dtype=np.float32),
            "opt/m/count": np.array(3, dtype=np.int32),
            "opt/v/x": np.linspace(0, 1, 4).astype(np.float64),
        }
        arrays = state.to_arrays()
        assert int(arrays["format"][0]) == 4
        back = TaskState.from_arrays(arrays)
        assert back.task.compression == task.compression
        assert set(back.trainer_state) == set(state.trainer_state)
        for k, v in state.trainer_state.items():
            assert back.trainer_state[k].dtype == v.dtype, k
            np.testing.assert_array_equal(back.trainer_state[k], v)

    def test_attach_and_restore_trainer_state_hooks(self):
        from repro.core.lifecycle import (attach_trainer_state,
                                          restore_trainer_state)

        class Exporter:
            def export_state(self):
                return {"params/w": np.full(2, 7.0, np.float32)}

            def import_state(self, arrays):
                self.got = arrays

        state = TaskState(task=TaskRequest(budget=1.0))
        attach_trainer_state(state, Exporter())
        assert "params/w" in state.trainer_state
        back = TaskState.from_arrays(state.to_arrays())
        sink = Exporter()
        assert restore_trainer_state(back, sink)
        np.testing.assert_array_equal(sink.got["params/w"],
                                      np.full(2, 7.0, np.float32))
        # hook-less trainers are a no-op on attach, empty on restore
        empty = TaskState(task=TaskRequest(budget=1.0))
        attach_trainer_state(empty, object())
        assert empty.trainer_state == {}
        assert not restore_trainer_state(empty, sink)


class TestFaultResume:
    """ISSUE-7 extension of the resume matrix: tasks checkpointed in the
    fault-mode retry/backoff and DEGRADED states restore with identical
    remaining-round results (the fresh-draw retry subsets come from the
    checkpointed rng)."""

    class FaultyStub(ChunkStub):
        accepts_arrivals = True

        def __init__(self, fault_plan=None):
            self.fault_plan = fault_plan

        def run_rounds(self, start_round, subsets, weights,
                       arrivals=None):
            return super().run_rounds(start_round, subsets, weights)

    # harsh-but-survivable and unsurvivable fault loads; stop_phase is
    # where the checkpoint is taken
    # arrivals are a fixed per-(client, round) property, so retries of
    # one round resample a finite pool — the recoverable case needs a
    # quorum the pool can actually supply plus enough retry headroom
    @pytest.mark.parametrize("crash,quorum,expect_terminal", [
        (0.3, 0.3, TaskPhase.DONE),        # retries, then recovers
        (1.0, 0.5, TaskPhase.DEGRADED),    # quorum never met
    ])
    def test_resume_fault_states(self, tmp_path, crash, quorum,
                                 expect_terminal):
        from repro.core import FaultPlan
        plan = FaultPlan(seed=4, straggler_frac=0.5,
                         straggler_slowdown=8.0, crash_prob=crash)
        task = TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=3,
                           overschedule_factor=1.5, quorum_frac=quorum,
                           collect_deadline=1.5, max_retries=10,
                           retry_backoff=0.5)
        profiles = _profiles()
        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        trainer = self.FaultyStub(fault_plan=plan)
        pre = []
        # step until mid-backoff (first quorum miss) or terminal
        for _ in range(500):
            if state.phase.terminal or state.retry_count > 0:
                break
            state, ev = step(sp, state, trainer)
            pre.extend(ev)
        assert state.retry_count > 0 or state.phase.terminal
        path = os.path.join(tmp_path, "fault.ckpt")
        save_state(path, state)
        restored = load_state(path)
        assert restored.retry_count == state.retry_count
        assert restored.retry_latency == state.retry_latency
        assert restored.phase == state.phase
        sp2 = FLServiceProvider(profiles)
        state, post_a = drain(sp, state, trainer)
        restored, post_b = drain(sp2, restored,
                                 self.FaultyStub(fault_plan=plan))
        assert state.phase == expect_terminal
        assert restored.phase == expect_terminal
        assert [(e.period, e.round_index, e.subset) for e in post_a] == \
            [(e.period, e.round_index, e.subset) for e in post_b]
        for a, b in zip(post_a, post_b):
            assert a.metrics == b.metrics
        assert as_run_result(state).reputation == \
            as_run_result(restored).reputation


# ---------------------------------------------------------------------------
# ISSUE-4: the dispatch/collect split of the TRAINING transition
# ---------------------------------------------------------------------------

class TestDispatchCollect:
    def _task(self, **kw):
        kw.setdefault("budget", 400.0)
        kw.setdefault("n_star", 10)
        kw.setdefault("subset_size", 5)
        kw.setdefault("subset_delta", 2)
        kw.setdefault("max_periods", 3)
        kw.setdefault("seed", 3)
        return TaskRequest(**kw)

    def test_async_stub_is_async_trainer(self):
        assert isinstance(AsyncChunkStub(), AsyncTrainer)
        assert isinstance(AsyncChunkStub(), Trainer)
        assert not isinstance(ChunkStub(), AsyncTrainer)   # sync fallback

    @pytest.mark.parametrize("trainer_cls", [ChunkStub, AsyncChunkStub])
    def test_dispatch_collect_equals_step(self, trainer_cls):
        profiles = _profiles()
        task = self._task(round_chunk=2)
        ref_sp = FLServiceProvider(profiles)
        ref = submit(ref_sp, task)
        ref, ref_events = drain(ref_sp, ref, trainer_cls())

        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        trainer = trainer_cls()
        events = []
        while not state.phase.terminal:
            if state.phase in (TaskPhase.SCHEDULED, TaskPhase.TRAINING):
                state = dispatch(sp, state, trainer)
                state, ev = collect(state)
                events.extend(ev)
            else:
                state, ev = step(sp, state, trainer)
                events.extend(ev)
        assert [(e.period, e.round_index, e.subset) for e in events] == \
            [(e.period, e.round_index, e.subset) for e in ref_events]
        assert as_run_result(state).reputation == \
            as_run_result(ref).reputation

    def test_dispatch_is_lazy_for_async_trainers(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, AsyncChunkStub())   # schedule the period
        rec = {"inflight": 0, "max_inflight": 0}
        trainer = AsyncChunkStub(rec)
        state = dispatch(sp, state, trainer)
        assert state.pending is not None and not state.pending.sync
        assert rec["inflight"] == 1                    # enqueued, not run
        assert state.rounds == []                      # nothing settled yet
        state, ev = collect(state)
        assert rec["inflight"] == 0 and len(ev) >= 1
        assert state.pending is None

    def test_sync_trainer_dispatch_runs_eagerly(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, ChunkStub())
        state = dispatch(sp, state, ChunkStub())
        assert state.pending is not None and state.pending.sync
        state, ev = collect(state)
        assert ev and state.pending is None

    def test_double_dispatch_raises(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, ChunkStub())
        state = dispatch(sp, state, ChunkStub())
        with pytest.raises(InFlightError, match="already in flight"):
            dispatch(sp, state, ChunkStub())
        collect(state)                                 # settle for hygiene

    def test_dispatch_wrong_phase_raises(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())               # POOL_SELECTED
        with pytest.raises(ValueError, match="SCHEDULED/TRAINING"):
            dispatch(sp, state, ChunkStub())

    def test_collect_without_pending_is_noop(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        st, ev = collect(state)
        assert st is state and ev == []

    def test_step_with_pending_collects(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, ChunkStub())
        state = dispatch(sp, state, AsyncChunkStub())
        state, ev = step(sp, state, AsyncChunkStub())  # finishes the half
        assert ev and state.pending is None

    def test_dispatch_guard_advances_phase_without_pending(self):
        # max_rounds already consumed: dispatch performs the host-side
        # phase advance and leaves nothing in flight
        sp = FLServiceProvider(_profiles())
        task = self._task(max_rounds=1, round_chunk=1)
        state = submit(sp, task)
        state, _ = step(sp, state, ChunkStub())        # schedule
        state, _ = step(sp, state, ChunkStub())        # train round 0
        # precondition: the period has more subsets, so the state is
        # still mid-period with the round budget exhausted
        assert state.phase == TaskPhase.TRAINING
        state = dispatch(sp, state, ChunkStub())
        assert state.pending is None
        assert state.phase == TaskPhase.PERIOD_CHECKPOINT


# ---------------------------------------------------------------------------
# ISSUE-4: checkpointing around an in-flight chunk
# ---------------------------------------------------------------------------

class TestInFlightCheckpoint:
    def _task(self):
        return TaskRequest(budget=400.0, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, round_chunk=2,
                           seed=3)

    def test_to_arrays_refuses_in_flight(self):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, AsyncChunkStub())
        state = dispatch(sp, state, AsyncChunkStub())
        with pytest.raises(InFlightError, match="in-flight"):
            state.to_arrays()
        state, _ = collect(state)
        state.to_arrays()                              # settled: fine

    def test_save_state_refuses_in_flight_by_default(self, tmp_path):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        state, _ = step(sp, state, AsyncChunkStub())
        state = dispatch(sp, state, AsyncChunkStub())
        path = os.path.join(tmp_path, "inflight.ckpt")
        with pytest.raises(InFlightError):
            save_state(path, state)
        assert not os.path.exists(path)
        collect(state)

    def test_flush_roundtrips_through_restore_dict(self, tmp_path):
        """A TaskState captured between dispatch and collect: flush
        settles the chunk, the checkpoint round-trips through
        ``checkpoint.restore_dict`` (load_state), and the resumed task
        reproduces the uninterrupted run exactly."""
        profiles = _profiles()
        task = self._task()
        ref_sp = FLServiceProvider(profiles)
        ref = submit(ref_sp, task)
        ref, ref_events = drain(ref_sp, ref, AsyncChunkStub())
        ref_rep = as_run_result(ref).reputation

        sp = FLServiceProvider(profiles)
        state = submit(sp, task)
        pre = []
        trainer = AsyncChunkStub()
        # advance into period 1, then stop between dispatch and collect
        while not (state.phase == TaskPhase.TRAINING and state.period == 1):
            state, ev = step(sp, state, trainer)
            pre.extend(ev)
        state = dispatch(sp, state, trainer)
        assert state.pending is not None
        path = os.path.join(tmp_path, "flush.ckpt")
        flushed = save_state(path, state, flush=True)
        assert flushed and state.pending is None       # chunk was settled
        pre.extend(flushed)

        restored = load_state(path)                    # checkpoint.restore_dict
        assert restored.phase == state.phase
        assert restored.subset_index == state.subset_index
        assert restored.global_round == state.global_round
        sp2 = FLServiceProvider(profiles)              # "fresh process"
        restored, post = drain(sp2, restored, AsyncChunkStub())
        got = pre + post
        assert [(e.period, e.round_index, e.subset) for e in got] == \
            [(e.period, e.round_index, e.subset) for e in ref_events]
        assert as_run_result(restored).reputation == ref_rep

    def test_flush_on_settled_state_returns_no_events(self, tmp_path):
        sp = FLServiceProvider(_profiles())
        state = submit(sp, self._task())
        path = os.path.join(tmp_path, "settled.ckpt")
        assert save_state(path, state, flush=True) == []


# ---------------------------------------------------------------------------
# Multi-tenant ServiceScheduler
# ---------------------------------------------------------------------------

class TestServiceScheduler:
    def _tasks(self, T):
        return [TaskRequest(budget=300.0 + 20 * t, n_star=5, subset_size=4,
                            subset_delta=2, max_periods=2,
                            scheduler="mkp" if t % 2 else "random", seed=t)
                for t in range(T)]

    def test_concurrent_equals_serial(self):
        profiles = _profiles()
        tasks = self._tasks(8)
        serial = {}
        for tid, task in enumerate(tasks):
            sp = FLServiceProvider(profiles)
            st = submit(sp, task)
            st, _ = drain(sp, st, _stub)
            serial[tid] = as_run_result(st)

        sched = ServiceScheduler(FLServiceProvider(profiles))
        for task in tasks:
            sched.submit(task, _stub)
        conc = sched.run()
        assert set(conc) == set(serial)
        for tid in serial:
            # batched intake returns the same pool set (pool order is
            # greedy-pick vs pool order — documented); rounds and
            # reputation must be bitwise identical
            _assert_results_equal(serial[tid], conc[tid],
                                  order_insensitive_pool=True)

    def test_rounds_interleave_across_tasks(self):
        sched = ServiceScheduler(FLServiceProvider(_profiles()))
        for task in self._tasks(4):
            sched.submit(task, _stub)
        order = []
        for _ in range(10_000):
            if not sched.active:
                break
            for tid, evs in sched.sweep().items():
                order.extend([tid] * len(evs))
        assert not sched.active
        # every task trains before any task finishes its full run
        first_complete = min(max(i for i, t in enumerate(order) if t == tid)
                             for tid in set(order))
        assert set(order[:first_complete]) == set(order)

    def test_infeasible_tenant_terminates(self):
        sched = ServiceScheduler(FLServiceProvider(_profiles()))
        good = sched.submit(self._tasks(1)[0], _stub)
        bad = sched.submit(TaskRequest(budget=1.0, n_star=50), _stub)
        results = sched.run()
        assert results[bad].pool.feasible is False
        assert results[bad].num_rounds == 0
        assert results[good].num_rounds > 0

    def test_retire_evicts_finished_task(self):
        sched = ServiceScheduler(FLServiceProvider(_profiles()))
        tid = sched.submit(self._tasks(1)[0], _stub)
        with pytest.raises(ValueError, match="only terminal"):
            sched.retire(tid)                  # still queued
        sched.run()
        res = sched.retire(tid)
        assert res.num_rounds > 0
        assert tid not in sched.task_ids
        with pytest.raises(KeyError):
            sched.retire(tid)

    def test_adopt_restored_state(self, tmp_path):
        profiles = _profiles()
        task = self._tasks(1)[0]
        sp = FLServiceProvider(profiles)
        st = submit(sp, task)
        st, pre = drain(sp, st, _stub, max_steps=4)
        path = os.path.join(tmp_path, "adopt.ckpt")
        save_state(path, st)
        sched = ServiceScheduler(FLServiceProvider(profiles))
        tid = sched.adopt(load_state(path), _stub)
        res = sched.run()[tid]
        ref_sp = FLServiceProvider(profiles)
        ref_st = submit(ref_sp, task)
        ref_st, ref_events = drain(ref_sp, ref_st, _stub)
        assert [(e.round_index, e.subset) for e in pre] + \
            [(e.round_index, e.subset) for e in res.rounds] == \
            [(e.round_index, e.subset) for e in ref_events]


# ---------------------------------------------------------------------------
# ISSUE-4: overlapped two-phase pump
# ---------------------------------------------------------------------------

class TestOverlappedScheduler:
    def _tasks(self, T):
        return [TaskRequest(budget=300.0 + 20 * t, n_star=5, subset_size=4,
                            subset_delta=2, max_periods=2,
                            scheduler="mkp" if t % 2 else "random", seed=t)
                for t in range(T)]

    def _serial(self, profiles, tasks, trainer_factory):
        out = {}
        for tid, task in enumerate(tasks):
            sp = FLServiceProvider(profiles)
            st = submit(sp, task)
            st, _ = drain(sp, st, trainer_factory())
            out[tid] = as_run_result(st)
        return out

    def test_overlapped_equals_serial_with_async_trainer(self):
        profiles = _profiles()
        tasks = self._tasks(8)
        serial = self._serial(profiles, tasks, AsyncChunkStub)
        sched = ServiceScheduler(FLServiceProvider(profiles), overlap=True)
        for task in tasks:
            sched.submit(task, AsyncChunkStub())
        conc = sched.run()
        assert set(conc) == set(serial)
        for tid in serial:
            _assert_results_equal(serial[tid], conc[tid],
                                  order_insensitive_pool=True)

    def test_overlap_modes_agree(self):
        profiles = _profiles()
        tasks = self._tasks(6)
        results = {}
        for overlap in (False, True):
            sched = ServiceScheduler(FLServiceProvider(profiles),
                                     overlap=overlap)
            for task in tasks:
                sched.submit(task, AsyncChunkStub())
            results[overlap] = sched.run()
        for tid in results[False]:
            _assert_results_equal(results[False][tid], results[True][tid])

    def test_max_inflight_bounds_outstanding_handles(self):
        profiles = _profiles()
        tasks = self._tasks(7)
        rec = {"inflight": 0, "max_inflight": 0}
        sched = ServiceScheduler(FLServiceProvider(profiles),
                                 max_inflight=2, overlap=True)
        for task in tasks:
            sched.submit(task, AsyncChunkStub(rec))
        conc = sched.run()
        assert rec["max_inflight"] <= 2
        assert rec["inflight"] == 0                    # fully drained
        serial = self._serial(profiles, tasks, AsyncChunkStub)
        for tid in serial:
            _assert_results_equal(serial[tid], conc[tid],
                                  order_insensitive_pool=True)

    def test_window_rotation_interleaves_all_tasks(self):
        # 6 tenants through a 2-slot window: every task must still train
        # before any task completes its full run (FIFO rotation, no
        # starvation)
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 max_inflight=2, overlap=True)
        for task in self._tasks(6):
            sched.submit(task, AsyncChunkStub())
        order = []
        for _ in range(10_000):
            if not sched.active:
                break
            for tid, evs in sched.sweep().items():
                order.extend([tid] * len(evs))
        assert not sched.active
        first_complete = min(max(i for i, t in enumerate(order) if t == tid)
                             for tid in set(order))
        assert set(order[:first_complete]) == set(order)

    def test_invalid_max_inflight_rejected(self):
        with pytest.raises(ValueError, match="max_inflight"):
            ServiceScheduler(FLServiceProvider(_profiles()), max_inflight=0)

    def test_adopt_state_with_chunk_in_flight(self):
        # a caller may dispatch through the public API and only then
        # hand the state to a scheduler: sweep must track the pending
        # chunk, not re-dispatch (which would raise InFlightError)
        profiles = _profiles()
        task = self._tasks(1)[0]
        sp = FLServiceProvider(profiles)
        st = submit(sp, task)
        st, _ = step(sp, st, AsyncChunkStub())     # schedule period 0
        trainer = AsyncChunkStub()
        st = dispatch(sp, st, trainer)
        assert st.pending is not None
        sched = ServiceScheduler(sp, overlap=True)
        tid = sched.adopt(st, trainer)
        res = sched.run()[tid]
        ref_sp = FLServiceProvider(profiles)
        ref = submit(ref_sp, task)
        ref, ref_events = drain(ref_sp, ref, AsyncChunkStub())
        assert [(e.round_index, e.subset) for e in res.rounds] == \
            [(e.round_index, e.subset) for e in ref_events]

    def test_nothing_left_in_flight_after_run(self):
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 overlap=True)
        for task in self._tasks(4):
            sched.submit(task, AsyncChunkStub())
        sched.run()
        for tid in sched.task_ids:
            assert sched.state(tid).pending is None
            assert sched.state(tid).phase.terminal


# ---------------------------------------------------------------------------
# Client churn
# ---------------------------------------------------------------------------

class TestChurn:
    def _run_to_checkpoint(self, sp, task):
        state = submit(sp, task)
        while state.phase != TaskPhase.PERIOD_CHECKPOINT:
            assert not state.phase.terminal, state.phase
            state, _ = step(sp, state, _stub)
        return state

    def test_joiners_admitted_at_checkpoint(self):
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=0)
        state = self._run_to_checkpoint(sp, task)
        extra = ClientPoolState.random(3, 10, np.random.default_rng(9))
        sp.pool_state.register_arrays(extra.client_ids + 1000, extra.scores,
                                      extra.histograms, extra.costs)
        state, _ = step(sp, state, _stub)      # the checkpoint transition
        assert {1000, 1001, 1002} <= state.pool
        assert set(state.admitted) == {1000, 1001, 1002}
        state, _ = drain(sp, state, _stub)
        p1 = {c for r in as_run_result(state).rounds
              if r.period == 1 for c in r.subset}
        assert {1000, 1001, 1002} <= p1       # schedulable next period
        # reputation tracked for admitted clients too
        assert 1000 in as_run_result(state).reputation

    def test_joiners_respect_budget_and_thresholds(self):
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2, seed=0,
                           thresholds=np.full(9, 0.05))
        state = self._run_to_checkpoint(sp, task)
        spent = state.pool_selected.total_cost
        extra = ClientPoolState.random(2, 10, np.random.default_rng(3))
        scores = extra.scores.copy()
        scores[0, :] = 0.9                     # passes thresholds
        scores[1, :] = 0.01                    # fails thresholds
        costs = np.array([task.budget - spent + 1.0, 1.0])
        # client 2000 passes thresholds but exceeds the leftover budget;
        # client 2001 is cheap but fails thresholds -> neither admitted
        sp.pool_state.register_arrays([2000, 2001], scores,
                                      extra.histograms, costs)
        state, _ = step(sp, state, _stub)
        assert 2000 not in state.pool and 2001 not in state.pool

    def test_admit_joiners_opt_out(self):
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2, seed=0,
                           admit_joiners=False)
        state = self._run_to_checkpoint(sp, task)
        extra = ClientPoolState.random(2, 10, np.random.default_rng(4))
        sp.pool_state.register_arrays(extra.client_ids + 3000, extra.scores,
                                      extra.histograms, extra.costs)
        state, _ = step(sp, state, _stub)
        assert not ({3000, 3001} & state.pool) and state.admitted == []

    def test_deregister_mid_period_finishes_schedule(self):
        # churning a client out mid-period must not crash the task: the
        # drawn schedule completes against the tombstoned row, and the
        # client is dropped at the next PERIOD_CHECKPOINT
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2, seed=0)
        state = submit(sp, task)
        state, _ = step(sp, state, _stub)      # schedule period 0
        assert state.phase == TaskPhase.SCHEDULED
        victim = state.schedule.subsets[-1][0]  # appears in a later round
        sp.pool_state.deregister([victim])
        state, events = drain(sp, state, _stub)
        res = as_run_result(state)
        p0 = {c for r in res.rounds if r.period == 0 for c in r.subset}
        p1 = {c for r in res.rounds if r.period == 1 for c in r.subset}
        assert victim in p0 and victim not in p1

    def test_deregister_before_first_schedule_does_not_crash(self):
        # churn in the POOL_SELECTED window (right after submit, or
        # between a checkpoint and the next schedule draw) must drop the
        # client, not KeyError out of schedule_period
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=2, seed=0)
        state = submit(sp, task)
        victim = sorted(state.pool)[0]
        sp.pool_state.deregister([victim])
        state, _ = drain(sp, state, _stub)
        assert state.phase == TaskPhase.DONE
        participants = {c for r in as_run_result(state).rounds
                        for c in r.subset}
        assert victim not in participants

    def test_rejoining_new_client_is_admitted(self):
        # a client that registered, churned out, and rejoins reactivates
        # its old row (below the old row count) — the reg_seq watermark
        # must still surface it to the joiner scan
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=0)
        extra = ClientPoolState.random(1, 10, np.random.default_rng(9))
        sp.pool_state.register_arrays([1000], extra.scores,
                                      extra.histograms, extra.costs)
        sp.pool_state.deregister([1000])   # leaves before the task starts
        state = self._run_to_checkpoint(sp, task)
        assert 1000 not in state.pool
        sp.pool_state.register_arrays([1000], extra.scores,
                                      extra.histograms, extra.costs)
        state, _ = step(sp, state, _stub)  # checkpoint: joiner scan
        assert 1000 in state.pool and 1000 in state.admitted

    def test_rejoining_stage1_client_reenters_without_second_charge(self):
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=0)
        state = self._run_to_checkpoint(sp, task)
        member = sorted(state.pool)[0]
        row = int(sp.pool_state.positions([member])[0])
        profile = sp.pool_state.to_profiles()[row]
        sp.pool_state.deregister([member])
        state, _ = step(sp, state, _stub)          # checkpoint drops it
        assert member not in state.pool
        sp.pool_state.register([profile])          # rejoins next period
        # run period 1 to its checkpoint, then roll over
        while state.phase != TaskPhase.PERIOD_CHECKPOINT:
            state, _ = step(sp, state, _stub)
        state, _ = step(sp, state, _stub)
        assert member in state.pool                # re-admitted
        assert member not in state.admitted        # seat already paid
        assert state.admitted_cost == 0.0

    def test_deregistered_dropped_from_pool(self):
        sp = FLServiceProvider(_profiles(40, seed=1))
        task = TaskRequest(budget=1e6, n_star=10, subset_size=5,
                           subset_delta=2, max_periods=3, seed=0)
        state = self._run_to_checkpoint(sp, task)
        victim = sorted(state.pool)[0]
        sp.pool_state.deregister([victim])
        state, _ = step(sp, state, _stub)
        assert victim not in state.pool
        state, _ = drain(sp, state, _stub)
        later = {c for r in as_run_result(state).rounds
                 if r.period >= 1 for c in r.subset}
        assert victim not in later


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

class TestRejoin:
    def test_deregistered_client_can_rejoin(self):
        pool = ClientPoolState.random(6, 10, np.random.default_rng(0))
        pool.deregister([2])
        with pytest.raises(KeyError):
            pool.positions([2])
        add = ClientPoolState.random(2, 10, np.random.default_rng(1))
        pos = pool.register_arrays([2, 50], add.scores, add.histograms,
                                   add.costs)
        assert pos[0] == 2 and pos[1] == 6     # row reused; new appended
        assert int(pool.positions([2])[0]) == 2
        np.testing.assert_array_equal(pool.histograms[2], add.histograms[0])
        assert pool.n == 7

    def test_batch_dup_named_in_error(self):
        pool = ClientPoolState.random(3, 10, np.random.default_rng(0))
        add = ClientPoolState.random(2, 10, np.random.default_rng(1))
        with pytest.raises(ValueError, match=r"\[7\]"):
            pool.register_arrays([7, 7], add.scores, add.histograms,
                                 add.costs)
        with pytest.raises(ValueError, match=r"\[1\]"):
            pool.register_arrays([1, 9], add.scores, add.histograms,
                                 add.costs)


class TestRegistryInvalidation:
    def test_registry_refreshes_on_mutation(self):
        sp = FLServiceProvider(_profiles(20))
        before = set(sp.registry)
        extra = ClientPoolState.random(2, 10, np.random.default_rng(2))
        sp.pool_state.register_arrays(extra.client_ids + 500, extra.scores,
                                      extra.histograms, extra.costs)
        after = set(sp.registry)               # regression: was stale
        assert after == before | {500, 501}
        sp.pool_state.deregister([500])
        assert 500 not in sp.registry

    def test_registry_refreshes_on_replacement(self):
        sp = FLServiceProvider(_profiles(20))
        _ = sp.registry
        sp.pool_state = ClientPoolState.random(5, 10,
                                               np.random.default_rng(1))
        assert set(sp.registry) == set(range(5))

    def test_registry_cached_between_reads(self):
        sp = FLServiceProvider(_profiles(20))
        assert sp.registry is sp.registry      # no rebuild without mutation


class TestPositionsKeyError:
    def test_unknown_id_raises(self):
        pool = ClientPoolState.random(5, 10, np.random.default_rng(0))
        with pytest.raises(KeyError, match="not registered"):
            pool.positions([99])

    def test_deregistered_id_raises(self):
        pool = ClientPoolState.random(5, 10, np.random.default_rng(0))
        pool.deregister([2])
        with pytest.raises(KeyError, match="not registered"):
            pool.positions([2])

    def test_schedule_period_surfaces_churned_id(self):
        sp = FLServiceProvider(_profiles(20))
        task = TaskRequest(budget=1e6, n_star=5, subset_size=4)
        with pytest.raises(KeyError, match="not registered"):
            sp.schedule_period([0, 1, 10_000], task,
                               np.random.default_rng(0))


class TestSelectPoolsBatchEdges:
    def test_empty_task_list(self):
        sp = FLServiceProvider(_profiles(20))
        assert sp.select_pools_batch([]) == []

    def test_all_infeasible_thresholds(self):
        sp = FLServiceProvider(_profiles(20))
        tasks = [TaskRequest(budget=1e6, n_star=1,
                             thresholds=np.full(9, 1.1)) for _ in range(3)]
        res = sp.select_pools_batch(tasks)
        assert all(not r.feasible for r in res)
        assert all("pass thresholds" in r.note for r in res)

    def test_budget_floor_note_fires(self):
        sp = FLServiceProvider(_profiles(20))
        task = TaskRequest(budget=3.0, n_star=10)
        (res,) = sp.select_pools_batch([task])
        assert not res.feasible and "floor" in res.note

    def test_parity_with_select_pool(self):
        sp = FLServiceProvider(_profiles(50, seed=4))
        tasks = [TaskRequest(budget=b, n_star=n,
                             thresholds=th)
                 for b, n, th in [(150.0, 5, None),
                                  (80.0, 3, np.full(9, 0.2)),
                                  (3.0, 10, None),
                                  (1e6, 60, np.full(9, 0.9))]]
        batch = sp.select_pools_batch(tasks)
        for task, b in zip(tasks, batch):
            s = sp.select_pool(task)
            assert sorted(s.selected) == sorted(b.selected)
            assert s.total_score == pytest.approx(b.total_score)
            assert s.total_cost == pytest.approx(b.total_cost)
            assert s.feasible == b.feasible
            assert s.note == b.note


class TestDeprecation:
    def test_run_task_warns_once_per_call_site(self):
        sp = FLServiceProvider(_profiles(30))
        task = TaskRequest(budget=200.0, n_star=5, subset_size=4,
                           subset_delta=2, max_periods=1)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("default")   # once-per-location filter
            for _ in range(3):                 # one call site, three calls
                sp.run_task(task, _stub)
        dep = [w for w in caught
               if issubclass(w.category, DeprecationWarning)
               and "run_task" in str(w.message)]
        assert len(dep) == 1

    def test_lifecycle_api_does_not_warn(self):
        sp = FLServiceProvider(_profiles(30))
        task = TaskRequest(budget=200.0, n_star=5, subset_size=4,
                           subset_delta=2, max_periods=1)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("error", DeprecationWarning)
            state = submit(sp, task)
            drain(sp, state, _stub)
        assert not caught


class TestReputationSoA:
    def test_records_view_and_arrays_roundtrip(self):
        tr = ReputationTracker([3, 7, 9], rep_threshold=0.4)
        tr.record_round(3, True, q_value=0.8)
        tr.record_round(3, False)
        tr.record_round(7, True, q_value=0.6)
        tr.update_pool({3, 7, 9})
        back = ReputationTracker.from_arrays(tr.to_arrays())
        assert back.scores() == tr.scores()
        assert back.period == tr.period
        assert back.records[3].suspended_until == tr.records[3].suspended_until
        np.testing.assert_array_equal(back.records[3].q_rounds,
                                      tr.records[3].q_rounds)
        # and the restored tracker keeps accepting rounds
        back.record_round(9, True, q_value=1.0)
        assert back.records[9].num_rounds == 1

    def test_add_clients(self):
        tr = ReputationTracker([0, 1])
        tr.record_round(0, True, q_value=0.9)
        tr.add_clients([5])
        assert set(tr.records) == {0, 1, 5}
        tr.record_round(5, True, q_value=0.7)
        assert tr.records[5].s_rep == pytest.approx(1.7)
        assert tr.records[0].s_rep == pytest.approx(1.9)
        with pytest.raises(ValueError):
            tr.add_clients([0])

    def test_round_buffer_growth(self):
        tr = ReputationTracker([0])
        for r in range(50):                    # > initial capacity
            tr.record_round(0, True, q_value=0.5)
        assert tr.records[0].num_rounds == 50
        assert tr.records[0].s_rep == pytest.approx(1.5)
