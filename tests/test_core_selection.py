"""Stage-1 selection tests, including the paper's Experiment 1 instance."""
import numpy as np
import pytest

from repro.core import selection as S
from repro.core import criteria as C

# Paper Table II — Experiment 1 input.
PAPER_SCORES = np.array([6.92, 4.89, 6.8, 6.08, 6.9, 6.08, 3.74, 3.36, 5.26, 3.39])
PAPER_COSTS = np.array([18, 14, 18, 17, 18, 17, 12, 11, 15, 11], dtype=float)
BUDGET = 100.0


class TestPaperExperiment1:
    """Reproduces Table III."""

    def test_dp_optimal(self):
        res = S.select_dp(PAPER_SCORES, PAPER_COSTS, BUDGET)
        assert res.total_cost <= BUDGET
        # Paper: DP attains 36.85 with {8,5,4,2,1,0}. The instance has
        # score ties ({0,1,2,4,5,8} and {0,1,2,3,4,8} both reach 36.85);
        # we assert the optimum value, not the particular optimizer.
        assert res.total_score == pytest.approx(36.85, abs=1e-9)
        assert len(res.selected) == 6

    def test_greedy_matches_paper(self):
        res = S.select_greedy(PAPER_SCORES, PAPER_COSTS, BUDGET)
        assert res.total_cost <= BUDGET
        # Paper: greedy selects {0,4,2,5,3} with total score 32.78
        assert sorted(res.selected) == [0, 2, 3, 4, 5]
        assert res.total_score == pytest.approx(32.78, abs=1e-9)
        opt = S.select_dp(PAPER_SCORES, PAPER_COSTS, BUDGET).total_score
        assert res.approx_ratio(opt) == pytest.approx(0.11, abs=5e-3)

    def test_random_within_budget(self):
        res = S.select_random(PAPER_SCORES, PAPER_COSTS, BUDGET,
                              np.random.default_rng(3))
        assert res.total_cost <= BUDGET
        opt = S.select_dp(PAPER_SCORES, PAPER_COSTS, BUDGET).total_score
        assert res.total_score <= opt


class TestSolvers:
    def test_greedy_never_exceeds_dp(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            n = int(rng.integers(5, 40))
            scores = rng.uniform(1, 10, n)
            costs = np.rint(rng.uniform(5, 25, n))
            B = float(rng.integers(30, 200))
            g = S.select_greedy(scores, costs, B)
            d = S.select_dp(scores, costs, B)
            assert g.total_cost <= B and d.total_cost <= B
            assert g.total_score <= d.total_score + 1e-9
            # known greedy bound is loose; empirically stays close
            if d.total_score > 0:
                assert g.total_score >= 0.5 * d.total_score

    def test_dp_exact_against_bruteforce(self):
        rng = np.random.default_rng(1)
        for _ in range(10):
            n = 10
            scores = rng.uniform(1, 10, n)
            costs = np.rint(rng.uniform(3, 15, n))
            B = 40.0
            best = 0.0
            for mask in range(1 << n):
                idx = [i for i in range(n) if mask >> i & 1]
                if np.sum(costs[idx]) <= B:
                    best = max(best, float(np.sum(scores[idx])))
            d = S.select_dp(scores, costs, B)
            assert d.total_score == pytest.approx(best, abs=1e-9)

    def test_zero_budget(self):
        res = S.select_greedy(PAPER_SCORES, PAPER_COSTS, 0.0)
        assert res.selected == [] and res.total_score == 0.0


class TestStage1Pipeline:
    def _profiles(self, n=30, seed=0):
        return C.random_profiles(n, 10, np.random.default_rng(seed))

    def test_threshold_filter(self):
        profs = self._profiles()
        th = np.full(9, 0.3)
        kept = S.threshold_filter(profs, th)
        for p in kept:
            assert np.all(p.scores[:9] >= 0.3)
        assert len(kept) < len(profs)  # random scores: some fail

    def test_budget_floor_eq11(self):
        profs = self._profiles()
        floor = S.budget_floor(profs, 5)
        top5 = sorted((p.cost for p in profs), reverse=True)[:5]
        assert floor == pytest.approx(sum(top5))

    def test_select_initial_pool_feasible(self):
        profs = self._profiles()
        res = S.select_initial_pool(profs, budget=400.0, n_star=5)
        assert res.feasible and len(res.selected) >= 5
        # returned ids must be real client ids
        ids = {p.client_id for p in profs}
        assert set(res.selected) <= ids

    def test_select_initial_pool_infeasible_thresholds(self):
        profs = self._profiles()
        res = S.select_initial_pool(profs, budget=1e6, n_star=5,
                                    thresholds=np.full(9, 0.999))
        assert not res.feasible

    def test_select_initial_pool_infeasible_budget(self):
        profs = self._profiles()
        res = S.select_initial_pool(profs, budget=1.0, n_star=5)
        assert not res.feasible
        assert "Eq.(11)" in res.note

    @pytest.mark.parametrize("method", ["greedy", "dp", "random"])
    def test_all_methods_run(self, method):
        profs = self._profiles()
        res = S.select_initial_pool(profs, budget=300.0, n_star=2,
                                    method=method,
                                    rng=np.random.default_rng(0))
        assert res.total_cost <= 300.0
