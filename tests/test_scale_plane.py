"""Million-client selection plane (ISSUE-6): segmented top-k kernel vs
oracle, DevicePoolState mirror coherence under randomized churn,
hierarchical two-level greedy vs the flat path (bit-exact, incl.
tie-heavy pools and forced escalation), batched score_prop vs serial,
select_pools_batch parity at >= 2 shards, and the policy-aware churn
admission regression."""
import numpy as np
import pytest

from repro.core import (FLServiceProvider, TaskPhase, TaskRequest, drain,
                        step, submit)
from repro.core import device_pool, engine, policy, selection
from repro.core.criteria import overall_score, random_histograms
from repro.core.device_pool import DevicePoolState
from repro.core.pool import ClientPoolState
from repro.kernels import ops, ref

TH = np.full(9, 0.05)


def _pool(n, seed=0):
    return ClientPoolState.random(n, 10, np.random.default_rng(seed))


def _churn(pool, rng, n_events):
    """Random deregister/register mix; returns nothing (mutates pool)."""
    drop = rng.choice(pool.client_ids[pool.registered], size=n_events // 2,
                      replace=False)
    pool.deregister(drop)
    k = n_events - drop.size
    base = int(pool.client_ids.max()) + 1
    pool.register_arrays(np.arange(base, base + k),
                         rng.random((k, 11)),
                         random_histograms(k, 10, rng),
                         rng.uniform(1.0, 5.0, k))


# ---------------------------------------------------------------------------
# segmented top-k kernel
# ---------------------------------------------------------------------------

class TestSegmentedTopk:
    @pytest.mark.parametrize("S,C,k", [(1, 8, 3), (4, 64, 8), (7, 129, 16),
                                       (3, 32, 32), (2, 16, 40)])
    def test_kernel_matches_oracle(self, S, C, k):
        x = np.random.default_rng(S * C + k).normal(size=(S, C))
        vo, io = ref.segmented_topk_ref(np.asarray(x, np.float32), k)
        vk, ik = ops.segmented_topk(np.asarray(x, np.float32), k,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(vk), np.asarray(vo))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(io))

    def test_ties_break_to_lowest_lane(self):
        x = np.zeros((2, 12), np.float32)
        x[0, [3, 7, 11]] = 5.0                  # three-way tie
        x[1, :] = 1.0                           # full-row tie
        for impl in (lambda a: ref.segmented_topk_ref(a, 4),
                     lambda a: ops.segmented_topk(a, 4, interpret=True)):
            _, idx = impl(x)
            np.testing.assert_array_equal(np.asarray(idx)[0], [3, 7, 11, 0])
            np.testing.assert_array_equal(np.asarray(idx)[1], [0, 1, 2, 3])

    def test_neg_inf_padding_marks_exhaustion(self):
        x = np.full((2, 8), -np.inf, np.float32)
        x[0, 2] = 1.0
        vals, idx = ops.segmented_topk(x, 3, interpret=True)
        vals = np.asarray(vals)
        assert vals[0, 0] == 1.0 and np.asarray(idx)[0, 0] == 2
        assert np.all(np.isinf(vals[0, 1:])) and np.all(np.isinf(vals[1]))

    def test_dispatcher_uses_oracle_on_cpu(self):
        x = np.random.default_rng(0).normal(size=(3, 20)).astype(np.float32)
        vd, idd = ops.segmented_topk(x, 5)       # interpret=None -> oracle
        vo, ido = ref.segmented_topk_ref(x, 5)
        np.testing.assert_array_equal(np.asarray(vd), np.asarray(vo))
        np.testing.assert_array_equal(np.asarray(idd), np.asarray(ido))


# ---------------------------------------------------------------------------
# device mirror + dirty-region sync
# ---------------------------------------------------------------------------

class TestDevicePoolMirror:
    def _assert_coherent(self, m, pool):
        """Mirror rows [0, pool.n) equal a fresh staging of the host."""
        fresh = DevicePoolState.from_host(pool, shard_cap=m.shard_cap)
        for attr in ("overall", "costs", "th_scores", "registered"):
            a = np.asarray(getattr(m, attr)).reshape(m.capacity, -1)[:pool.n]
            b = np.asarray(getattr(fresh, attr)
                           ).reshape(fresh.capacity, -1)[:pool.n]
            np.testing.assert_array_equal(a, b, err_msg=attr)
        assert m.n_rows == pool.n and m.synced_version == pool.version

    def test_from_host_layout(self):
        pool = _pool(1000)
        m = DevicePoolState.from_host(pool, shard_cap=256)
        assert m.num_shards == 4 and m.capacity == 1024
        reg = np.asarray(m.registered).reshape(-1)
        assert reg[:1000].all() and not reg[1000:].any()
        np.testing.assert_allclose(
            np.asarray(m.overall).reshape(-1)[:1000],
            overall_score(pool.scores).astype(np.float32), rtol=0, atol=0)

    def test_incremental_sync_after_randomized_churn(self):
        pool = _pool(2000, seed=3)
        m = pool.device_mirror(shard_cap=512)
        rng = np.random.default_rng(7)
        for _ in range(5):
            _churn(pool, rng, rng.integers(10, 120))
            m2 = pool.device_mirror(shard_cap=512)
            assert m2 is m                       # cached object, synced
            self._assert_coherent(m, pool)
        assert m.restages == 1                   # only the initial staging
        assert m.syncs == 5

    def test_growth_appends_shards(self):
        pool = _pool(500, seed=1)
        m = pool.device_mirror(shard_cap=256)
        assert m.num_shards == 2
        _churn(pool, np.random.default_rng(2), 4)  # few events first
        big = 900                                  # then a big join wave
        base = int(pool.client_ids.max()) + 1
        r = np.random.default_rng(5)
        pool.register_arrays(np.arange(base, base + big),
                             r.random((big, 11)),
                             random_histograms(big, 10, r),
                             r.uniform(1, 5, big))
        m2 = pool.device_mirror(shard_cap=256)
        assert m2 is m and m.num_shards >= -(-pool.n // 256)
        self._assert_coherent(m, pool)

    def test_pruned_log_forces_restage(self):
        pool = _pool(300, seed=4)
        m = pool.device_mirror(shard_cap=128)
        old_max = ClientPoolState._MUTLOG_MAX
        ClientPoolState._MUTLOG_MAX = 4
        try:
            rng = np.random.default_rng(9)
            for _ in range(10):                  # overflow the log
                _churn(pool, rng, 6)
            assert pool.dirty_rows_since(m.synced_version) is None
            m2 = pool.device_mirror(shard_cap=128)
        finally:
            ClientPoolState._MUTLOG_MAX = old_max
        assert m2 is m and m.restages == 2
        self._assert_coherent(m, pool)

    def test_noop_sync_when_clean(self):
        pool = _pool(100)
        m = pool.device_mirror(shard_cap=64)
        m2 = pool.device_mirror(shard_cap=64)
        assert m2 is m and m.syncs == 0 and m.restages == 1


# ---------------------------------------------------------------------------
# hierarchical two-level greedy vs flat
# ---------------------------------------------------------------------------

class TestHierarchicalEquivalence:
    @pytest.mark.parametrize("budget", [50.0, 800.0, 8000.0])
    def test_matches_flat_greedy(self, budget):
        pool = _pool(6000, seed=11)
        frows, fts, ftc, fnv = engine._flat_pool_greedy(pool, budget, TH)
        stats = {}
        rows, ts, tc, nv = engine.hierarchical_greedy_knapsack(
            pool, budget, TH, shard_cap=512, stats=stats)
        np.testing.assert_array_equal(rows, frows)  # incl. pick order
        assert ts == fts and tc == ftc and nv == fnv
        assert stats["path"] == "frontier" and stats["shards"] >= 2

    def test_tie_heavy_pool(self):
        pool = _pool(4000, seed=12)
        pool.scores[:] = np.round(pool.scores * 4) / 4   # massive ties
        pool.costs[:] = np.round(np.maximum(pool.costs, 1.0))
        pool._overall = None
        frows, _, _, _ = engine._flat_pool_greedy(pool, 400.0, TH)
        rows, _, _, _ = engine.hierarchical_greedy_knapsack(
            pool, 400.0, TH, shard_cap=256)
        np.testing.assert_array_equal(rows, frows)

    def test_escalation_still_exact(self):
        # skew all the best ratios into one shard so the initial
        # frontier must escalate before the answer stabilizes
        pool = _pool(2000, seed=13)
        pool.costs[:256] = 1.0                  # shard 0 = cheap = hot
        pool._overall = None
        stats = {}
        rows, ts, tc, _ = engine.hierarchical_greedy_knapsack(
            pool, 150.0, TH, shard_cap=256, stats=stats)
        frows, fts, ftc, _ = engine._flat_pool_greedy(pool, 150.0, TH)
        assert stats["escalations"] >= 1
        np.testing.assert_array_equal(rows, frows)
        assert ts == fts and tc == ftc

    def test_select_everything_budget_falls_back_flat(self):
        pool = _pool(3000, seed=14)
        stats = {}
        rows, ts, tc, _ = engine.hierarchical_greedy_knapsack(
            pool, 10.0 * pool.n, TH, shard_cap=512, stats=stats)
        assert stats["path"] == "flat-fallback"
        frows, fts, ftc, _ = engine._flat_pool_greedy(pool, 10.0 * pool.n, TH)
        np.testing.assert_array_equal(rows, frows)

    def test_post_churn_reselection_matches(self):
        pool = _pool(3000, seed=15)
        m = pool.device_mirror(shard_cap=512)
        rng = np.random.default_rng(16)
        for _ in range(3):
            _churn(pool, rng, 80)
            rows, ts, tc, nv = engine.hierarchical_greedy_knapsack(
                pool, 900.0, TH, mirror=m)
            frows, fts, ftc, fnv = engine._flat_pool_greedy(pool, 900.0, TH)
            np.testing.assert_array_equal(rows, frows)
            assert ts == fts and tc == ftc and nv == fnv
        assert m.restages == 1

    def test_select_initial_pool_routes_hierarchical(self, monkeypatch):
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 1000)
        monkeypatch.setattr(device_pool, "DEFAULT_SHARD_CAP", 512)
        pool = _pool(2500, seed=17)
        res = selection.select_initial_pool(pool, 700.0, n_star=5,
                                            thresholds=TH)
        flat = selection.select_initial_pool(pool, 700.0, n_star=5,
                                             thresholds=TH, method="greedy")
        # second call hits the same route; compare against a pool below
        # the threshold cutoff containing identical rows
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 10**9)
        ref_res = selection.select_initial_pool(pool, 700.0, n_star=5,
                                                thresholds=TH)
        assert res.selected == ref_res.selected == flat.selected
        assert res.total_score == ref_res.total_score
        assert res.total_cost == ref_res.total_cost
        assert res.feasible and res.note == ref_res.note

    def test_infeasible_notes_match_flat(self, monkeypatch):
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 100)
        monkeypatch.setattr(device_pool, "DEFAULT_SHARD_CAP", 64)
        pool = _pool(400, seed=18)
        hi = selection.select_initial_pool(pool, 2.0, n_star=50,
                                           thresholds=TH)
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 10**9)
        fl = selection.select_initial_pool(pool, 2.0, n_star=50,
                                           thresholds=TH)
        assert (not hi.feasible) and (not fl.feasible)
        assert hi.note == fl.note and hi.selected == fl.selected

    def test_select_pools_batch_parity_multi_shard(self, monkeypatch):
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 1000)
        monkeypatch.setattr(device_pool, "DEFAULT_SHARD_CAP", 512)
        pool = _pool(2200, seed=19)
        sp = FLServiceProvider(pool)
        tasks = [TaskRequest(budget=b, n_star=3, thresholds=TH, seed=i)
                 for i, b in enumerate([120.0, 950.0, 4000.0])]
        batch = sp.select_pools_batch(tasks)
        assert pool._mirror is not None and pool._mirror.num_shards >= 2
        monkeypatch.setattr(device_pool, "HIERARCHICAL_MIN_N", 10**9)
        flat = sp.select_pools_batch(tasks)
        for hb, fb in zip(batch, flat):
            assert hb.selected == fb.selected          # both pool order
            assert hb.total_score == fb.total_score
            assert hb.total_cost == fb.total_cost
            assert hb.feasible == fb.feasible


# ---------------------------------------------------------------------------
# batched score_prop
# ---------------------------------------------------------------------------

class TestScorePropBatch:
    def test_batch_matches_serial_per_task(self):
        pool = _pool(800, seed=21)
        budgets = np.array([40.0, 200.0, 1e6])
        valid = np.stack([pool.threshold_mask(TH)] * 3)
        valid[1, ::3] = False                   # task-specific masks
        serial = []
        for t in range(3):
            rng = np.random.default_rng(100 + t)
            cols = np.flatnonzero(valid[t])
            r = selection.select_score_prop(pool.overall[cols],
                                            pool.costs[cols],
                                            budgets[t], rng, ids=cols)
            serial.append((np.asarray(r.selected), r.total_score,
                           r.total_cost))
        batch = selection.select_score_prop_batch(
            pool.overall, pool.costs, budgets,
            [np.random.default_rng(100 + t) for t in range(3)], valid)
        for (sp_, sts, stc), (bp, bts, btc) in zip(serial, batch):
            np.testing.assert_array_equal(sp_, bp)   # pick order too
            assert sts == bts and stc == btc

    def test_policy_batch_matches_policy_serial(self):
        pool = _pool(600, seed=22)
        pol = policy.selection_policy("score_prop")
        tasks = [TaskRequest(budget=b, n_star=ns, thresholds=TH, seed=i,
                             selection_policy="score_prop")
                 for i, (b, ns) in enumerate([(60.0, 2), (2.0, 50),
                                              (500.0, 2)])]
        serial = pol.select(pool, tasks[0], np.random.default_rng(0)), \
            pol.select(pool, tasks[1], np.random.default_rng(1)), \
            pol.select(pool, tasks[2], np.random.default_rng(2))
        batch = pol.select_batch(pool, tasks,
                                 [np.random.default_rng(i)
                                  for i in range(3)])
        for s, b in zip(serial, batch):
            assert s.selected == b.selected
            assert s.total_score == b.total_score
            assert s.total_cost == b.total_cost
            assert s.feasible == b.feasible and s.note == b.note


# ---------------------------------------------------------------------------
# policy-aware churn admission (satellite regression)
# ---------------------------------------------------------------------------

def _stub(rnd, subset, weights):
    subset = np.asarray(subset)
    returned = np.ones(subset.size, bool)
    return returned, np.full(subset.size, 0.8), {"round": rnd}


class TestChurnPolicyRouting:
    def _to_checkpoint(self, sp, task):
        state = submit(sp, task)
        while state.phase != TaskPhase.PERIOD_CHECKPOINT:
            assert not state.phase.terminal
            state, _ = step(sp, state, _stub)
        return state

    def _join_wave(self, sp, seed=31, k=6):
        rng = np.random.default_rng(seed)
        scores = np.clip(rng.random((k, 11)), 0.1, None)
        costs = rng.uniform(1.0, 6.0, k)
        ids = np.arange(5000, 5000 + k)
        sp.pool_state.register_arrays(ids, scores,
                                      random_histograms(k, 10, rng), costs)
        return ids, scores, costs

    def test_default_greedy_admission_unchanged(self):
        """paper_greedy admission == the legacy hard-coded skip-scan."""
        sp = FLServiceProvider(_pool(40, seed=30))
        task = TaskRequest(budget=250.0, n_star=5, subset_size=5,
                           max_periods=3, seed=0)
        state = self._to_checkpoint(sp, task)
        ids, scores, costs = self._join_wave(sp)
        budget_left = (task.budget - state.pool_selected.total_cost
                       - state.admitted_cost)
        # legacy rule: ratio order, skip unaffordable
        ratio = overall_score(scores) / np.maximum(costs, 1e-12)
        expect, rem = [], budget_left
        for j in np.argsort(-ratio, kind="stable"):
            if costs[j] <= rem:
                expect.append(int(ids[j]))
                rem -= float(costs[j])
        state, _ = step(sp, state, _stub)
        assert sorted(state.admitted) == sorted(expect)

    def test_dp_policy_routes_admission(self):
        """A dp task admits joiners via the exact knapsack — the greedy
        ratio rule no longer decides (the pre-ISSUE-6 behavior)."""
        pool = _pool(40, seed=33)
        sp = FLServiceProvider(pool)
        # budget covers the whole pool -> a known leftover of ~10 for
        # the joiner knapsack below
        task = TaskRequest(budget=float(pool.costs.sum()) + 10.0, n_star=5,
                           subset_size=5, max_periods=3, seed=0,
                           selection_policy="dp")
        state = self._to_checkpoint(sp, task)
        # candidates engineered so greedy(skip) and dp disagree:
        # greedy takes the high-ratio pricey one first and strands
        # budget; dp packs the two complements exactly
        budget_left = (task.budget - state.pool_selected.total_cost
                       - state.admitted_cost)
        scores = np.full((3, 11), 0.5)
        scores[0] = 0.95                         # ratio hero
        costs = np.array([np.floor(budget_left) - 1.0,
                          np.floor(budget_left) / 2.0,
                          np.floor(budget_left) / 2.0])
        rng = np.random.default_rng(34)
        sp.pool_state.register_arrays([7000, 7001, 7002], scores,
                                      random_histograms(3, 10, rng), costs)
        from repro.core.selection import select_dp
        exp = select_dp(overall_score(scores), costs, budget_left,
                        ids=[7000, 7001, 7002]).selected
        state, _ = step(sp, state, _stub)
        assert sorted(state.admitted) == sorted(int(c) for c in exp)

    def test_hookless_policy_falls_back_to_legacy_rule(self, monkeypatch):
        class Hookless:
            name = "hookless_sel"

            def select(self, pool, task, rng):
                return selection.select_initial_pool(
                    pool, task.budget, task.n_star, task.thresholds,
                    method="greedy")

            def select_batch(self, pool, tasks, rngs):
                return [self.select(pool, t, r)
                        for t, r in zip(tasks, rngs)]

        monkeypatch.setitem(policy._SELECTION, "hookless_sel", Hookless())
        sp = FLServiceProvider(_pool(40, seed=35))
        task = TaskRequest(budget=250.0, n_star=5, subset_size=5,
                           max_periods=3, seed=0,
                           selection_policy="hookless_sel")
        state = self._to_checkpoint(sp, task)
        ids, scores, costs = self._join_wave(sp, seed=36)
        budget_left = (task.budget - state.pool_selected.total_cost
                       - state.admitted_cost)
        ratio = overall_score(scores) / np.maximum(costs, 1e-12)
        expect, rem = [], budget_left
        for j in np.argsort(-ratio, kind="stable"):
            if costs[j] <= rem:
                expect.append(int(ids[j]))
                rem -= float(costs[j])
        state, _ = step(sp, state, _stub)
        assert sorted(state.admitted) == sorted(expect)
