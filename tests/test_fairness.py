"""§VII fairness-guarantee property checks (promised by
core/scheduling.py's docstring).

For both the legacy dict/loop scheduler and the array-native one, over
the paper's pool types and fully randomized pools:

  1. coverage  — every pooled client appears in >= 1 subset;
  2. bounded   — no client appears in more than x* subsets;
  3. sizes     — every subset has <= n+δ clients; every subset but the
     last has >= n−δ; the last has >= min(n−δ, tail), where tail is the
     number of clients still uncovered when it is formed.
"""
import numpy as np
import pytest

from repro.core import fairness as F
from repro.core import scheduling as Sch
from repro.core.criteria import random_histograms
from test_core_scheduling import make_pool

SCHEDULERS = {
    "array": Sch.generate_subsets,
    "legacy": Sch.generate_subsets_legacy,
}


def check_guarantees(res, hists, n, delta, x_star):
    ids = set(hists)
    # 1. coverage
    covered = set().union(*map(set, res.subsets)) if res.subsets else set()
    assert covered == ids, "some pooled client never scheduled"
    assert F.coverage(res, list(ids))
    # 2. bounded participation
    assert F.bounded_participation(res, x_star)
    recount = {}
    for s in res.subsets:
        assert len(s) == len(set(s)), "duplicate client within a subset"
        for k in s:
            recount[k] = recount.get(k, 0) + 1
    assert recount == {k: v for k, v in res.counts.items() if v > 0}
    # 3. size bounds
    min_size, max_size = max(1, n - delta), n + delta
    seen = set()
    for i, s in enumerate(res.subsets):
        assert len(s) <= max_size
        tail = len(ids) - len(seen)
        if i < len(res.subsets) - 1:
            assert len(s) >= min_size
        else:
            assert len(s) >= min(min_size, tail)
        seen |= set(s)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
@pytest.mark.parametrize("kind", ["type1", "type2", "type3", "iid"])
def test_paper_pool_types(backend, kind):
    hists = make_pool(kind, n_clients=70)
    res = SCHEDULERS[backend](hists, n=10, delta=3, x_star=3)
    check_guarantees(res, hists, n=10, delta=3, x_star=3)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
def test_randomized_pools(backend):
    rng = np.random.default_rng(0)
    for trial in range(8):
        P = int(rng.integers(5, 80))
        c = int(rng.integers(2, 12))
        hists = {i: h for i, h in
                 enumerate(random_histograms(P, c, rng))}
        n = int(rng.integers(3, 14))
        delta = int(rng.integers(0, 4))
        x_star = int(rng.integers(1, 5))
        res = SCHEDULERS[backend](hists, n=n, delta=delta, x_star=x_star)
        check_guarantees(res, hists, n, delta, x_star)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
def test_fairness_report_quantities(backend):
    hists = make_pool("type1", n_clients=60)
    res = SCHEDULERS[backend](hists, n=10, delta=3, x_star=3)
    rep = F.fairness_report(res, list(hists), x_star=3)
    assert rep["coverage"] and rep["bounded"]
    assert 0.0 < rep["jain_index"] <= 1.0
    assert rep["max_count"] <= 3
    assert rep["rounds"] == res.num_rounds


def test_single_and_empty_pools():
    for backend in SCHEDULERS.values():
        res = backend({0: np.array([10.0, 0.0])}, n=10, delta=3)
        assert res.subsets == [[0]]
        res = backend({}, n=10, delta=3)
        assert res.subsets == []
