"""§VII fairness-guarantee property checks (promised by
core/scheduling.py's docstring).

For both the legacy dict/loop scheduler and the array-native one —
and, since ISSUE-5, for **every registered scheduling policy**
(core.policy) — over the paper's pool types and fully randomized
pools:

  1. coverage  — every pooled client appears in >= 1 subset;
  2. bounded   — no client appears in more than x* subsets;
  3. sizes     — every subset has <= n+δ clients; every subset but the
     last has >= n−δ; the last has >= min(n−δ, tail), where tail is the
     number of clients still uncovered when it is formed.
"""
import numpy as np
import pytest

from repro.core import TaskRequest
from repro.core import fairness as F
from repro.core import policy as P
from repro.core import scheduling as Sch
from repro.core.criteria import random_histograms
from test_core_scheduling import make_pool

SCHEDULERS = {
    "array": Sch.generate_subsets,
    "legacy": Sch.generate_subsets_legacy,
}


def policy_schedule(name, hists, n, delta, x_star,
                    state=None, seed=0):
    """Drive a registered SchedulingPolicy over a dict pool (the test
    harness shape) through its array-native contract."""
    ids = np.array(sorted(hists), dtype=np.int64)
    H = (np.stack([np.asarray(hists[int(k)], dtype=np.float64)
                   for k in ids]) if ids.size else np.zeros((0, 1)))
    task = TaskRequest(budget=0.0, subset_size=n, subset_delta=delta,
                       x_star=x_star)
    return P.scheduling_policy(name).schedule(
        ids, H, task, np.random.default_rng(seed),
        {} if state is None else state)


def check_guarantees(res, hists, n, delta, x_star):
    ids = set(hists)
    # 1. coverage
    covered = set().union(*map(set, res.subsets)) if res.subsets else set()
    assert covered == ids, "some pooled client never scheduled"
    assert F.coverage(res, list(ids))
    # 2. bounded participation
    assert F.bounded_participation(res, x_star)
    recount = {}
    for s in res.subsets:
        assert len(s) == len(set(s)), "duplicate client within a subset"
        for k in s:
            recount[k] = recount.get(k, 0) + 1
    assert recount == {k: v for k, v in res.counts.items() if v > 0}
    # 3. size bounds
    min_size, max_size = max(1, n - delta), n + delta
    seen = set()
    for i, s in enumerate(res.subsets):
        assert len(s) <= max_size
        tail = len(ids) - len(seen)
        if i < len(res.subsets) - 1:
            assert len(s) >= min_size
        else:
            assert len(s) >= min(min_size, tail)
        seen |= set(s)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
@pytest.mark.parametrize("kind", ["type1", "type2", "type3", "iid"])
def test_paper_pool_types(backend, kind):
    hists = make_pool(kind, n_clients=70)
    res = SCHEDULERS[backend](hists, n=10, delta=3, x_star=3)
    check_guarantees(res, hists, n=10, delta=3, x_star=3)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
def test_randomized_pools(backend):
    rng = np.random.default_rng(0)
    for trial in range(8):
        P = int(rng.integers(5, 80))
        c = int(rng.integers(2, 12))
        hists = {i: h for i, h in
                 enumerate(random_histograms(P, c, rng))}
        n = int(rng.integers(3, 14))
        delta = int(rng.integers(0, 4))
        x_star = int(rng.integers(1, 5))
        res = SCHEDULERS[backend](hists, n=n, delta=delta, x_star=x_star)
        check_guarantees(res, hists, n, delta, x_star)


@pytest.mark.parametrize("backend", list(SCHEDULERS))
def test_fairness_report_quantities(backend):
    hists = make_pool("type1", n_clients=60)
    res = SCHEDULERS[backend](hists, n=10, delta=3, x_star=3)
    rep = F.fairness_report(res, list(hists), x_star=3)
    assert rep["coverage"] and rep["bounded"]
    assert 0.0 < rep["jain_index"] <= 1.0
    assert rep["max_count"] <= 3
    assert rep["rounds"] == res.num_rounds


def test_single_and_empty_pools():
    for backend in SCHEDULERS.values():
        res = backend({0: np.array([10.0, 0.0])}, n=10, delta=3)
        assert res.subsets == [[0]]
        res = backend({}, n=10, delta=3)
        assert res.subsets == []
    for name in P.available_scheduling_policies():
        res = policy_schedule(name, {0: np.array([10.0, 0.0])},
                              n=10, delta=3, x_star=3)
        assert res.subsets == [[0]], name
        res = policy_schedule(name, {}, n=10, delta=3, x_star=3)
        assert res.subsets == [], name


# ---------------------------------------------------------------------------
# ISSUE-5: every registered scheduling policy upholds the §VII guarantee
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", P.available_scheduling_policies())
@pytest.mark.parametrize("kind", ["type1", "type2", "type3", "iid"])
def test_registered_policies_paper_pools(name, kind):
    hists = make_pool(kind, n_clients=70)
    res = policy_schedule(name, hists, n=10, delta=3, x_star=3)
    check_guarantees(res, hists, n=10, delta=3, x_star=3)


@pytest.mark.parametrize("name", P.available_scheduling_policies())
def test_registered_policies_randomized_pools(name):
    rng = np.random.default_rng(1)
    for trial in range(8):
        Pn = int(rng.integers(5, 80))
        c = int(rng.integers(2, 12))
        hists = {i: h for i, h in
                 enumerate(random_histograms(Pn, c, rng))}
        n = int(rng.integers(3, 14))
        delta = int(rng.integers(0, 4))
        x_star = int(rng.integers(1, 5))
        res = policy_schedule(name, hists, n, delta, x_star, seed=trial)
        check_guarantees(res, hists, n, delta, x_star)


# ---------------------------------------------------------------------------
# ISSUE-9: the §VII guarantees hold over the federated-LM bundle — the
# transformer task's real partition histograms (latent bigram classes),
# not just the paper's synthetic pool types
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_hists():
    from repro.fl.partition import client_histograms
    from repro.fl.transformer_task import make_transformer_fl
    b = make_transformer_fl(n_clients=24, n_train=300, n_test=60, seq_len=8)
    return client_histograms(b["data"].labels, b["parts"],
                             b["data"].num_classes)


@pytest.mark.parametrize("name", P.available_scheduling_policies())
@pytest.mark.parametrize("n,delta,x_star", [(6, 2, 3), (10, 3, 2)])
def test_registered_policies_transformer_bundle(name, lm_hists, n, delta,
                                                x_star):
    res = policy_schedule(name, lm_hists, n=n, delta=delta, x_star=x_star)
    check_guarantees(res, lm_hists, n=n, delta=delta, x_star=x_star)


def test_fair_ema_guarantees_hold_with_carried_state():
    # the stateful policy must uphold the guarantee in *every* period,
    # not only from a cold start — drive 5 periods with the EMA state
    # persisting, checking each drawn schedule
    hists = make_pool("type2", n_clients=45)
    state = {}
    cumulative = {k: 0 for k in hists}
    for period in range(5):
        res = policy_schedule("fair_ema", hists, n=8, delta=2, x_star=3,
                              state=state)
        check_guarantees(res, hists, n=8, delta=2, x_star=3)
        for k, v in res.counts.items():
            cumulative[k] += v
    # the EMA penalty keeps long-run participation tight: with 5
    # periods of compensation the cumulative spread stays bounded and
    # the Jain index beats what a worst-case x*-skewed schedule allows
    counts = np.array(sorted(cumulative.values()), dtype=np.float64)
    assert counts.max() - counts.min() <= 5
    assert F.jain_index(counts) > 0.9
