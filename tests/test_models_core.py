"""Model-layer correctness: chunked GLA vs sequential recurrence, MoE
scatter dispatch vs dense oracle, attention masks, cache consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import transformer as T


def rk(i=0):
    return jax.random.PRNGKey(i)


class TestGatedLinearAttention:
    @pytest.mark.parametrize("normalize", [True, False])
    @pytest.mark.parametrize("seq,chunk", [(16, 4), (17, 4), (32, 32), (7, 16)])
    def test_chunked_matches_sequential(self, normalize, seq, chunk):
        B, H, dk, dv = 2, 3, 8, 5
        ks = jax.random.split(rk(0), 6)
        q = jax.random.normal(ks[0], (B, seq, H, dk))
        k = jax.random.normal(ks[1], (B, seq, H, dk)) * 0.5
        v = jax.random.normal(ks[2], (B, seq, H, dv))
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, seq, H)) + 2.0)
        log_i = jax.random.normal(ks[4], (B, seq, H)) * 0.5
        li = log_i if normalize else None

        out, final = S.gated_linear_attention(q, k, v, log_f, li, chunk=chunk,
                                              normalize=normalize)
        # sequential oracle via the decode step
        state = {"S": jnp.zeros((B, H, dk, dv)), "n": jnp.zeros((B, H, dk)),
                 "m": jnp.zeros((B, H))}
        outs = []
        for t in range(seq):
            li_t = log_i[:, t] if normalize else None
            y, state = S.gla_decode_step(q[:, t], k[:, t], v[:, t],
                                         log_f[:, t], li_t, state,
                                         normalize=normalize)
            outs.append(y)
        seq_out = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(seq_out),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final["S"] * jnp.exp(final["m"])[..., None, None]),
                                   np.asarray(state["S"] * jnp.exp(state["m"])[..., None, None]),
                                   rtol=2e-4, atol=2e-4)

    def test_initial_state_continuation(self):
        """Processing [a; b] == processing a then b with carried state."""
        B, H, dk, dv, S1, S2 = 1, 2, 4, 4, 12, 8
        ks = jax.random.split(rk(1), 5)
        q = jax.random.normal(ks[0], (B, S1 + S2, H, dk))
        k = jax.random.normal(ks[1], (B, S1 + S2, H, dk))
        v = jax.random.normal(ks[2], (B, S1 + S2, H, dv))
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S1 + S2, H)) + 1.0)
        log_i = jax.random.normal(ks[4], (B, S1 + S2, H)) * 0.3

        full, _ = S.gated_linear_attention(q, k, v, log_f, log_i, chunk=4)
        a, st = S.gated_linear_attention(q[:, :S1], k[:, :S1], v[:, :S1],
                                         log_f[:, :S1], log_i[:, :S1], chunk=4)
        b, _ = S.gated_linear_attention(q[:, S1:], k[:, S1:], v[:, S1:],
                                        log_f[:, S1:], log_i[:, S1:], chunk=4,
                                        initial_state=st)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                                   np.asarray(full), rtol=2e-4, atol=2e-4)

    def test_no_nan_extreme_gates(self):
        B, seq, H, d = 1, 32, 2, 4
        q = jnp.ones((B, seq, H, d))
        k = jnp.ones((B, seq, H, d))
        v = jnp.ones((B, seq, H, d))
        log_f = jnp.full((B, seq, H), -50.0)     # near-total forget
        log_i = jnp.full((B, seq, H), 40.0)      # huge input gate
        out, _ = S.gated_linear_attention(q, k, v, log_f, log_i, chunk=8)
        assert bool(jnp.isfinite(out).all())


class TestMoE:
    def _cfg(self, **kw):
        return get_config("qwen2-moe-a2.7b").reduced(**kw)

    def test_scatter_matches_dense_when_no_drops(self):
        import dataclasses
        cfg = dataclasses.replace(self._cfg(), capacity_factor=64.0)
        p = M.moe_params(cfg, rk(0))
        x = jax.random.normal(rk(1), (2, 16, cfg.d_model), jnp.float32)
        y, aux = M.moe_ffn(cfg, p, x)
        y_ref = M.moe_ffn_dense(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-3, atol=2e-3)
        assert float(aux) > 0

    def test_capacity_drops_are_bounded(self):
        import dataclasses
        cfg = dataclasses.replace(self._cfg(), capacity_factor=1.0)
        p = M.moe_params(cfg, rk(0))
        x = jax.random.normal(rk(1), (4, 32, cfg.d_model), jnp.float32)
        y, _ = M.moe_ffn(cfg, p, x)
        assert y.shape == x.shape and bool(jnp.isfinite(y).all())

    def test_aux_loss_balanced_router_is_one(self):
        """For a perfectly uniform router, Switch aux ≈ weight·1."""
        import dataclasses
        cfg = dataclasses.replace(self._cfg(), router_aux_weight=1.0)
        p = M.moe_params(cfg, rk(0))
        p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
        x = jax.random.normal(rk(1), (2, 64, cfg.d_model), jnp.float32)
        _, aux = M.moe_ffn(cfg, p, x)
        assert 0.9 < float(aux) < 1.1


class TestAttention:
    def test_gqa_equals_mha_when_repeated(self):
        B, Sq, H, hd = 2, 8, 4, 16
        ks = jax.random.split(rk(0), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, hd))
        k = jax.random.normal(ks[1], (B, Sq, 2, hd))
        v = jax.random.normal(ks[2], (B, Sq, 2, hd))
        out_gqa = L.dot_product_attention(q, k, v)
        k_full = jnp.repeat(k, 2, axis=2)
        v_full = jnp.repeat(v, 2, axis=2)
        out_mha = L.dot_product_attention(q, k_full, v_full)
        np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                                   rtol=1e-5, atol=1e-5)

    def test_causal_mask_blocks_future(self):
        pos = jnp.arange(6)[None]
        m = L.attention_mask(pos, pos, causal=True, window=0)[0]
        assert bool(m[0, 0]) and not bool(m[0, 5]) and bool(m[5, 0])

    def test_window_mask(self):
        pos = jnp.arange(10)[None]
        m = L.attention_mask(pos, pos, causal=True, window=3)[0]
        assert bool(m[5, 5]) and bool(m[5, 3]) and not bool(m[5, 2])

    def test_rope_relative_property(self):
        """RoPE scores depend only on relative distance."""
        hd = 32
        x = jax.random.normal(rk(0), (1, 1, 1, hd))
        y = jax.random.normal(rk(1), (1, 1, 1, hd))
        def score(p_q, p_k):
            q = L.apply_rope(x, jnp.array([[p_q]]), 10000.0)
            k = L.apply_rope(y, jnp.array([[p_k]]), 10000.0)
            return float(jnp.sum(q * k))
        assert score(3, 1) == pytest.approx(score(10, 8), rel=1e-5)
        assert score(3, 1) != pytest.approx(score(3, 2), rel=1e-3)

    def test_ring_cache_build_and_attend(self):
        """build_kv_cache ring layout + cache_attend == direct windowed attn."""
        B, Ss, G, hd, W = 1, 12, 2, 8, 8
        ks = jax.random.split(rk(2), 3)
        k = jax.random.normal(ks[0], (B, Ss, G, hd))
        v = jax.random.normal(ks[1], (B, Ss, G, hd))
        pos = jnp.arange(Ss)[None]
        cache = L.build_kv_cache(k, v, pos, window=W)
        assert cache["k"].shape == (B, W, G, hd)
        # query at position Ss attends to last W-1 keys + itself
        q = jax.random.normal(ks[2], (B, 1, 2 * G, hd))
        cfg = get_config("smollm-360m").reduced()
        qpos = jnp.full((B, 1), Ss)
        nk = jax.random.normal(rk(3), (B, 1, G, hd))
        nv = jax.random.normal(rk(4), (B, 1, G, hd))
        o, newc = L.cache_attend(cfg, q, cache, qpos, W, new_k=nk, new_v=nv)
        # reference: direct attention over the last W tokens
        k_all = jnp.concatenate([k, nk], axis=1)[:, -(W):]
        v_all = jnp.concatenate([v, nv], axis=1)[:, -(W):]
        ref = L.dot_product_attention(q, k_all, v_all)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestNorms:
    def test_rmsnorm_unit_scale(self):
        x = jax.random.normal(rk(0), (4, 8)) * 10
        y = L.rmsnorm(x, jnp.ones(8))
        rms = jnp.sqrt(jnp.mean(y ** 2, -1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-4)

    def test_layernorm_zero_mean(self):
        x = jax.random.normal(rk(0), (4, 8)) + 5
        y = L.layernorm(x, jnp.ones(8), jnp.zeros(8))
        np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)


class TestChunkedAttention:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
    def test_matches_full(self, causal, window):
        B, S, H, G, hd = 2, 37, 4, 2, 16
        ks = jax.random.split(rk(7), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, G, hd))
        v = jax.random.normal(ks[2], (B, S, G, hd))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = L.attention_mask(pos, pos, causal, window)[:, None]
        full = L.dot_product_attention(q, k, v, mask)
        chunked = L.chunked_attention(q, k, v, pos, causal=causal,
                                      window=window, q_chunk=8)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)

    def test_grad_flows(self):
        B, S, H, hd = 1, 16, 2, 8
        q = jax.random.normal(rk(8), (B, S, H, hd))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        g = jax.grad(lambda q_: L.chunked_attention(
            q_, q_, q_, pos, causal=True, window=0, q_chunk=4).sum())(q)
        assert bool(jnp.isfinite(g).all())
