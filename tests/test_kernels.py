"""Pallas kernel validation: interpret-mode execution vs ref.py oracles,
swept over shapes and dtypes (per the deliverable-c contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fedavg_agg import fedavg_agg, fedavg_agg_quality
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.swiglu import swiglu


def rk(i):
    return jax.random.PRNGKey(i)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol(dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,G,S,hd", [
        (1, 2, 2, 32, 16),    # MHA
        (2, 4, 2, 64, 32),    # GQA rep=2
        (1, 8, 1, 48, 64),    # MQA, ragged seq vs block
    ])
    def test_causal_sweep(self, B, H, G, S, hd, dtype):
        q = jax.random.normal(rk(0), (B, H, S, hd), dtype)
        k = jax.random.normal(rk(1), (B, G, S, hd), dtype)
        v = jax.random.normal(rk(2), (B, G, S, hd), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, dtype)

    @pytest.mark.parametrize("window", [8, 16])
    def test_sliding_window(self, window):
        B, H, G, S, hd = 1, 2, 1, 64, 16
        q = jax.random.normal(rk(3), (B, H, S, hd))
        k = jax.random.normal(rk(4), (B, G, S, hd))
        v = jax.random.normal(rk(5), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        assert_close(out, expected, jnp.float32)

    def test_decode_shape_sq1(self):
        """Sq=1 against a long KV (right-aligned causal) — the serve path."""
        B, H, G, Sk, hd = 2, 4, 2, 128, 32
        q = jax.random.normal(rk(6), (B, H, 1, hd))
        k = jax.random.normal(rk(7), (B, G, Sk, hd))
        v = jax.random.normal(rk(8), (B, G, Sk, hd))
        out = flash_attention(q, k, v, causal=True, block_q=8, block_k=32,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, jnp.float32)

    def test_noncausal(self):
        B, H, G, S, hd = 1, 2, 2, 32, 16
        q = jax.random.normal(rk(9), (B, H, S, hd))
        k = jax.random.normal(rk(10), (B, G, S, hd))
        v = jax.random.normal(rk(11), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=False)
        assert_close(out, expected, jnp.float32)

    def test_ragged_seq_not_multiple_of_block(self):
        B, H, G, S, hd = 1, 2, 2, 40, 16   # 40 % 16 != 0
        q = jax.random.normal(rk(12), (B, H, S, hd))
        k = jax.random.normal(rk(13), (B, G, S, hd))
        v = jax.random.normal(rk(14), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, jnp.float32)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 256)])
    def test_sweep(self, shape, dtype):
        x = jax.random.normal(rk(0), shape, dtype) * 3
        s = jax.random.normal(rk(1), shape[-1:], dtype)
        out = rmsnorm(x, s, block_rows=4, interpret=True)
        assert_close(out, ref.rmsnorm_ref(x, s), dtype)


class TestSwiGLU:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("M,D,F", [(16, 32, 48), (7, 64, 24), (64, 128, 256)])
    def test_sweep(self, M, D, F, dtype):
        x = jax.random.normal(rk(0), (M, D), dtype)
        wg = jax.random.normal(rk(1), (D, F), dtype) * 0.1
        wu = jax.random.normal(rk(2), (D, F), dtype) * 0.1
        out = swiglu(x, wg, wu, block_m=8, block_n=16, block_k=16,
                     interpret=True)
        assert_close(out, ref.swiglu_ref(x, wg, wu), dtype)


class TestFedAvgAgg:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("K,P", [(4, 128), (13, 1000), (1, 64)])
    def test_sweep(self, K, P, dtype):
        u = jax.random.normal(rk(0), (K, P), dtype)
        w = jax.nn.softmax(jax.random.normal(rk(1), (K,)))
        out = fedavg_agg(u, w, block_p=64, interpret=True)
        assert_close(out, ref.fedavg_agg_ref(u, w), dtype)

    def test_matches_paper_weighting(self):
        """Aggregation with p_k = n_k/Σn matches manual weighted sum."""
        u = jnp.stack([jnp.ones(32), 2 * jnp.ones(32), 4 * jnp.ones(32)])
        w = jnp.array([0.5, 0.25, 0.25])
        out = fedavg_agg(u, w, block_p=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 2.0, rtol=1e-6)


class TestFedAvgAggQuality:
    """Fused aggregation+quality kernel vs the two-pass reference:
    ragged parameter axes (P % block_p != 0), small/odd K, both dtypes,
    interpret and reference modes (deliverable of ISSUE 2)."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("K,P,bp", [
        (4, 128, 64),     # aligned
        (13, 1000, 64),   # ragged P, odd K
        (1, 64, 64),      # single client
        (3, 130, 64),     # ragged tail smaller than a block
        (8, 50, 64),      # single partial block (P < block_p)
    ])
    def test_sweep_vs_ref(self, K, P, bp, dtype):
        u = jax.random.normal(rk(0), (K, P), dtype)
        w = jax.nn.softmax(jax.random.normal(rk(1), (K,)))
        agg, dots, sq, asq = fedavg_agg_quality(u, w, block_p=bp,
                                                interpret=True)
        r_agg, r_dots, r_sq, r_asq = ref.fedavg_agg_quality_ref(u, w)
        assert_close(agg, r_agg, dtype)
        assert_close(dots, r_dots, dtype)
        assert_close(sq, r_sq, dtype)
        assert_close(asq, r_asq, dtype)

    def test_matches_two_pass_cosine(self):
        """q from the fused outputs == cosine(delta_k, tree_weighted_sum)
        computed the legacy way (f32 accumulate tolerance)."""
        K, P = 6, 333
        u = jax.random.normal(rk(2), (K, P))
        w = jax.nn.softmax(jax.random.normal(rk(3), (K,)))
        agg, dots, sq, asq = fedavg_agg_quality(u, w, block_p=128,
                                                interpret=True)
        q = dots / jnp.maximum(jnp.sqrt(sq) * jnp.sqrt(asq), 1e-12)
        ref_agg = ref.fedavg_agg_ref(u, w).astype(jnp.float32)
        ref_q = (u.astype(jnp.float32) @ ref_agg) / jnp.maximum(
            jnp.linalg.norm(u, axis=1) * jnp.linalg.norm(ref_agg), 1e-12)
        assert_close(agg, ref_agg, jnp.float32)
        assert_close(q, ref_q, jnp.float32)

    def test_agg_consistent_with_plain_kernel(self):
        K, P = 5, 200
        u = jax.random.normal(rk(4), (K, P))
        w = jax.nn.softmax(jax.random.normal(rk(5), (K,)))
        agg, *_ = fedavg_agg_quality(u, w, block_p=64, interpret=True)
        plain = fedavg_agg(u, w, block_p=64, interpret=True)
        assert_close(agg, plain, jnp.float32)


class TestMLSTMScan:
    @pytest.mark.parametrize("normalize", [True, False])
    @pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (16, 16)])
    def test_sweep(self, S, chunk, normalize):
        B, H, dk, dv = 2, 3, 16, 8
        q = jax.random.normal(rk(0), (B, H, S, dk))
        k = jax.random.normal(rk(1), (B, H, S, dk)) * 0.3
        v = jax.random.normal(rk(2), (B, H, S, dv))
        log_f = jax.nn.log_sigmoid(jax.random.normal(rk(3), (B, H, S)) + 2)
        log_i = (jax.random.normal(rk(4), (B, H, S)) * 0.5) if normalize else None
        out = mlstm_scan(q, k, v, log_f, log_i, chunk=chunk,
                         normalize=normalize, interpret=True)
        expected = ref.mlstm_scan_ref(q, k, v, log_f, log_i, chunk=chunk,
                                      normalize=normalize)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=5e-4, atol=5e-4)

    def test_bfloat16(self):
        B, H, S, d = 1, 2, 32, 8
        q = jax.random.normal(rk(0), (B, H, S, d), jnp.bfloat16)
        k = jax.random.normal(rk(1), (B, H, S, d), jnp.bfloat16)
        v = jax.random.normal(rk(2), (B, H, S, d), jnp.bfloat16)
        log_f = jax.nn.log_sigmoid(jax.random.normal(rk(3), (B, H, S)) + 2)
        out = mlstm_scan(q, k, v, log_f, None, chunk=8, normalize=False,
                         interpret=True)
        expected = ref.mlstm_scan_ref(q, k, v, log_f, None, chunk=8,
                                      normalize=False)
        assert_close(out, expected, jnp.bfloat16)
