"""Pallas kernel validation: interpret-mode execution vs ref.py oracles,
swept over shapes and dtypes (per the deliverable-c contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fedavg_agg import fedavg_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_scan import mlstm_scan
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.swiglu import swiglu


def rk(i):
    return jax.random.PRNGKey(i)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol(dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,G,S,hd", [
        (1, 2, 2, 32, 16),    # MHA
        (2, 4, 2, 64, 32),    # GQA rep=2
        (1, 8, 1, 48, 64),    # MQA, ragged seq vs block
    ])
    def test_causal_sweep(self, B, H, G, S, hd, dtype):
        q = jax.random.normal(rk(0), (B, H, S, hd), dtype)
        k = jax.random.normal(rk(1), (B, G, S, hd), dtype)
        v = jax.random.normal(rk(2), (B, G, S, hd), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, dtype)

    @pytest.mark.parametrize("window", [8, 16])
    def test_sliding_window(self, window):
        B, H, G, S, hd = 1, 2, 1, 64, 16
        q = jax.random.normal(rk(3), (B, H, S, hd))
        k = jax.random.normal(rk(4), (B, G, S, hd))
        v = jax.random.normal(rk(5), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        assert_close(out, expected, jnp.float32)

    def test_decode_shape_sq1(self):
        """Sq=1 against a long KV (right-aligned causal) — the serve path."""
        B, H, G, Sk, hd = 2, 4, 2, 128, 32
        q = jax.random.normal(rk(6), (B, H, 1, hd))
        k = jax.random.normal(rk(7), (B, G, Sk, hd))
        v = jax.random.normal(rk(8), (B, G, Sk, hd))
        out = flash_attention(q, k, v, causal=True, block_q=8, block_k=32,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, jnp.float32)

    def test_noncausal(self):
        B, H, G, S, hd = 1, 2, 2, 32, 16
        q = jax.random.normal(rk(9), (B, H, S, hd))
        k = jax.random.normal(rk(10), (B, G, S, hd))
        v = jax.random.normal(rk(11), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=False)
        assert_close(out, expected, jnp.float32)

    def test_ragged_seq_not_multiple_of_block(self):
        B, H, G, S, hd = 1, 2, 2, 40, 16   # 40 % 16 != 0
        q = jax.random.normal(rk(12), (B, H, S, hd))
        k = jax.random.normal(rk(13), (B, G, S, hd))
        v = jax.random.normal(rk(14), (B, G, S, hd))
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        assert_close(out, expected, jnp.float32)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 256)])
    def test_sweep(self, shape, dtype):
        x = jax.random.normal(rk(0), shape, dtype) * 3
        s = jax.random.normal(rk(1), shape[-1:], dtype)
        out = rmsnorm(x, s, block_rows=4, interpret=True)
        assert_close(out, ref.rmsnorm_ref(x, s), dtype)


class TestSwiGLU:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("M,D,F", [(16, 32, 48), (7, 64, 24), (64, 128, 256)])
    def test_sweep(self, M, D, F, dtype):
        x = jax.random.normal(rk(0), (M, D), dtype)
        wg = jax.random.normal(rk(1), (D, F), dtype) * 0.1
        wu = jax.random.normal(rk(2), (D, F), dtype) * 0.1
        out = swiglu(x, wg, wu, block_m=8, block_n=16, block_k=16,
                     interpret=True)
        assert_close(out, ref.swiglu_ref(x, wg, wu), dtype)


class TestFedAvgAgg:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("K,P", [(4, 128), (13, 1000), (1, 64)])
    def test_sweep(self, K, P, dtype):
        u = jax.random.normal(rk(0), (K, P), dtype)
        w = jax.nn.softmax(jax.random.normal(rk(1), (K,)))
        out = fedavg_agg(u, w, block_p=64, interpret=True)
        assert_close(out, ref.fedavg_agg_ref(u, w), dtype)

    def test_matches_paper_weighting(self):
        """Aggregation with p_k = n_k/Σn matches manual weighted sum."""
        u = jnp.stack([jnp.ones(32), 2 * jnp.ones(32), 4 * jnp.ones(32)])
        w = jnp.array([0.5, 0.25, 0.25])
        out = fedavg_agg(u, w, block_p=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 2.0, rtol=1e-6)


class TestMLSTMScan:
    @pytest.mark.parametrize("normalize", [True, False])
    @pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (16, 16)])
    def test_sweep(self, S, chunk, normalize):
        B, H, dk, dv = 2, 3, 16, 8
        q = jax.random.normal(rk(0), (B, H, S, dk))
        k = jax.random.normal(rk(1), (B, H, S, dk)) * 0.3
        v = jax.random.normal(rk(2), (B, H, S, dv))
        log_f = jax.nn.log_sigmoid(jax.random.normal(rk(3), (B, H, S)) + 2)
        log_i = (jax.random.normal(rk(4), (B, H, S)) * 0.5) if normalize else None
        out = mlstm_scan(q, k, v, log_f, log_i, chunk=chunk,
                         normalize=normalize, interpret=True)
        expected = ref.mlstm_scan_ref(q, k, v, log_f, log_i, chunk=chunk,
                                      normalize=normalize)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=5e-4, atol=5e-4)

    def test_bfloat16(self):
        B, H, S, d = 1, 2, 32, 8
        q = jax.random.normal(rk(0), (B, H, S, d), jnp.bfloat16)
        k = jax.random.normal(rk(1), (B, H, S, d), jnp.bfloat16)
        v = jax.random.normal(rk(2), (B, H, S, d), jnp.bfloat16)
        log_f = jax.nn.log_sigmoid(jax.random.normal(rk(3), (B, H, S)) + 2)
        out = mlstm_scan(q, k, v, log_f, None, chunk=8, normalize=False,
                         interpret=True)
        expected = ref.mlstm_scan_ref(q, k, v, log_f, None, chunk=8,
                                      normalize=False)
        assert_close(out, expected, jnp.bfloat16)
