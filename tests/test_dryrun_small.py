"""Dry-run machinery tests.

The full 512-device dry-run runs via ``python -m repro.launch.dryrun``
(artifacts under artifacts/dryrun). Here we prove the machinery itself
in-process-cheap ways: the HLO collective parser on fixture text, the
roofline arithmetic, and (marked slow) a subprocess dry-run on an 8-device
4x2 mesh for one arch per family.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline as R

HLO_FIXTURE = """
HloModule test
fused_computation {
  ROOT %x = f32[8,128]{1,0} add(f32[8,128]{1,0} %a, f32[8,128]{1,0} %b)
}
ENTRY main {
  %ag = bf16[16,4096,384]{2,1,0} all-gather(bf16[16,4096,24]{2,1,0} %p), dimensions={2}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %q), to_apply=%sum
  %ars = f32[512]{0} reduce-scatter(f32[1024]{0} %q), dimensions={0}
  %a2a = (f32[64]{0}, f32[64]{0}) all-to-all(f32[64]{0} %r, f32[64]{0} %s)
  %cp = u32[2]{0} collective-permute(u32[2]{0} %t), source_target_pairs={{0,1}}
  %ag2 = bf16[128]{0} all-gather-start(bf16[8]{0} %u), dimensions={0}
  %agd = bf16[128]{0} all-gather-done(bf16[128]{0} %ag2)
}
"""


class TestCollectiveParser:
    def test_counts_and_bytes(self):
        out = R.collective_bytes(HLO_FIXTURE)
        assert out["counts"]["all-gather"] == 2     # incl. -start, not -done
        assert out["counts"]["all-reduce"] == 1
        assert out["counts"]["reduce-scatter"] == 1
        assert out["counts"]["all-to-all"] == 1
        assert out["counts"]["collective-permute"] == 1
        assert out["bytes"]["all-gather"] == 16 * 4096 * 384 * 2 + 128 * 2
        assert out["bytes"]["all-reduce"] == 1024 * 4
        assert out["bytes"]["all-to-all"] == 2 * 64 * 4   # tuple shape
        assert out["total_bytes"] == sum(out["bytes"].values())

    def test_shape_bytes(self):
        assert R.shape_bytes("bf16[2,3]") == 12
        assert R.shape_bytes("f32[10]{0}") == 40
        assert R.shape_bytes("(f32[4], s32[2])") == 24
        assert R.shape_bytes("pred[8]") == 8

    def test_derive_terms(self):
        cost = {"flops": 197e12, "bytes accessed": 819e9}
        coll = {"total_bytes": 25e9}
        t = R.derive_terms(cost, coll, chips=4, model_flops_global=4 * 197e12)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.collective_s == pytest.approx(0.5)
        assert t.bottleneck in ("compute", "memory")
        assert t.useful_ratio == pytest.approx(1.0)


FAMILY_REPS = ["smollm-360m", "qwen2-moe-a2.7b", "hymba-1.5b", "xlstm-125m",
               "whisper-large-v3"]


@pytest.mark.dryrun
@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_dryrun_subprocess_small_mesh(arch, tmp_path):
    """One family representative each: lower+compile train_4k on a 4x2
    8-host-device mesh in a subprocess (XLA_FLAGS isolation)."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8", REPRO_MESH="4,2",
               PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", "train_4k", "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=900)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    art = json.load(open(tmp_path / f"{arch}__train_4k__4x2.json"))
    assert art["ok"]
    assert art["roofline"]["flops"] > 0
    assert art["roofline"]["bottleneck"] in ("compute", "memory", "collective")


def test_production_artifacts_if_present():
    """When the full dry-run has been run, every single-pod artifact must
    be ok and the multi-pod pass present."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "dryrun")
    if not os.path.isdir(art_dir):
        pytest.skip("no artifacts yet")
    files = [f for f in os.listdir(art_dir) if f.endswith(".json")]
    if not files:
        pytest.skip("no artifacts yet")
    bad = []
    for f in files:
        r = json.load(open(os.path.join(art_dir, f)))
        if not r.get("ok"):
            bad.append((f, r.get("error", "")[:100]))
    assert not bad, bad
