"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED variant of the
same family (2 layers, d_model<=512, <=4 experts), run one forward and
one train step on CPU, assert output shapes and no NaNs; then check
prefill+decode consistency against the full forward where the family
supports exact equivalence.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.models.common import count_params
from repro.optim import adam, apply_updates

B, S = 2, 24


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size),
        "weights": jnp.array([0.25, 0.75]),
    }
    if cfg.family == "vlm" and cfg.frontend_seq:
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (B, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            ks[3], (B, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_setups():
    return {}


def setup_arch(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(hash(arch) % 2 ** 31))
    return cfg, params


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_reduced_constraints(self, arch):
        cfg = get_config(arch).reduced()
        assert cfg.num_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.num_experts <= 4

    def test_forward_shapes_and_finite(self, arch):
        cfg, params = setup_arch(arch)
        batch = make_batch(cfg, jax.random.PRNGKey(0))
        extras = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
        logits, aux = T.forward(cfg, params, batch["tokens"], extras)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
        assert count_params(params) > 0

    def test_one_train_step(self, arch):
        cfg, params = setup_arch(arch)
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        opt = adam(1e-3)
        opt_state = opt.init(params)

        @jax.jit
        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                functools.partial(T.loss_fn, cfg), has_aux=True)(params, batch)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss, metrics

        p1, opt_state, loss1, m1 = step(params, opt_state, batch)
        p2, _, loss2, _ = step(p1, opt_state, batch)
        assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2))
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), params, p1)
        assert max(jax.tree_util.tree_leaves(moved)) > 0
        # one more step on the same batch should (almost always) reduce loss
        assert float(loss2) < float(loss1) + 0.1

    def test_prefill_decode_consistency(self, arch):
        """Decode logits at position S must match the forward pass's last
        position (exact for attention archs, loose for recurrent).

        MoE archs use a no-drop capacity factor here: with finite capacity
        the dropped-token set legitimately differs between the B·S and
        B·(S-1) token populations, so exact equivalence only holds without
        drops."""
        import dataclasses
        cfg, params = setup_arch(arch)
        if cfg.is_moe:
            cfg = dataclasses.replace(cfg, capacity_factor=64.0)
        batch = make_batch(cfg, jax.random.PRNGKey(2))
        extras = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
        toks = batch["tokens"]
        logits_full, _ = T.forward(cfg, params, toks, extras)

        logits_pre, cache, memory = T.prefill(cfg, params, toks[:, :-1], extras)
        # prefill last-token logits == forward at position S-2
        tol = dict(rtol=2e-3, atol=2e-3)
        if cfg.family == "vlm":
            # vision prefix shifts positions; compare decode only
            pass
        else:
            np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                                       np.asarray(logits_full[:, -2]), **tol)
        cache = T.grow_cache(cfg, cache, extra=1)
        n_prefix = cfg.frontend_seq if cfg.family == "vlm" else 0
        logits_dec, _ = T.decode_step(cfg, params, toks[:, -1:], cache,
                                      jnp.asarray(S - 1 + n_prefix), memory)
        np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                                   np.asarray(logits_full[:, -1]), **tol)

    def test_decode_cache_shapes(self, arch):
        cfg, _ = setup_arch(arch)
        cache = T.init_decode_cache(cfg, B, 32)
        leaves = jax.tree_util.tree_leaves(cache)
        assert all(bool(jnp.isfinite(x).all()) for x in leaves
                   if jnp.issubdtype(x.dtype, jnp.floating))
