"""FL runtime tests: partitions, federated rounds, optimizer, data,
checkpointing."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.core.criteria import nid
from repro.data import make_classification_data, make_lm_data
from repro.fl import (client_histograms, make_fl_round, partition_labels,
                      tree_weighted_sum)
from repro.models import cnn
from repro.optim import adam, apply_updates, global_norm, sgd, warmup_cosine


class TestPartition:
    @pytest.mark.parametrize("kind,max_labels", [("type1", 1), ("type2", 2),
                                                 ("type3", 3)])
    def test_label_counts_per_type(self, kind, max_labels):
        labels = np.random.default_rng(0).integers(0, 10, 5000)
        parts = partition_labels(labels, 50, kind, 10, seed=1)
        hists = client_histograms(labels, parts, 10)
        for h in hists.values():
            assert np.count_nonzero(h) <= max_labels
            assert h.sum() > 0

    def test_type2_ratio(self):
        labels = np.random.default_rng(0).integers(0, 10, 20000)
        parts = partition_labels(labels, 20, "type2", 10, seed=2,
                                 samples_per_client=100)
        hists = client_histograms(labels, parts, 10)
        for h in hists.values():
            top = np.sort(h)[::-1]
            assert top[0] / h.sum() == pytest.approx(0.9, abs=0.05)

    def test_iid_partition_low_nid(self):
        labels = np.random.default_rng(0).integers(0, 10, 10000)
        parts = partition_labels(labels, 20, "iid", 10, seed=3)
        hists = client_histograms(labels, parts, 10)
        for h in hists.values():
            assert nid(h) < 0.2


class TestSyntheticData:
    def test_classification_learnable_shapes(self):
        d = make_classification_data("mnist", 256, seed=0)
        assert d.images.shape == (256, 28, 28, 1)
        assert d.images.min() >= 0 and d.images.max() <= 1
        d2 = make_classification_data("cifar", 64, seed=0)
        assert d2.images.shape == (64, 32, 32, 3)

    def test_lm_data_predictable(self):
        d = make_lm_data(16, 32, 64, seed=0)
        assert d.tokens.shape == (16, 33)
        assert d.tokens.max() < 64

    def test_cnn_learns_synthetic(self):
        """Sanity: a few SGD steps reduce loss on the synthetic task."""
        d = make_classification_data("mnist", 512, seed=0)
        cfg = cnn.MNIST_CNN
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        opt = adam(3e-3)
        state = opt.init(params)
        batch = {"images": jnp.asarray(d.images[:128]),
                 "labels": jnp.asarray(d.labels[:128])}

        @jax.jit
        def step(p, s):
            (l, m), g = jax.value_and_grad(
                lambda p_: cnn.loss_fn(cfg, p_, batch), has_aux=True)(p)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, l

        losses = []
        for _ in range(30):
            params, state, l = step(params, state)
            losses.append(float(l))
        assert losses[-1] < losses[0] * 0.5


class TestOptim:
    def test_adam_converges_quadratic(self):
        params = {"x": jnp.array([3.0, -2.0])}
        opt = adam(0.1)
        s = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            u, s = opt.update(g, s, params)
            params = apply_updates(params, u)
        assert float(jnp.abs(params["x"]).max()) < 1e-2

    def test_sgd_momentum_matches_manual(self):
        opt = sgd(0.1, momentum=0.9)
        p = {"w": jnp.array(1.0)}
        s = opt.init(p)
        g = {"w": jnp.array(2.0)}
        u1, s = opt.update(g, s, p)
        assert float(u1["w"]) == pytest.approx(-0.2)
        u2, s = opt.update(g, s, p)
        assert float(u2["w"]) == pytest.approx(-0.1 * (0.9 * 2 + 2))

    def test_grad_clip(self):
        opt = adam(1.0, grad_clip=1.0)
        p = {"w": jnp.ones(4)}
        s = opt.init(p)
        g = {"w": jnp.full(4, 100.0)}
        u, s = opt.update(g, s, p)
        assert float(global_norm(g)) > 1.0
        assert bool(jnp.isfinite(u["w"]).all())

    def test_warmup_cosine(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(1))) == pytest.approx(0.1)
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


class TestFLRound:
    def _setup(self):
        cfg = cnn.MNIST_CNN
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        round_fn = make_fl_round(lambda p, b: cnn.loss_fn(cfg, p, b),
                                 local_lr=0.05, local_steps=2)
        d = make_classification_data("mnist", 4 * 2 * 8, seed=0)
        batches = {
            "images": jnp.asarray(d.images.reshape(4, 2, 8, 28, 28, 1)),
            "labels": jnp.asarray(d.labels.reshape(4, 2, 8)),
        }
        return params, round_fn, batches

    def test_round_updates_params_and_q(self):
        params, round_fn, batches = self._setup()
        w = jnp.full(4, 0.25)
        mask = jnp.ones(4)
        new_params, info = round_fn(params, batches, w, mask)
        diff = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                                      params, new_params)
        assert max(jax.tree_util.tree_leaves(diff)) > 0
        q = np.asarray(info["q_values"])
        assert q.shape == (4,)
        assert np.all(q > 0.2)  # same-task clients: deltas roughly aligned

    def test_dropped_client_excluded(self):
        params, round_fn, batches = self._setup()
        w = jnp.full(4, 0.25)
        mask = jnp.array([1.0, 1.0, 1.0, 0.0])
        p_a, info_a = round_fn(params, batches, w, mask)
        # manually zero client 3's data -> same aggregate
        b2 = jax.tree_util.tree_map(lambda x: x.at[3].set(x[2]), batches)
        p_b, _ = round_fn(params, b2, w, mask)
        diff = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            p_a, p_b)
        assert max(jax.tree_util.tree_leaves(diff)) < 1e-6
        assert float(info_a["q_values"][3]) == 0.0

    def test_weighted_sum_kernel_path(self):
        trees = {"a": jnp.arange(12.0).reshape(3, 4)}
        w = jnp.array([0.5, 0.3, 0.2])
        plain = tree_weighted_sum(trees, w, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(plain["a"]),
            np.asarray(0.5 * trees["a"][0] + 0.3 * trees["a"][1]
                       + 0.2 * trees["a"][2]), rtol=1e-6)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16),
                      "d": jnp.array(3, jnp.int32)},
                "lst": [jnp.zeros(2), jnp.ones(2)]}
        p = str(tmp_path / "x.ckpt")
        save(p, tree)
        back = restore(p, tree)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))

    def test_restore_dict_exact_numpy_dtypes(self, tmp_path):
        from repro.checkpoint import restore_dict
        tree = {"f64": np.arange(4, dtype=np.float64),
                "i64": np.array([2**60 + 1], dtype=np.int64),
                "u64": np.array([2**64 - 1], dtype=np.uint64),
                "bf16": jnp.ones(3, jnp.bfloat16)}
        p = str(tmp_path / "flat.ckpt")
        save(p, tree)
        back = restore_dict(p)
        # exact dtypes, mutable numpy leaves (no jnp truncation to 32 bit)
        assert back["f64"].dtype == np.float64
        assert int(back["i64"][0]) == 2**60 + 1
        assert int(back["u64"][0]) == 2**64 - 1
        assert isinstance(back["f64"], np.ndarray)
        assert isinstance(back["bf16"], np.ndarray)
        assert str(back["bf16"].dtype) == "bfloat16"
        back["f64"][0] = -1.0          # numpy contract: writable

    def test_restore_warns_on_dtype_narrowing(self, tmp_path):
        # ISSUE-5 satellite: the jnp path used to truncate f64 -> f32
        # silently under x64=off; it must now say so and point at the
        # exact-dtype restore_dict, so the two entry points can't
        # disagree without a trace
        from repro.checkpoint import reset_narrowing_warnings, restore_dict
        reset_narrowing_warnings()   # the warning dedups per run (ISSUE-9)
        p = str(tmp_path / "f64.ckpt")
        save(p, {"x": np.arange(3, dtype=np.float64),
                 "y": jnp.zeros(2, jnp.float32)})
        with pytest.warns(UserWarning, match="restore_dict"):
            back = restore(p, {"x": jnp.zeros(3), "y": jnp.zeros(2)})
        assert back["x"].dtype == jnp.float32      # narrowed, but loudly
        with warnings.catch_warnings():            # exact path: silent
            warnings.simplefilter("error")
            assert restore_dict(p)["x"].dtype == np.float64

    def test_narrowing_warns_once_per_run(self, tmp_path):
        # ISSUE-9 satellite: a service restoring the same state layout
        # every period used to re-emit the identical warning on every
        # restore; it now fires once per run per narrowed-key set
        from repro.checkpoint import reset_narrowing_warnings
        reset_narrowing_warnings()
        p = str(tmp_path / "f64.ckpt")
        save(p, {"x": np.arange(3, dtype=np.float64)})
        like = {"x": jnp.zeros(3)}
        with pytest.warns(UserWarning, match="restore_dict"):
            restore(p, like)
        with warnings.catch_warnings():            # same layout: silent
            warnings.simplefilter("error")
            restore(p, like)
        # a *different* narrowed-key set still warns once
        p2 = str(tmp_path / "i64.ckpt")
        save(p2, {"n": np.arange(4, dtype=np.int64)})
        with pytest.warns(UserWarning, match="restore_dict"):
            restore(p2, {"n": jnp.zeros(4, jnp.int32)})
        # and the reset hook re-arms the first layout
        reset_narrowing_warnings()
        with pytest.warns(UserWarning, match="restore_dict"):
            restore(p, like)

    def test_restore_silent_when_dtypes_match(self, tmp_path):
        p = str(tmp_path / "f32.ckpt")
        tree = {"w": jnp.ones(3, jnp.float32), "b": jnp.ones(2, jnp.bfloat16)}
        save(p, tree)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            restore(p, tree)

    def test_shape_mismatch_raises(self, tmp_path):
        p = str(tmp_path / "x.ckpt")
        save(p, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            restore(p, {"a": jnp.zeros(4)})
        with pytest.raises(KeyError):
            restore(p, {"zz": jnp.zeros(3)})

    def test_manager_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.zeros(2)}
        for s in range(5):
            mgr.save(s, jax.tree_util.tree_map(lambda x: x + s, tree))
        assert mgr.steps() == [3, 4]
        step, back = mgr.restore_latest(tree)
        assert step == 4
        np.testing.assert_allclose(np.asarray(back["w"]), 4.0)
