"""Hypothesis property-based tests for the control plane's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dep: hypothesis not installed")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import criteria as C
from repro.core import mkp as M
from repro.core import scheduling as Sch
from repro.core import selection as S

hist_strategy = hnp.arrays(
    dtype=np.float64, shape=st.tuples(st.integers(2, 12)),
    elements=st.floats(0, 1000, allow_nan=False))


@settings(max_examples=200, deadline=None)
@given(hist_strategy)
def test_nid_in_unit_interval(h):
    v = float(C.nid(h))
    assert 0.0 <= v <= 1.0


@settings(max_examples=200, deadline=None)
@given(hist_strategy)
def test_nid_scale_invariant(h):
    """Nid(αh) == Nid(h) for α>0 — it is a distribution property."""
    if h.sum() > 0:
        np.testing.assert_allclose(C.nid(h * 3.7), C.nid(h), atol=1e-9)


@settings(max_examples=100, deadline=None)
@given(hist_strategy, hist_strategy)
def test_nid_variants_agree_on_extremes(h1, h2):
    for fn in (C.nid, C.nid_l2, C.nid_hellinger, C.nid_kl):
        v = fn(h1)
        assert -1e-9 <= float(v) <= 1 + 1e-9


knapsack = st.integers(3, 25).flatmap(lambda n: st.tuples(
    hnp.arrays(np.float64, n, elements=st.floats(0.1, 50, allow_nan=False)),
    hnp.arrays(np.float64, n, elements=st.floats(1, 30, allow_nan=False)),
    st.floats(5, 200)))


@settings(max_examples=60, deadline=None)
@given(knapsack)
def test_greedy_selection_budget_and_bound(args):
    scores, costs, B = args
    g = S.select_greedy(scores, costs, B)
    assert g.total_cost <= B + 1e-9
    gs = S.select_greedy(scores, costs, B, skip_unaffordable=True)
    # the beyond-paper skipping variant dominates the paper's variant
    assert gs.total_score >= g.total_score - 1e-9
    d = S.select_dp(scores, np.rint(costs), np.floor(B))
    assert d.total_score >= S.select_greedy(scores, np.rint(costs),
                                            np.floor(B)).total_score - 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 20), st.integers(2, 6), st.integers(0, 10_000))
def test_mkp_greedy_feasibility(n, m, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 20, size=(n, m)).astype(float)
    v = w.sum(axis=1) + 1.0
    c = rng.uniform(0.3, 0.8) * np.maximum(w.sum(axis=0), 1.0)
    res = M.solve_mkp_greedy(v, w, c)
    assert M.is_feasible(w, c, res.selected)
    assert len(set(res.selected)) == len(res.selected)


@settings(max_examples=25, deadline=None)
@given(st.integers(5, 60), st.integers(2, 10), st.integers(2, 8),
       st.integers(0, 3), st.integers(1, 4), st.integers(0, 10_000))
def test_schedule_invariants(n_clients, n_classes, n, delta, x_star, seed):
    """The paper's fairness guarantee holds for arbitrary pools."""
    rng = np.random.default_rng(seed)
    hists = {}
    for i in range(n_clients):
        h = np.zeros(n_classes)
        k = int(rng.integers(1, n_classes + 1))
        lab = rng.choice(n_classes, k, replace=False)
        h[lab] = rng.integers(1, 100, size=k)
        hists[i] = h
    res = Sch.generate_subsets(hists, n=n, delta=delta, x_star=x_star)
    # coverage: every client at least once
    assert set().union(*map(set, res.subsets)) == set(hists)
    # bound: at most x* times
    assert max(res.counts.values()) <= x_star
    # subsets are duplicate-free
    for s in res.subsets:
        assert len(set(s)) == len(s)
    # Nid values are valid
    assert all(0.0 <= v <= 1.0 for v in res.nids)
