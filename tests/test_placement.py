"""ISSUE-10 multi-device placement fabric: the PlacementPolicy
registry, the cost/imbalance helpers, per-device in-flight windows in
ServiceScheduler, migrate-on-imbalance over the checkpoint path
(flush -> re-place -> resume, bit-identical results), and the
mesh-sharded round scan vs the unsharded device plane.

The scheduler tests run on the default single-CPU-device jax config;
CI additionally runs this file under REPRO_HOST_DEVICES=8 (see
tools/run.sh), which un-skips the real multi-device assertions."""
import numpy as np
import pytest

from repro.core import (FLServiceProvider, PlacementPolicy, ServiceScheduler,
                        TaskPhase, TaskRequest, as_run_result,
                        available_placement_policies, drain, placement_policy,
                        random_profiles, register_placement_policy,
                        resolve_placement_policy, submit)
from repro.core import placement as placement_mod


# ---------------------------------------------------------------------------
# deterministic stub trainers (mirroring tests/test_lifecycle.py)
# ---------------------------------------------------------------------------

def _round_result(rnd, subset, fail_mod=7):
    subset = np.asarray(subset)
    returned = (subset + rnd) % fail_mod != 0
    q = np.where(returned, 0.5 + 0.4 * np.cos(subset + rnd), 0.0)
    return returned, q, {"round": rnd, "loss": 1.0 / (rnd + 1)}


def _stub(rnd, subset, weights):
    return _round_result(rnd, subset)


class AsyncChunkStub:
    """Deterministic AsyncTrainer: lazy dispatch handle, collect
    materializes."""

    chunkable = True

    def dispatch_rounds(self, start_round, subsets, weights):
        return (start_round, [list(s) for s in subsets])

    def collect(self, handle):
        start_round, subsets = handle
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]

    def run_rounds(self, start_round, subsets, weights):
        return self.collect(self.dispatch_rounds(start_round, subsets,
                                                 weights))


class PlacedAsyncStub(AsyncChunkStub):
    """AsyncChunkStub that honors the ``place_on`` hook and records the
    in-flight depth per device in a shared ``fleet`` dict."""

    def __init__(self, fleet):
        self.fleet = fleet           # device -> {"inflight", "max"}
        self.device = None           # set by the scheduler's place_on

    def place_on(self, device_index):
        self.device = int(device_index)

    def dispatch_rounds(self, start_round, subsets, weights):
        r = self.fleet.setdefault(self.device, {"inflight": 0, "max": 0})
        r["inflight"] += 1
        r["max"] = max(r["max"], r["inflight"])
        return (self.device, start_round, [list(s) for s in subsets])

    def collect(self, handle):
        device, start_round, subsets = handle
        self.fleet[device]["inflight"] -= 1
        return [_round_result(start_round + j, s)
                for j, s in enumerate(subsets)]


def _profiles(n=60, seed=0):
    return random_profiles(n, 10, np.random.default_rng(seed))


def _tasks(T, max_periods=2):
    return [TaskRequest(budget=300.0 + 20 * t, n_star=5, subset_size=4,
                        subset_delta=2, max_periods=max_periods,
                        scheduler="mkp" if t % 2 else "random", seed=t)
            for t in range(T)]


def _assert_results_equal(a, b):
    """Bit-for-bit round stream + reputation equality (pool order is
    greedy-pick vs batched intake order — compared as sets)."""
    assert sorted(a.pool.selected) == sorted(b.pool.selected)
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        assert (ra.period, ra.round_index) == (rb.period, rb.round_index)
        assert ra.subset == rb.subset
        np.testing.assert_array_equal(ra.weights, rb.weights)
        assert ra.nid == rb.nid
    assert a.reputation == b.reputation


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_shipped_policies_registered(self):
        assert {"bin_pack", "round_robin"} <= \
            set(available_placement_policies())

    def test_unknown_name_lists_available(self):
        with pytest.raises(KeyError, match="bin_pack"):
            placement_policy("nope")

    def test_duplicate_name_rejected(self):
        class Dup:
            name = "bin_pack"

            def place(self, tids, n_devices, costs, loads, counts):
                return {}
        with pytest.raises(ValueError, match="already registered"):
            register_placement_policy(Dup)

    def test_non_conforming_rejected(self):
        class NoPlace:
            name = "no_place"
        with pytest.raises(TypeError, match="PlacementPolicy"):
            register_placement_policy(NoPlace)

    def test_resolve(self):
        assert resolve_placement_policy(None).name == "bin_pack"
        assert resolve_placement_policy("round_robin").name == "round_robin"
        inst = placement_policy("bin_pack")
        assert resolve_placement_policy(inst) is inst
        with pytest.raises(TypeError):
            resolve_placement_policy(42)


# ---------------------------------------------------------------------------
# cost model + shipped policies (pure numpy determinism)
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_estimate_cost_defaults(self):
        assert placement_mod.estimate_cost(None) == 1.0
        assert placement_mod.estimate_cost({}) == 1.0
        assert placement_mod.estimate_cost(
            {"obs/latency": np.array([])}) == 1.0
        assert placement_mod.estimate_cost(
            {"obs/latency": np.array([np.nan, -1.0, 0.0])}) == 1.0

    def test_estimate_cost_means_valid_samples(self):
        ps = {"obs/latency": np.array([2.0, np.nan, 4.0, -3.0])}
        assert placement_mod.estimate_cost(ps) == pytest.approx(3.0)

    def test_loads_counts_imbalance(self):
        placement = {0: 0, 1: 1, 2: 0}
        costs = {0: 2.0, 1: 1.0, 2: 1.0}
        np.testing.assert_array_equal(
            placement_mod.device_loads(placement, costs, 2), [3.0, 1.0])
        np.testing.assert_array_equal(
            placement_mod.device_counts(placement, 2), [2.0, 1.0])
        assert placement_mod.imbalance(np.array([3.0, 1.0])) == 1.5
        assert placement_mod.imbalance(np.array([])) == 1.0
        assert placement_mod.imbalance(np.zeros(4)) == 1.0


class TestShippedPolicies:
    def test_round_robin_deals_cyclically(self):
        pol = placement_policy("round_robin")
        out = pol.place([10, 11, 12, 13, 14], 3, {}, np.zeros(3),
                        np.zeros(3))
        assert out == {10: 0, 11: 1, 12: 2, 13: 0, 14: 1}

    def test_round_robin_continues_cycle_across_batches(self):
        pol = placement_policy("round_robin")
        out = pol.place([7, 8], 3, {}, np.zeros(3),
                        np.array([2.0, 1.0, 1.0]))
        assert out == {7: 1, 8: 2}

    def test_bin_pack_is_lpt(self):
        pol = placement_policy("bin_pack")
        costs = {1: 5.0, 2: 3.0, 3: 2.0, 4: 2.0}
        out = pol.place([1, 2, 3, 4], 2, costs, np.zeros(2), np.zeros(2))
        # LPT: 5 -> d0, 3 -> d1, 2 -> d1 (3 < 5), 2 -> d0 (tie -> idx 0)
        assert out == {1: 0, 2: 1, 3: 1, 4: 0}

    def test_bin_pack_respects_existing_loads(self):
        pol = placement_policy("bin_pack")
        out = pol.place([9], 2, {9: 1.0}, np.array([10.0, 0.5]),
                        np.array([1.0, 1.0]))
        assert out == {9: 1}

    def test_bin_pack_unknown_cost_defaults_to_unit(self):
        pol = placement_policy("bin_pack")
        out = pol.place([0, 1, 2, 3], 2, {}, np.zeros(2), np.zeros(2))
        assert sorted(placement_mod.device_counts(out, 2)) == [2.0, 2.0]


# ---------------------------------------------------------------------------
# ServiceScheduler: per-device windows + placement determinism
# ---------------------------------------------------------------------------

class TestSchedulerPlacement:
    def test_invalid_args_rejected(self):
        sp = FLServiceProvider(_profiles())
        with pytest.raises(ValueError, match="n_devices"):
            ServiceScheduler(sp, n_devices=0)
        with pytest.raises(ValueError, match="rebalance_threshold"):
            ServiceScheduler(sp, n_devices=2, rebalance_threshold=1.0)

    def _serial(self, profiles, tasks, trainer_factory):
        out = {}
        for tid, task in enumerate(tasks):
            sp = FLServiceProvider(profiles)
            st = submit(sp, task)
            st, _ = drain(sp, st, trainer_factory())
            out[tid] = as_run_result(st)
        return out

    @pytest.mark.parametrize("overlap", [False, True])
    def test_single_device_matches_serial(self, overlap):
        profiles = _profiles()
        tasks = _tasks(6)
        serial = self._serial(profiles, tasks, AsyncChunkStub)
        sched = ServiceScheduler(FLServiceProvider(profiles),
                                 overlap=overlap, n_devices=1,
                                 placement="bin_pack")
        for task in tasks:
            sched.submit(task, AsyncChunkStub())
        conc = sched.run()
        for tid in serial:
            _assert_results_equal(serial[tid], conc[tid])

    @pytest.mark.parametrize("n_devices,placement",
                             [(3, "bin_pack"), (3, "round_robin"),
                              (8, "bin_pack")])
    def test_multi_device_results_bit_identical(self, n_devices, placement):
        """Placement must be invisible in per-task results: any device
        count x any policy produces the 1-device round stream."""
        profiles = _profiles()
        tasks = _tasks(6)
        ref = self._serial(profiles, tasks, AsyncChunkStub)
        sched = ServiceScheduler(FLServiceProvider(profiles), overlap=True,
                                 n_devices=n_devices, placement=placement)
        for task in tasks:
            sched.submit(task, AsyncChunkStub())
        conc = sched.run()
        for tid in ref:
            _assert_results_equal(ref[tid], conc[tid])
        # every live-at-some-point tenant got a placement in range
        assert all(0 <= d < n_devices
                   for d in sched.placements().values()) or \
            not sched.placements()

    def test_placements_cover_live_tenants(self):
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 n_devices=2, placement="round_robin")
        tids = [sched.submit(t, AsyncChunkStub()) for t in _tasks(4)]
        assert sched.device_of(999) == 0          # unknown -> device 0
        sched.sweep()
        placed = sched.placements()
        assert sorted(placed) == sorted(tids)
        assert set(placed.values()) == {0, 1}     # round_robin spreads
        assert all(sched.device_of(t) == placed[t] for t in tids)

    def test_per_device_windows_bound_independently(self):
        """Each device runs its own max_inflight window: with 2 devices
        x window 2, total outstanding handles exceed a single global
        window of 2 but never exceed 2 on any one device."""
        fleet = {}
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 max_inflight=2, overlap=True, n_devices=2,
                                 placement="round_robin")
        for task in _tasks(8):
            sched.submit(task, PlacedAsyncStub(fleet))
        conc = sched.run()
        assert set(fleet) == {0, 1}               # both devices exercised
        for dev, rec in fleet.items():
            assert rec["max"] <= 2, f"device {dev} window overflowed"
            assert rec["inflight"] == 0           # fully drained
        # per-device windows admit more total in-flight than one global
        # window would (the whole point of the fabric)
        assert sum(rec["max"] for rec in fleet.values()) > 2
        ref = self._serial(_profiles(), _tasks(8),
                           lambda: PlacedAsyncStub({}))
        for tid in ref:
            _assert_results_equal(ref[tid], conc[tid])

    def test_out_of_range_placement_rejected(self):
        class Bad:
            name = "bad_device"

            def place(self, tids, n_devices, costs, loads, counts):
                return {tid: 99 for tid in tids}
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 n_devices=2, placement=Bad())
        sched.submit(_tasks(1)[0], AsyncChunkStub())
        with pytest.raises(ValueError, match="bad_device"):
            sched.sweep()


# ---------------------------------------------------------------------------
# migration: flush -> re-place -> resume over the checkpoint path
# ---------------------------------------------------------------------------

class TestMigration:
    def _inject_latency(self, sched):
        """Skew the obs/latency telemetry so tenant 0 looks 20x more
        expensive — the imbalance trigger for bin_pack re-placement."""
        for tid in sched.task_ids:
            st = sched.state(tid)
            if not st.phase.terminal:
                cost = 20.0 if tid == 0 else 1.0
                st.policy_state["obs/latency"] = np.full(8, cost)

    def _run_injected(self, profiles, tasks, **kw):
        sched = ServiceScheduler(FLServiceProvider(profiles), overlap=True,
                                 **kw)
        for task in tasks:
            sched.submit(task, AsyncChunkStub())
        for _ in range(10_000):
            if not sched.active:
                break
            sched.sweep()
            self._inject_latency(sched)
        assert not sched.active
        return sched, {tid: as_run_result(sched.state(tid))
                       for tid in sched.task_ids}

    def test_rebalance_migrates_and_preserves_results(self):
        profiles = _profiles()
        tasks = _tasks(6, max_periods=3)
        # window 1: a collected tenant parks in the ready queue at its
        # period boundary, which is exactly when it is migratable (a
        # wide-open window keeps every tenant perpetually in flight)
        _, ref = self._run_injected(profiles, tasks, n_devices=1,
                                    max_inflight=1)
        sched, got = self._run_injected(profiles, tasks, n_devices=3,
                                        max_inflight=1,
                                        placement="bin_pack",
                                        rebalance_threshold=1.2)
        assert sched.migrations >= 1
        for tid in ref:
            _assert_results_equal(ref[tid], got[tid])

    def test_midperiod_tenants_are_not_movable(self):
        """rebalance() only moves boundary-parked tenants: right after
        an overlapped sweep every live tenant has a chunk in flight, so
        a manual rebalance moves nothing."""
        sched = ServiceScheduler(FLServiceProvider(_profiles()),
                                 overlap=True, n_devices=3,
                                 placement="bin_pack")
        for task in _tasks(6):
            sched.submit(task, AsyncChunkStub())
        sched.sweep()
        before = sched.placements()
        assert any(sched.state(t).pending is not None
                   for t in sched.task_ids)
        assert sched.rebalance() == 0
        assert sched.placements() == before
        assert sched.migrations == 0

    def test_manual_rebalance_at_boundary_moves_and_rehomes_queue(self):
        """Drive one tenant to a period boundary by hand, skew its cost,
        and check the migrate path end to end: device map updated, ready
        queue re-homed, results identical to an unmigrated twin."""
        profiles = _profiles()
        task = _tasks(1)[0]
        sp = FLServiceProvider(profiles)
        ref_st = submit(sp, task)
        ref_st, _ = drain(sp, ref_st, AsyncChunkStub())
        ref = as_run_result(ref_st)

        sched = ServiceScheduler(FLServiceProvider(profiles), overlap=False,
                                 n_devices=2, placement="round_robin")
        tid = sched.submit(task, AsyncChunkStub())
        # step until the tenant parks at a period boundary
        for _ in range(10_000):
            sched.sweep()
            st = sched.state(tid)
            if st.phase in (TaskPhase.POOL_SELECTED,
                            TaskPhase.PERIOD_CHECKPOINT) \
                    and st.pending is None and st.period >= 1:
                break
        assert not st.phase.terminal
        old_dev = sched.device_of(tid)
        st.policy_state["obs/latency"] = np.full(8, 50.0)
        moved = sched.rebalance()
        # a lone tenant on a 2-device fleet re-places onto the least
        # loaded device; whether that differs from old_dev depends on
        # pinned load (none) -> bin_pack/round_robin both pick device 0
        assert moved == sched.migrations
        if moved:
            assert sched.device_of(tid) != old_dev
        sched.run()
        _assert_results_equal(ref, as_run_result(sched.state(tid)))


# ---------------------------------------------------------------------------
# mesh-sharded round scan (jax; 1-device run degenerates to n_shard=1)
# ---------------------------------------------------------------------------

class TestShardedScan:
    def _sim(self, mesh=None, dropout_rate=0.0, **kw):
        import jax  # noqa: F401  (defer jax init to test body)
        from repro.data.synthetic import make_classification_data
        from repro.fl.partition import partition_labels
        from repro.fl.simulation import DeviceFLSim, SimConfig
        from repro.models import cnn
        d = make_classification_data("mnist", 600, seed=0)
        parts = partition_labels(d.labels, 8, "type1", 10, seed=0)
        test = make_classification_data("mnist", 100, seed=1)
        sim = SimConfig(batch_size=8, local_steps=2, eval_every=1000,
                        dropout_rate=dropout_rate, seed=0)
        return DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim,
                           pad_subset_to=4, mesh=mesh, **kw)

    def _drive(self, simul):
        subsets = [[0, 1, 2], [3, 4, 5, 6], [7, 0, 1], [2, 3, 4]]
        weights = [np.full(len(s), 1.0 / len(s)) for s in subsets]
        return simul, simul.run_rounds(0, subsets, weights)

    def test_sharded_equals_unsharded(self):
        import jax
        from repro.launch.mesh import make_host_mesh
        sim_a, res_a = self._drive(self._sim())
        sim_b, res_b = self._drive(self._sim(mesh=make_host_mesh()))
        for (ma, qa, meta), (mb, qb, metb) in zip(res_a, res_b):
            np.testing.assert_array_equal(ma, mb)    # masks bit-equal
            np.testing.assert_allclose(qa, qb, rtol=1e-3, atol=1e-4)
            assert meta["loss"] == pytest.approx(metb["loss"], rel=1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(sim_a.params),
                        jax.tree_util.tree_leaves(sim_b.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)

    def test_mesh_mode_rejects_unsupported_features(self):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        with pytest.raises(ValueError, match="dropout"):
            self._sim(mesh=mesh, dropout_rate=0.2)
        with pytest.raises(ValueError, match="uncompressed"):
            self._sim(mesh=mesh, compression="int8")

    def test_place_on_moves_sim_state(self):
        import jax
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices (REPRO_HOST_DEVICES=8)")
        sim_ref, res_ref = self._drive(self._sim())
        simul = self._sim()
        simul.place_on(1)
        assert jax.tree_util.tree_leaves(simul.params)[0].devices() == \
            {jax.devices()[1]}
        _, res = self._drive(simul)
        for (ma, qa, meta), (mb, qb, metb) in zip(res_ref, res):
            np.testing.assert_array_equal(ma, mb)
            np.testing.assert_allclose(qa, qb, rtol=1e-4, atol=1e-5)

    def test_sharded_chunk_requires_divisible_k(self):
        import jax
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices (REPRO_HOST_DEVICES=8)")
        import jax.numpy as jnp
        from repro.fl.round import make_fl_rounds_scan_sharded
        from repro.fl import device_data
        from repro.data.synthetic import make_classification_data
        from repro.fl.partition import partition_labels
        from repro.launch.mesh import make_host_mesh
        from repro.models import cnn
        d = make_classification_data("mnist", 200, seed=0)
        parts = partition_labels(d.labels, 8, "type1", 10, seed=0)
        dd = device_data.DeviceDataset.stage(d, parts)
        cfg = cnn.MNIST_CNN
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_host_mesh()
        n = len(jax.devices())
        chunk = make_fl_rounds_scan_sharded(
            lambda p, b: cnn.loss_fn(cfg, p, b), mesh=mesh)
        K = n + 1                                     # not divisible
        sched = {"rows": jnp.zeros((1, K), jnp.int32),
                 "weights": jnp.full((1, K), 1.0 / K, jnp.float32),
                 "active": jnp.ones((1, K), jnp.float32),
                 "round_ids": jnp.zeros(1, jnp.int32)}
        with pytest.raises(ValueError, match="divisible"):
            chunk(params, dd, sched, jax.random.PRNGKey(1))
