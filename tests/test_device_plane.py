"""Device-resident FL data plane tests: dense index pools, on-device
batch gather, chunked scan driver, and device-vs-legacy equivalence
(same seeds -> same schedule, masks, and metrics within tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import make_classification_data
from repro.fl import device_data, run_fl_experiment
from repro.fl.partition import dense_index_pools, partition_labels
from repro.fl.round import flatten_stacked, make_fl_rounds_scan
from repro.fl.simulation import DeviceFLSim, FLClassificationSim, SimConfig
from repro.models import cnn


class TestDenseIndexPools:
    def test_padding_cycles_own_indices(self):
        parts = [np.array([5, 9, 2]), np.array([7]), np.array([1, 3])]
        pools, sizes = dense_index_pools(parts)
        assert pools.shape == (3, 3)
        np.testing.assert_array_equal(sizes, [3, 1, 2])
        np.testing.assert_array_equal(pools[0], [5, 9, 2])
        np.testing.assert_array_equal(pools[1], [7, 7, 7])   # cycled
        np.testing.assert_array_equal(pools[2], [1, 3, 1])   # cycled

    def test_explicit_cap_and_overflow(self):
        parts = [np.array([1, 2]), np.array([3])]
        pools, sizes = dense_index_pools(parts, cap=4)
        assert pools.shape == (2, 4)
        with pytest.raises(ValueError):
            dense_index_pools([np.arange(5)], cap=3)

    def test_empty_client(self):
        pools, sizes = dense_index_pools([np.array([], np.int64),
                                          np.array([4])])
        assert sizes[0] == 0 and sizes[1] == 1


class TestGather:
    def _staged(self):
        d = make_classification_data("mnist", 400, seed=0)
        parts = partition_labels(d.labels, 8, "type2", 10, seed=0)
        return d, parts, device_data.DeviceDataset.stage(d, parts)

    def test_samples_belong_to_client(self):
        d, parts, dd = self._staged()
        rows = jnp.array([0, 2, 5])
        _, pos_u = device_data.sample_positions(jax.random.PRNGKey(3), 7,
                                                3, 2, 16)
        idx = device_data.positions_to_indices(dd.pools, dd.sizes, rows, pos_u)
        for i, cid in enumerate([0, 2, 5]):
            assert set(np.asarray(idx[i]).ravel()) <= set(parts[cid])

    def test_batch_shapes_and_label_consistency(self):
        d, parts, dd = self._staged()
        rows = jnp.array([1, 3])
        _, pos_u = device_data.sample_positions(jax.random.PRNGKey(0), 0,
                                                2, 3, 4)
        batch = device_data.gather_batches(dd, rows, pos_u)
        assert batch["images"].shape == (2, 3, 4, 28, 28, 1)
        assert batch["labels"].shape == (2, 3, 4)
        idx = device_data.positions_to_indices(dd.pools, dd.sizes, rows, pos_u)
        np.testing.assert_array_equal(np.asarray(batch["labels"]),
                                      d.labels[np.asarray(idx)])

    def test_slot_keyed_draws_are_padding_invariant(self):
        mu4, pu4 = device_data.sample_positions(jax.random.PRNGKey(1), 5,
                                                4, 2, 8)
        mu9, pu9 = device_data.sample_positions(jax.random.PRNGKey(1), 5,
                                                9, 2, 8)
        np.testing.assert_array_equal(np.asarray(mu4), np.asarray(mu9[:4]))
        np.testing.assert_array_equal(np.asarray(pu4), np.asarray(pu9[:4]))

    def test_dropout_mask_keeps_a_client(self):
        active = jnp.array([1.0, 1.0, 1.0, 0.0])
        mask = device_data.dropout_mask(jnp.zeros(4), active, 0.5)
        np.testing.assert_array_equal(np.asarray(mask), [1, 0, 0, 0])
        # padded slots never survive
        mask = device_data.dropout_mask(jnp.ones(4), active, 0.0)
        assert float(mask[3]) == 0.0


class TestFlattenStacked:
    def test_roundtrip(self):
        tree = {"a": jnp.arange(12.0).reshape(3, 2, 2),
                "b": {"c": jnp.ones((3, 5))}}
        flat, unflatten = flatten_stacked(tree)
        assert flat.shape == (3, 9)
        back = unflatten(flat[1])
        np.testing.assert_array_equal(np.asarray(back["a"]),
                                      np.asarray(tree["a"][1]))
        np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                      np.asarray(tree["b"]["c"][1]))


class TestFusedRoundQuality:
    def test_fused_round_matches_legacy_round(self):
        """make_fl_round(fused_quality=True) == two-pass path: same
        aggregate step and same q_t within f32 accumulate tolerance."""
        from repro.fl.round import make_fl_round
        cfg = cnn.MNIST_CNN
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        d = make_classification_data("mnist", 4 * 2 * 8, seed=0)
        batches = {
            "images": jnp.asarray(d.images.reshape(4, 2, 8, 28, 28, 1)),
            "labels": jnp.asarray(d.labels.reshape(4, 2, 8))}
        w = jnp.full(4, 0.25)
        mask = jnp.array([1.0, 1.0, 0.0, 1.0])
        loss = lambda p, b: cnn.loss_fn(cfg, p, b)
        p_a, info_a = make_fl_round(loss, local_steps=2)(
            params, batches, w, mask)
        p_b, info_b = make_fl_round(loss, local_steps=2, fused_quality=True)(
            params, batches, w, mask)
        for a, b in zip(jax.tree_util.tree_leaves(p_a),
                        jax.tree_util.tree_leaves(p_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(info_a["q_values"]),
                                   np.asarray(info_b["q_values"]),
                                   rtol=1e-4, atol=1e-5)


class TestScanDriver:
    def _run(self, chunk_sizes, rounds=4, seed=0):
        """Drive the same 4-round schedule with the given chunking."""
        d = make_classification_data("mnist", 600, seed=seed)
        parts = partition_labels(d.labels, 8, "type1", 10, seed=seed)
        test = make_classification_data("mnist", 100, seed=seed + 1)
        sim = SimConfig(batch_size=8, local_steps=2, eval_every=1000,
                        dropout_rate=0.2, seed=seed)
        simul = DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim,
                            pad_subset_to=4)
        subsets = [[0, 1, 2], [3, 4, 5, 6], [7, 0, 1], [2, 3, 4]]
        weights = [np.full(len(s), 1.0 / len(s)) for s in subsets]
        results = []
        r = 0
        for cs in chunk_sizes:
            results += simul.run_rounds(r, subsets[r:r + cs],
                                        weights[r:r + cs])
            r += cs
        return simul, results

    def test_chunked_equals_per_round(self):
        """Chunked scan vs per-round dispatch: same seeds -> same masks
        and metrics (the chunking must be semantics-free)."""
        sim_a, res_a = self._run([1, 1, 1, 1])
        sim_b, res_b = self._run([4])
        for (ma, qa, meta), (mb, qb, metb) in zip(res_a, res_b):
            np.testing.assert_array_equal(ma, mb)
            np.testing.assert_allclose(qa, qb, rtol=1e-4, atol=1e-5)
            assert meta["loss"] == pytest.approx(metb["loss"], rel=1e-4)
        pa = jax.tree_util.tree_leaves(sim_a.params)
        pb = jax.tree_util.tree_leaves(sim_b.params)
        for a, b in zip(pa, pb):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_donated_params_still_usable(self):
        """chunk_fn donates params; the sim must keep only the output."""
        simul, _ = self._run([2, 2])
        assert np.isfinite(float(jax.tree_util.tree_leaves(
            simul.params)[0].sum()))


@pytest.mark.slow
class TestDeviceVsLegacyEquivalence:
    """The ISSUE-2 contract: same seeds -> same schedule, same dropout
    masks, and per-round metrics within tolerance between the legacy
    host-loop trainer and the device-resident chunked path."""

    def _experiment(self, data_plane, round_chunk=1):
        return run_fl_experiment(
            "mnist", "type2", n_clients=16, rounds=6, scheduler="mkp",
            n_train=900, n_test=200, subset_size=5,
            sim=SimConfig(batch_size=8, local_steps=2, eval_every=1000,
                          dropout_rate=0.1, seed=3),
            seed=3, data_plane=data_plane, round_chunk=round_chunk)

    def test_equivalence(self):
        host = self._experiment("host")
        dev = self._experiment("device", round_chunk=3)
        h_rounds, d_rounds = host["service"].rounds, dev["service"].rounds
        assert len(h_rounds) == len(d_rounds) == 6
        for hr, dr in zip(h_rounds, d_rounds):
            assert hr.subset == dr.subset          # same schedule
            assert hr.metrics["loss"] == pytest.approx(
                dr.metrics["loss"], rel=2e-2, abs=1e-3)
        # same dropout masks: reputation b_t histories must agree
        h_rep = host["service"].reputation
        d_rep = dev["service"].reputation
        assert set(h_rep) == set(d_rep)
        for cid in h_rep:
            assert h_rep[cid] == pytest.approx(d_rep[cid], abs=5e-2)

    def test_fast_impl_forward_bit_equal(self):
        """The device plane's CPU lowering is bit-identical in forward."""
        cfg = cnn.MNIST_CNN
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        x = jnp.asarray(np.random.default_rng(0)
                        .random((16, 28, 28, 1), dtype=np.float32))
        ref = cnn.forward(cfg, params, x, impl="reference")
        fast = cnn.forward(cfg, params, x, impl="fast")
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(fast))


class TestPoolLowering:
    def test_reshape_pool_matches_reduce_window_odd_dims(self):
        """Both poolings agree (VALID truncation) on odd spatial dims."""
        from repro.models.cnn import _pool_reshape, _pool_window
        y = jnp.asarray(np.random.default_rng(1)
                        .random((2, 7, 9, 3), dtype=np.float32))
        np.testing.assert_array_equal(np.asarray(_pool_window(y)),
                                      np.asarray(_pool_reshape(y)))


class TestEmptyPoolClient:
    def test_empty_client_slot_is_inactive(self):
        """A scheduled client with zero samples must contribute nothing
        (b_t = 0), not silently train on dataset sample 0."""
        d = make_classification_data("mnist", 200, seed=0)
        parts = [np.arange(50), np.array([], np.int64), np.arange(50, 100)]
        test = make_classification_data("mnist", 50, seed=1)
        sim = SimConfig(batch_size=4, local_steps=1, eval_every=1000,
                        dropout_rate=0.0, seed=0)
        simul = DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim)
        (returned, q, _metrics), = simul.run_rounds(
            0, [[0, 1, 2]], [np.full(3, 1 / 3)])
        assert bool(returned[0]) and bool(returned[2])
        assert not bool(returned[1])          # empty client never returns
        assert q[1] == 0.0


class TestAsyncDispatch:
    """ISSUE-4: DeviceFLSim's dispatch_rounds/collect split must be
    bit-identical to blocking run_rounds, including with interleaved
    dispatches from another task's trainer in between (the overlapped
    ServiceScheduler pattern)."""

    def _sim(self, seed):
        d = make_classification_data("mnist", 400, seed=2)
        parts = partition_labels(d.labels, 6, "type1", 10, seed=2)
        test = make_classification_data("mnist", 120, seed=3)
        sim = SimConfig(batch_size=4, local_steps=1, eval_every=2,
                        dropout_rate=0.0, seed=seed)
        return DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim)

    def test_interleaved_dispatch_matches_blocking(self):
        subsets = [[0, 1], [2, 3], [4, 5], [0, 2]]
        weights = [np.full(2, 0.5) for _ in subsets]

        ref_a = self._sim(0)
        out_ref_a = ref_a.run_rounds(0, subsets, weights)
        ref_b = self._sim(7)
        out_ref_b = ref_b.run_rounds(0, subsets, weights)

        # overlapped: enqueue task A's chunk, then task B's, collect in
        # dispatch order — nothing may depend on when collect happens
        sim_a, sim_b = self._sim(0), self._sim(7)
        ha = sim_a.dispatch_rounds(0, subsets, weights)
        hb = sim_b.dispatch_rounds(0, subsets, weights)
        out_a = sim_a.collect(ha)
        out_b = sim_b.collect(hb)

        for got, ref in ((out_a, out_ref_a), (out_b, out_ref_b)):
            assert len(got) == len(ref)
            for (ra, qa, ma), (rb, qb, mb) in zip(got, ref):
                np.testing.assert_array_equal(ra, rb)
                np.testing.assert_array_equal(qa, qb)
                assert ma == mb               # includes eval accuracies
        assert sim_a.history == ref_a.history
        assert sim_b.history == ref_b.history

    def test_eval_rounds_enqueue_with_their_params(self):
        # eval accuracy must come from the params at the eval round even
        # though later dispatches (which donate the param buffers) are
        # enqueued before collect runs
        subsets = [[0, 1], [2, 3]]
        weights = [np.full(2, 0.5) for _ in subsets]
        sim = self._sim(0)
        h1 = sim.dispatch_rounds(0, subsets, weights)      # evals round 0
        h2 = sim.dispatch_rounds(2, subsets, weights)      # evals round 2
        out = sim.collect(h1) + sim.collect(h2)
        accs = {m["round"]: m["accuracy"] for _, _, m in out
                if "accuracy" in m}
        ref = self._sim(0)
        ref_out = ref.run_rounds(0, subsets, weights) + \
            ref.run_rounds(2, subsets, weights)
        ref_accs = {m["round"]: m["accuracy"] for _, _, m in ref_out
                    if "accuracy" in m}
        assert accs == ref_accs and set(accs) == {0, 2}


class TestEvalAlignment:
    def test_mid_chunk_eval_uses_that_rounds_params(self):
        """Chunked and per-round drivers must report identical accuracy
        for a mid-chunk eval round (the chunk splits at eval rounds)."""
        d = make_classification_data("mnist", 400, seed=2)
        parts = partition_labels(d.labels, 6, "type1", 10, seed=2)
        test = make_classification_data("mnist", 120, seed=3)
        sim = SimConfig(batch_size=4, local_steps=1, eval_every=2,
                        dropout_rate=0.0, seed=2)
        subsets = [[0, 1], [2, 3], [4, 5], [0, 2]]
        weights = [np.full(2, 0.5) for _ in subsets]

        chunked = DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim)
        chunked.run_rounds(0, subsets, weights)
        stepwise = DeviceFLSim(cnn.MNIST_CNN, d, parts, test, sim)
        for r in range(4):
            stepwise.run_rounds(r, [subsets[r]], [weights[r]])

        acc_a = {h["round"]: h["accuracy"] for h in chunked.history
                 if "accuracy" in h}
        acc_b = {h["round"]: h["accuracy"] for h in stepwise.history
                 if "accuracy" in h}
        assert set(acc_a) == set(acc_b) == {0, 2}
        for r in acc_a:
            assert acc_a[r] == pytest.approx(acc_b[r], abs=1e-6)
