"""Algorithm 1 (Generate Subsets) tests on the paper's three non-iid types."""
import numpy as np
import pytest

from repro.core import scheduling as Sch
from repro.core.criteria import nid


def make_pool(kind: str, n_clients=100, n_classes=10, seed=0,
              samples_per_client=100):
    """Paper §VIII-A non-iid pool types."""
    rng = np.random.default_rng(seed)
    hists = {}
    for i in range(n_clients):
        h = np.zeros(n_classes)
        if kind == "type1":          # one label
            h[rng.integers(n_classes)] = samples_per_client
        elif kind == "type2":        # two labels 9:1
            a, b = rng.choice(n_classes, 2, replace=False)
            h[a], h[b] = 0.9 * samples_per_client, 0.1 * samples_per_client
        elif kind == "type3":        # three labels 5:4:1 (a few 5:1/4:1)
            if rng.uniform() < 0.1:
                a, b = rng.choice(n_classes, 2, replace=False)
                r = rng.choice([(5, 1), (4, 1)])
                tot = r[0] + r[1]
                h[a] = r[0] / tot * samples_per_client
                h[b] = r[1] / tot * samples_per_client
            else:
                a, b, c = rng.choice(n_classes, 3, replace=False)
                h[a], h[b], h[c] = 0.5, 0.4, 0.1
                h *= samples_per_client
        elif kind == "iid":
            h[:] = samples_per_client / n_classes
        else:
            raise ValueError(kind)
        hists[i] = h
    return hists


POOL_TYPES = ["type1", "type2", "type3"]


class TestGenerateSubsets:
    @pytest.mark.parametrize("kind", POOL_TYPES)
    def test_paper_invariants(self, kind):
        hists = make_pool(kind)
        res = Sch.generate_subsets(hists, n=10, delta=3, x_star=3)
        # constraint (9c): every client >= 1, <= x*
        assert all(res.counts[k] >= 1 for k in hists)
        assert all(res.counts[k] <= 3 for k in hists)
        # union covers pool
        covered = set().union(*map(set, res.subsets))
        assert covered == set(hists)
        # paper: with |S|=100, n±δ=10±3, x*=3 -> usually 10..20 subsets
        assert 8 <= res.num_rounds <= 25
        # constraint (9b) with the paper's tail relaxation: all but possibly
        # the last subsets within [n-δ, n+δ]
        for s in res.subsets[:-1]:
            assert 7 <= len(s) <= 13
        assert len(res.subsets[-1]) <= 13

    @pytest.mark.parametrize("kind", POOL_TYPES)
    def test_beats_random_nid(self, kind):
        """Fig. 4's qualitative claim: integrated subset histograms are much
        closer to uniform than random subsets'."""
        hists = make_pool(kind)
        ours = Sch.generate_subsets(hists, n=10, delta=3, x_star=3)
        rnd = Sch.random_subsets(hists, 10, np.random.default_rng(0))
        # compare mean Nid over subsets, excluding the tail subset
        ours_mean = np.mean(ours.nids[:-1])
        rnd_mean = np.mean(rnd.nids[:-1])
        assert ours_mean < rnd_mean

    def test_type1_near_uniform(self):
        """With one-label clients and 10 classes, a good schedule gets most
        subsets to low Nid (pick ~one client per class)."""
        hists = make_pool("type1")
        res = Sch.generate_subsets(hists, n=10, delta=3, x_star=3)
        assert np.median(res.nids) < 0.35

    def test_iid_pool_trivially_uniform(self):
        hists = make_pool("iid")
        res = Sch.generate_subsets(hists, n=10, delta=3, x_star=3)
        assert res.max_nid() < 1e-9

    def test_small_pool(self):
        hists = make_pool("type1", n_clients=5)
        res = Sch.generate_subsets(hists, n=10, delta=3, x_star=2)
        assert set().union(*map(set, res.subsets)) == set(hists)

    def test_single_client(self):
        hists = {0: np.array([10.0, 0.0])}
        res = Sch.generate_subsets(hists, n=10, delta=3)
        assert res.subsets == [[0]]

    def test_empty_pool(self):
        res = Sch.generate_subsets({}, n=10, delta=3)
        assert res.subsets == []

    def test_explicit_capacities(self):
        hists = make_pool("type1", n_clients=20)
        caps = np.full(10, 200.0)
        res = Sch.generate_subsets(hists, n=5, delta=2, capacities=caps)
        np.testing.assert_array_equal(res.capacities, caps)


class TestHelpers:
    def test_subset_nid_matches_direct(self):
        hists = make_pool("type2", n_clients=10)
        subset = [0, 3, 7]
        direct = nid(sum(hists[k] for k in subset))
        assert Sch.subset_nid(hists, subset) == pytest.approx(float(direct))

    def test_participation_weights_fedavg(self):
        hists = {0: np.array([10.0, 0]), 1: np.array([0, 30.0])}
        w = Sch.participation_weights(hists, [0, 1])
        np.testing.assert_allclose(w, [0.25, 0.75])
        assert w.sum() == pytest.approx(1.0)

    def test_default_capacities_rule(self):
        hists = make_pool("type1", n_clients=100)
        caps = Sch.default_capacities(hists, n=10)
        total = np.sum(list(hists.values()), axis=0)
        assert caps.shape == total.shape
        assert np.all(caps == caps[0])  # one capacity for all knapsacks
        assert caps[0] == pytest.approx(np.ceil(total.max() / 10))
