"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` matches the corresponding kernel's semantics exactly and
is used (a) by tests/test_kernels_*.py for allclose sweeps across
shapes/dtypes and (b) as the CPU fallback path in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B,H,Sq,hd), k/v: (B,G,Sk,hd) with H % G == 0.

    Returns (B,H,Sq,hd). Softmax in f32, output cast back to q.dtype.
    """
    B, H, Sq, hd = q.shape
    G, Sk = k.shape[1], k.shape[2]
    rep = H // G
    scale = hd ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, G, rep, Sq, hd) * scale
    s = jnp.einsum("bgrqh,bgkh->bgrqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos + (Sk - Sq)   # right-aligned when Sq < Sk
    if window > 0:
        mask &= kpos > qpos + (Sk - Sq) - window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bgkh->bgrqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (..., D), scale: (D,)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            ).astype(x.dtype) * scale


def swiglu_ref(x, w_gate, w_up):
    """x: (M, D), w_gate/w_up: (D, F) -> (M, F): silu(x@Wg) * (x@Wu)."""
    g = x.astype(jnp.float32) @ w_gate.astype(jnp.float32)
    u = x.astype(jnp.float32) @ w_up.astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)


def fedavg_agg_ref(updates, weights):
    """updates: (K, P) per-client updates, weights: (K,) p_k.

    The paper's aggregation Δ_t = Σ_k p_k Δ_t^(k), f32 accumulation.
    """
    acc = jnp.einsum("kp,k->p", updates.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return acc.astype(updates.dtype)


def fedavg_agg_quality_ref(updates, weights):
    """Fused aggregation + quality oracle (kernels.fedavg_agg).

    updates: (K, P), weights: (K,). Returns (agg, dots, sq, asq) with
    agg = Σ_k p_k u_k in updates.dtype, dots_k = ⟨u_k, agg⟩ (f32 agg),
    sq_k = ‖u_k‖², asq = ‖agg‖² — everything accumulated in f32.
    """
    u = updates.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    agg = jnp.einsum("k,kp->p", w, u)
    dots = u @ agg
    sq = jnp.sum(u * u, axis=1)
    asq = jnp.dot(agg, agg)
    return agg.astype(updates.dtype), dots, sq, asq


def mlstm_scan_ref(q, k, v, log_f, log_i, *, chunk: int = 64,
                   normalize: bool = True):
    """Chunkwise gated linear attention oracle.

    q,k: (B,H,S,dk), v: (B,H,S,dv), gates: (B,H,S). Returns (B,H,S,dv).
    Delegates to models.ssm.gated_linear_attention (itself validated
    against the step recurrence in tests/test_models_core.py).
    """
    from repro.models.ssm import gated_linear_attention
    to_bshd = lambda x: jnp.moveaxis(x, 1, 2)
    out, _ = gated_linear_attention(
        to_bshd(q), to_bshd(k), to_bshd(v),
        jnp.moveaxis(log_f, 1, 2),
        None if log_i is None else jnp.moveaxis(log_i, 1, 2),
        chunk=chunk, normalize=normalize)
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)


def segmented_topk_ref(x, k: int):
    """Segmented top-k oracle: x (S, C) -> ((S, k) f32 values,
    (S, k) int32 lane indices), descending per segment. Ties break to
    the lowest lane (``lax.top_k`` semantics, matching the kernel's
    iterative max-extract). ``-inf`` values mark exhausted segments;
    their indices are not meaningful."""
    k = int(min(k, x.shape[-1]))
    vals, idx = jax.lax.top_k(x.astype(jnp.float32), k)
    return vals, idx.astype(jnp.int32)


def topk_sparsify_ref(x, k: int):
    """Magnitude top-k oracle: x (K, P) -> ``(values (K, k) f32,
    indices (K, k) int32)``. Selection is ``lax.top_k(|x|, k)`` (stable
    — ties to the lowest index); values are the *signed* originals at
    the selected indices, ordered by descending magnitude."""
    k = int(min(k, x.shape[-1]))
    xf = x.astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(xf), k)
    vals = jnp.take_along_axis(xf, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def _chunked(x, chunk: int):
    """(K, P) f32 -> (K, nc, chunk) with a zero-padded ragged tail."""
    K, P = x.shape
    nc = -(-P // chunk)
    xp = jnp.pad(x, ((0, 0), (0, nc * chunk - P)))
    return xp.reshape(K, nc, chunk), nc


def quantize_i8_ref(x, chunk: int = 256):
    """Per-chunk symmetric int8 oracle: x (K, P) ->
    ``(values (K, P) int8, scales (K, ceil(P/chunk)) f32)`` with
    scale = amax(|chunk|)/127 (0 for an all-zero chunk) and
    values = round(x/scale) clipped to ±127."""
    K, P = x.shape
    xc, nc = _chunked(x.astype(jnp.float32), chunk)
    scales = jnp.max(jnp.abs(xc), axis=2) / 127.0              # (K, nc)
    safe = jnp.where(scales > 0.0, scales, 1.0)[:, :, None]
    q = jnp.where(scales[:, :, None] > 0.0, jnp.round(xc / safe), 0.0)
    vals = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    return vals.reshape(K, -1)[:, :P], scales


def dequantize_i8_ref(values, scales, chunk: int = 256):
    """Inverse oracle: (K, P) int8 + (K, nc) f32 -> (K, P) f32."""
    K, P = values.shape
    vc, nc = _chunked(values.astype(jnp.float32), chunk)
    return (vc * scales[:, :, None]).reshape(K, -1)[:, :P]


def fedavg_agg_quality_i8_ref(values, scales, weights, chunk: int = 256):
    """Compressed fused aggregation oracle: dequantize, then the exact
    ``fedavg_agg_quality_ref`` pass (f32 throughout)."""
    u = dequantize_i8_ref(values, scales, chunk)
    agg, dots, sq, asq = fedavg_agg_quality_ref(u, weights)
    return agg.astype(jnp.float32), dots, sq, asq


def mkp_utility_ref(values, weights, residual, selectable, eps: float = 1e-12):
    """Toyoda pseudo-utility oracle: values (n,), weights (n, m),
    residual (m,), selectable (n,) -> (n,) f32, −inf where infeasible."""
    v = values.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    r = residual.astype(jnp.float32)
    scarcity = 1.0 / jnp.maximum(r, eps)
    penalty = w @ scarcity
    fits = jnp.all(w <= r + eps, axis=1) & (selectable.astype(jnp.float32) > 0)
    util = v / jnp.maximum(penalty, eps)
    return jnp.where(fits, util, -jnp.inf)
