"""Fused FedAvg aggregation Pallas kernel.

The paper's server-side aggregation Δ_t = Σ_k p_k · Δ_t^(k) is a
bandwidth-bound weighted reduction over K client updates. The kernel
tiles the flattened parameter axis into VMEM-sized blocks; the client
axis is the in-register reduction dimension, weights live in SMEM-like
a (1,K) block, accumulation in f32 regardless of the update dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _agg_kernel(w_ref, u_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)                 # (K, bp)
    w = w_ref[...].astype(jnp.float32)                 # (1, K)
    acc = jax.lax.dot(w, u, preferred_element_type=jnp.float32)  # (1, bp)
    o_ref[...] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg(updates, weights, *, block_p: int = 16_384,
               interpret: bool = False):
    """updates: (K, P) flattened client updates; weights: (K,) p_k.

    Returns (P,) = Σ_k p_k updates_k (dtype of updates, f32 accumulate).
    """
    K, P = updates.shape
    bp = min(block_p, P)
    w2 = weights.reshape(1, K)
    return pl.pallas_call(
        _agg_kernel,
        grid=(pl.cdiv(P, bp),),
        in_specs=[pl.BlockSpec((1, K), lambda i: (0, 0)),
                  pl.BlockSpec((K, bp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w2, updates)


def fedavg_agg_tree(updates_tree, weights, *, interpret: bool = False):
    """Tree version: aggregates a pytree whose leaves have a leading
    client axis K. Flattens, runs the kernel per leaf, restores shapes."""
    def agg_leaf(leaf):
        K = leaf.shape[0]
        flat = leaf.reshape(K, -1)
        return fedavg_agg(flat, weights, interpret=interpret).reshape(
            leaf.shape[1:])
    return jax.tree_util.tree_map(agg_leaf, updates_tree)
