"""Fused FedAvg aggregation Pallas kernels.

The paper's server-side aggregation Δ_t = Σ_k p_k · Δ_t^(k) is a
bandwidth-bound weighted reduction over K client updates. The kernel
tiles the flattened parameter axis into VMEM-sized blocks; the client
axis is the in-register reduction dimension, weights live in SMEM-like
a (1,K) block, accumulation in f32 regardless of the update dtype.

``fedavg_agg_quality`` is the fused aggregation + model-quality kernel
of the device-resident round data plane: in a single pass over the
stacked deltas U (K, P) it emits the weighted aggregate Δ_t AND the
per-client Gram quantities the server's quality signal q_t (paper
§IV-C, q_t = cos(Δ_t^(k), Δ_t)) needs — ⟨Δ_t^(k), Δ_t⟩, ‖Δ_t^(k)‖² and
‖Δ_t‖². U is read once instead of twice (once to aggregate, once for
the K cosines), and the per-client tree-walk in fl.round disappears.
The reduction outputs accumulate across the sequential parameter-block
grid (init at block 0), with the ragged tail column-masked so padding
never leaks into the sums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _agg_kernel(w_ref, u_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)                 # (K, bp)
    w = w_ref[...].astype(jnp.float32)                 # (1, K)
    acc = jax.lax.dot(w, u, preferred_element_type=jnp.float32)  # (1, bp)
    o_ref[...] = acc[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg(updates, weights, *, block_p: int = 16_384,
               interpret: bool = False):
    """updates: (K, P) flattened client updates; weights: (K,) p_k.

    Returns (P,) = Σ_k p_k updates_k (dtype of updates, f32 accumulate).
    """
    K, P = updates.shape
    bp = min(block_p, P)
    w2 = weights.reshape(1, K)
    return pl.pallas_call(
        _agg_kernel,
        grid=(pl.cdiv(P, bp),),
        in_specs=[pl.BlockSpec((1, K), lambda i: (0, 0)),
                  pl.BlockSpec((K, bp), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w2, updates)


def _agg_quality_kernel(w_ref, u_ref, o_ref, dots_ref, sq_ref, asq_ref, *,
                        total_p: int, block_p: int):
    i = pl.program_id(0)
    u = u_ref[...].astype(jnp.float32)                 # (K, bp)
    # column-mask the ragged tail so reductions ignore block padding
    col = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1) + i * block_p
    u = jnp.where(col < total_p, u, 0.0)
    w = w_ref[...].astype(jnp.float32)                 # (1, K)
    agg = jax.lax.dot(w, u, preferred_element_type=jnp.float32)  # (1, bp)
    o_ref[...] = agg[0].astype(o_ref.dtype)
    part_dots = jax.lax.dot(u, agg.T,
                            preferred_element_type=jnp.float32)  # (K, 1)
    part_sq = jnp.sum(u * u, axis=1, keepdims=True)              # (K, 1)
    part_asq = jnp.sum(agg * agg).reshape(1, 1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = part_dots
        sq_ref[...] = part_sq
        asq_ref[...] = part_asq

    @pl.when(i > 0)
    def _accumulate():
        dots_ref[...] += part_dots
        sq_ref[...] += part_sq
        asq_ref[...] += part_asq


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg_quality(updates, weights, *, block_p: int = 16_384,
                       interpret: bool = False):
    """Fused Δ_t + quality pass. updates: (K, P); weights: (K,) p_k.

    Returns ``(agg, dots, sq, asq)``:
      agg  (P,)  = Σ_k p_k updates_k (dtype of updates, f32 accumulate)
      dots (K,)  = ⟨updates_k, agg⟩ (f32; agg kept in f32 for the dot)
      sq   (K,)  = ‖updates_k‖² (f32)
      asq  ()    = ‖agg‖² (f32)
    so q_k = dots_k / max(sqrt(sq_k)·sqrt(asq), eps).
    """
    K, P = updates.shape
    bp = min(block_p, P)
    w2 = weights.reshape(1, K)
    kernel = functools.partial(_agg_quality_kernel, total_p=P, block_p=bp)
    agg, dots, sq, asq = pl.pallas_call(
        kernel,
        grid=(pl.cdiv(P, bp),),
        in_specs=[pl.BlockSpec((1, K), lambda i: (0, 0)),
                  pl.BlockSpec((K, bp), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((bp,), lambda i: (i,)),
                   pl.BlockSpec((K, 1), lambda i: (0, 0)),
                   pl.BlockSpec((K, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((P,), updates.dtype),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w2, updates)
    return agg, dots[:, 0], sq[:, 0], asq[0, 0]


def fedavg_agg_tree(updates_tree, weights, *, interpret: bool = False):
    """Tree version: aggregates a pytree whose leaves have a leading
    client axis K. Flattens, runs the kernel per leaf, restores shapes."""
    def agg_leaf(leaf):
        K = leaf.shape[0]
        flat = leaf.reshape(K, -1)
        return fedavg_agg(flat, weights, interpret=interpret).reshape(
            leaf.shape[1:])
    return jax.tree_util.tree_map(agg_leaf, updates_tree)
