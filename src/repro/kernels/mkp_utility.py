"""Toyoda pseudo-utility update Pallas kernel (MKP inner loop, §VI-B).

Each greedy pick of the MKP scheduler rescores every candidate item
against the residual knapsack capacities:

    scarcity_k = 1 / residual_k
    util_j     = v_j / Σ_k w_jk · scarcity_k     (−inf if j can't fit)

For an ``(n_items, n_knapsacks)`` weight matrix this is a bandwidth-bound
row reduction; the kernel tiles the item axis into VMEM-sized blocks,
keeps the (small) knapsack axis whole, and fuses the fit mask, the
scarcity-weighted penalty and the final select into one pass. The
residual vector is a broadcast (1, m) block shared by every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

_EPS = 1e-12


def _mkp_utility_kernel(v_ref, sel_ref, w_ref, r_ref, o_ref):
    w = w_ref[...]                                   # (bn, m) f32
    resid = r_ref[...]                               # (1, m)  f32
    v = v_ref[...]                                   # (bn,)   f32
    sel = sel_ref[...]                               # (bn,)   f32 0/1
    scarcity = 1.0 / jnp.maximum(resid, _EPS)        # (1, m)
    penalty = jnp.sum(w * scarcity, axis=1)          # (bn,)
    fits = jnp.all(w <= resid + _EPS, axis=1) & (sel > 0.0)
    util = v / jnp.maximum(penalty, _EPS)
    o_ref[...] = jnp.where(fits, util, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mkp_utility(values, weights, residual, selectable, *,
                block_n: int = 4096, interpret: bool = False):
    """values: (n,), weights: (n, m), residual: (m,), selectable: (n,).

    Returns (n,) float32 utilities, −inf where the item is unselectable
    or does not fit the residual capacities.
    """
    n, m = weights.shape
    bn = min(block_n, n)
    v = values.astype(jnp.float32)
    sel = selectable.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    r = residual.astype(jnp.float32).reshape(1, m)
    return pl.pallas_call(
        _mkp_utility_kernel,
        grid=(pl.cdiv(n, bn),),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn,), lambda i: (i,)),
                  pl.BlockSpec((bn, m), lambda i: (i, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(v, sel, w, r)
