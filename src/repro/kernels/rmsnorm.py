"""Fused RMSNorm Pallas kernel: one HBM->VMEM pass per row block,
f32 variance accumulation, fused scale multiply."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (bm, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); scale: (D,). Rows are processed in VMEM blocks."""
    orig_shape = x.shape
    D = x.shape[-1]
    xm = x.reshape(-1, D)
    M = xm.shape[0]
    bm = min(block_rows, M)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(M, bm),),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xm, scale)
    return out.reshape(orig_shape)
