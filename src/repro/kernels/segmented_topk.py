"""Segmented top-k Pallas kernel (hierarchical selection frontier, §VI-A
at fleet scale).

The million-client selection plane shards the pool into ``S`` segments
of ``C`` rows and replaces the full-pool argsort of the greedy knapsack
with a per-shard *frontier*: the top-``k`` score/cost ratios of every
shard, extracted in one pass over the sharded ratio matrix. The global
merge then runs the exact greedy over the ``S * k`` surviving
candidates on the host (``core.engine.hierarchical_greedy_knapsack``).

Kernel shape: one grid step per segment; the segment row ``(1, C)``
lives in VMEM for the whole program, and the top-k is an iterative
max-extract — ``k`` vectorized max/mask passes over the resident row,
no sort network and no dynamic stores (the running ``(1, k)``
value/index frontiers are carried through a ``fori_loop`` and written
once). That trades ``k`` VPU passes for a single HBM read per row,
which is the right trade for the frontier regime ``k << C``. Ties
break toward the lowest lane index (matching ``jax.lax.top_k`` and the
host argsort's stable order).

Rows shorter than ``C`` are padded with ``-inf`` by the caller; a
``-inf`` frontier entry therefore means "segment exhausted" and its
index is meaningless (the oracle and kernel both park it at lane 0).
VMEM bounds the segment width: a ``(1, C)`` f32 row plus the iota mask
must fit, so keep ``C`` at or below ~256k lanes (the default shard
capacity of ``core.device_pool`` is far under this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams


def _segmented_topk_kernel(x_ref, vals_ref, idx_ref, *, k: int, width: int):
    row = x_ref[...].astype(jnp.float32)                 # (1, C)
    lanes = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(i, carry):
        row, vals, idxs = carry
        m = jnp.max(row, axis=1, keepdims=True)          # (1, 1)
        # lowest lane attaining the max (stable tie-break)
        j = jnp.min(jnp.where(row == m, lanes, width), axis=1, keepdims=True)
        vals = jnp.where(slots == i, m, vals)
        idxs = jnp.where(slots == i, j, idxs)
        row = jnp.where(lanes == j, -jnp.inf, row)
        return row, vals, idxs

    init = (row, jnp.full((1, k), -jnp.inf, jnp.float32),
            jnp.zeros((1, k), jnp.int32))
    _, vals, idxs = jax.lax.fori_loop(0, k, body, init)
    vals_ref[...] = vals
    idx_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def segmented_topk(x, k: int, *, interpret: bool = False):
    """x: (S, C) per-segment rows -> ((S, k) values f32, (S, k) lane
    indices int32), descending per segment, ties to the lowest lane.
    Entries equal to ``-inf`` mean the segment ran out of finite rows.
    """
    S, C = x.shape
    k = int(min(k, C))
    return pl.pallas_call(
        functools.partial(_segmented_topk_kernel, k=k, width=C),
        grid=(S,),
        in_specs=[pl.BlockSpec((1, C), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, k), jnp.float32),
                   jax.ShapeDtypeStruct((S, k), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x.astype(jnp.float32))
