"""Version shims for the Pallas TPU API.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` across
versions; every kernel imports the resolved class from here so a future
rename is one edit, with a clear error when neither name exists.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by "
        "repro.kernels")
