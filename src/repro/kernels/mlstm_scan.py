"""Chunkwise-parallel gated linear attention (mLSTM / SSD) Pallas kernel.

TPU adaptation of the GPU selective-scan: intra-chunk work is two small
MXU matmuls (QKᵀ and PV) with log-space gate weights; the inter-chunk
state (dk x dv per head) lives in VMEM scratch and is carried across the
innermost (sequential) grid dimension — no HBM round-trip per chunk.

Matches ``kernels.ref.mlstm_scan_ref`` (== models.ssm oracle) for both
the normalized (mLSTM) and unnormalized (SSD / mamba-2) variants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _gla_kernel(q_ref, k_ref, v_ref, f_ref, i_ref, o_ref,
                s_scr, n_scr, m_scr, *, chunk: int, normalize: bool,
                seq: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.zeros_like(m_scr)

    # padded-tail handling: zero K/V rows (0*garbage = NaN hazard) and
    # neutralize the gates (f=1, i=0 in log space)
    tpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = tpos < seq
    vcol = valid[:, None]

    q = q_ref[0].astype(jnp.float32)                   # (C, dk)
    k = jnp.where(vcol, k_ref[0].astype(jnp.float32), 0.0)
    v = jnp.where(vcol, v_ref[0].astype(jnp.float32), 0.0)  # (C, dv)
    fj = f_ref[0].astype(jnp.float32)                  # (C,)
    ij = i_ref[0].astype(jnp.float32)

    fj = jnp.where(valid, fj, 0.0)
    neg_big = jnp.float32(-1e30)
    ij = jnp.where(valid, ij, neg_big)

    g = jnp.cumsum(fj)                                  # (C,) inclusive
    G = g[-1]
    m_prev = m_scr[0, 0]

    causal = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    inter = g + m_prev                                  # (C,)
    intra = g[:, None] - g[None, :] + ij[None, :]       # (C, C)
    intra = jnp.where(causal, intra, neg_big)
    if normalize:
        M = jnp.maximum(inter, intra.max(axis=-1))      # (C,)
    else:
        M = jnp.zeros_like(inter)
    w_inter = jnp.exp(inter - M)
    w_intra = jnp.exp(intra - M[:, None])
    w_intra = jnp.where(causal, w_intra, 0.0)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, C)
    scores = qk * w_intra
    y = jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    y += w_inter[:, None] * jax.lax.dot(q, s_scr[...],
                                        preferred_element_type=jnp.float32)
    if normalize:
        nrm = scores.sum(axis=-1) + w_inter * (q @ n_scr[...][:, 0])
        denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-M))
        y = y / denom[:, None]
    o_ref[0] = y.astype(o_ref.dtype)

    # ---- state update ----
    m_new = jnp.maximum(G + m_prev, (G - g + ij).max())
    if not normalize:
        m_new = jnp.zeros_like(m_new)
    decay = jnp.exp(G + m_prev - m_new)
    w_k = jnp.exp(G - g + ij - m_new)                   # (C,)
    s_scr[...] = decay * s_scr[...] + jax.lax.dot_general(
        k * w_k[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (dk, dv)
    n_scr[...] = decay * n_scr[...] + (
        (k * w_k[:, None]).sum(axis=0))[:, None]        # (dk, 1)
    m_scr[...] = jnp.full_like(m_scr, m_new)


@functools.partial(jax.jit, static_argnames=("chunk", "normalize",
                                             "interpret"))
def mlstm_scan(q, k, v, log_f, log_i=None, *, chunk: int = 64,
               normalize: bool = True, interpret: bool = False):
    """q,k: (B,H,S,dk), v: (B,H,S,dv), log_f/log_i: (B,H,S).

    Returns (B,H,S,dv). log_i=None => SSD mode (zeros, unnormalized
    callers pass normalize=False)."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if log_i is None:
        log_i = jnp.zeros_like(log_f)
    C = min(chunk, S)
    NC = pl.cdiv(S, C)
    BH = B * H
    rs = lambda x: x.reshape(BH, S, *x.shape[3:])
    qf, kf, vf = rs(q), rs(k), rs(v)
    ff, iff = log_f.reshape(BH, S), log_i.reshape(BH, S)

    out = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=C, normalize=normalize, seq=S),
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec((1, C, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C), lambda b, c: (b, c)),
            pl.BlockSpec((1, C), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, C, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32),
                        pltpu.VMEM((dk, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, ff, iff)
    return out.reshape(B, H, S, dv)
