"""Fused SwiGLU Pallas kernel: silu(x @ Wg) * (x @ Wu) with both partial
products accumulated in VMEM scratch over K blocks — the activations
never round-trip to HBM between the two GEMMs and the gating."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref, accg, accu, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        accg[...] = jnp.zeros_like(accg)
        accu[...] = jnp.zeros_like(accu)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
    accg[...] += jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)
    accu[...] += jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        g = accg[...]
        o_ref[...] = (g / (1.0 + jnp.exp(-g)) * accu[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def swiglu(x, w_gate, w_up, *, block_m: int = 256, block_n: int = 256,
           block_k: int = 512, interpret: bool = False):
    """x: (..., D); w_gate/w_up: (D, F). Returns (..., F)."""
    orig = x.shape
    D = x.shape[-1]
    F = w_gate.shape[1]
    xm = x.reshape(-1, D)
    M = xm.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, F), min(block_k, D)
    nk = pl.cdiv(D, bk)
    out = pl.pallas_call(
        functools.partial(_swiglu_kernel, nk=nk),
        grid=(pl.cdiv(M, bm), pl.cdiv(F, bn), nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xm, w_gate, w_up)
    return out.reshape(*orig[:-1], F)
