"""Compressed client-update Pallas kernels (the delta codec plane).

Client updates dominate cross-device FL traffic, and the selection
metric the paper optimizes is only meaningful if aggregation cost
models that traffic. These kernels implement the two codecs of the
compressed update plane (fl.compression):

- ``topk_sparsify`` — per-row magnitude top-k with index+value packing:
  each flattened client delta keeps its k largest-|x| entries (signed
  values + lane indices). Same iterative max-extract shape as
  ``segmented_topk`` (one grid step per row, the ``(1, P)`` row resident
  in VMEM, k vectorized max/mask passes, frontiers carried through a
  ``fori_loop`` and written once); ties break to the lowest lane,
  matching ``jax.lax.top_k`` over ``|x|``.

- ``quantize_i8`` / ``dequantize_i8`` — per-chunk symmetric int8: each
  ``chunk``-wide slice of a row is scaled by ``amax/127`` (f32 scales,
  one per chunk) and rounded to int8. The grid is ``(rows, chunks)``;
  the caller pads the parameter axis with zeros up to a chunk multiple
  (padding quantizes to 0 and is sliced off), so no in-kernel tail
  masking is needed and kernel == oracle bit-for-bit.

- ``fedavg_agg_quality_i8`` — the fused *compressed* sibling of
  ``fedavg_agg_quality``: one pass over the quantized payloads
  dequantizes in-register and emits the weighted aggregate Δ_t plus all
  per-client Gram terms of the quality cosine — the server never
  materializes the dequantized (K, P) matrix in HBM.

Like every kernel in this package, each has a jnp oracle in ``ref.py``
and is called through the dispatching wrappers in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams


def _pad_to_chunks(x, chunk: int):
    """Zero-pad the last axis up to a multiple of ``chunk``."""
    P = x.shape[-1]
    pp = -(-P // chunk) * chunk
    if pp == P:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, pp - P)]
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# Magnitude top-k sparsification
# ---------------------------------------------------------------------------

def _topk_sparsify_kernel(x_ref, vals_ref, idx_ref, *, k: int, width: int):
    row = x_ref[...].astype(jnp.float32)                 # (1, P)
    mag = jnp.abs(row)
    lanes = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(i, carry):
        mag, vals, idxs = carry
        m = jnp.max(mag, axis=1, keepdims=True)          # (1, 1)
        # lowest lane attaining the max magnitude (stable tie-break)
        j = jnp.min(jnp.where(mag == m, lanes, width), axis=1, keepdims=True)
        v = jnp.sum(jnp.where(lanes == j, row, 0.0), axis=1, keepdims=True)
        vals = jnp.where(slots == i, v, vals)
        idxs = jnp.where(slots == i, j, idxs)
        mag = jnp.where(lanes == j, -jnp.inf, mag)
        return mag, vals, idxs

    init = (mag, jnp.zeros((1, k), jnp.float32), jnp.zeros((1, k), jnp.int32))
    _, vals, idxs = jax.lax.fori_loop(0, k, body, init)
    vals_ref[...] = vals
    idx_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_sparsify(x, k: int, *, interpret: bool = False):
    """x: (K, P) flattened client deltas -> ``(values (K, k) f32,
    indices (K, k) int32)``: each row's k largest-magnitude entries
    (signed values), ordered by descending |value|, ties to the lowest
    lane — exactly ``jax.lax.top_k(|x|, k)``'s selection.
    """
    K, P = x.shape
    k = int(min(k, P))
    return pl.pallas_call(
        functools.partial(_topk_sparsify_kernel, k=k, width=P),
        grid=(K,),
        in_specs=[pl.BlockSpec((1, P), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((K, k), jnp.float32),
                   jax.ShapeDtypeStruct((K, k), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Per-chunk symmetric int8 quantization
# ---------------------------------------------------------------------------

def _quantize_i8_kernel(x_ref, v_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (1, C)
    scale = jnp.max(jnp.abs(x)) / 127.0
    q = jnp.where(scale > 0.0, jnp.round(x / jnp.where(scale > 0.0,
                                                       scale, 1.0)), 0.0)
    v_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    s_ref[...] = scale.reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def quantize_i8(x, *, chunk: int = 256, interpret: bool = False):
    """x: (K, P) -> ``(values (K, P) int8, scales (K, ceil(P/chunk))
    f32)``. Symmetric per-chunk: scale = amax(|chunk|)/127; an all-zero
    chunk gets scale 0 and quantizes to 0.
    """
    K, P = x.shape
    xp = _pad_to_chunks(x.astype(jnp.float32), chunk)
    nc = xp.shape[1] // chunk
    vals, scales = pl.pallas_call(
        _quantize_i8_kernel,
        grid=(K, nc),
        in_specs=[pl.BlockSpec((1, chunk), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((K, nc * chunk), jnp.int8),
                   jax.ShapeDtypeStruct((K, nc), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp)
    return vals[:, :P], scales


def _dequantize_i8_kernel(v_ref, s_ref, o_ref):
    o_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def dequantize_i8(values, scales, *, chunk: int = 256,
                  interpret: bool = False):
    """Inverse of :func:`quantize_i8`: ``(K, P) int8 + (K, nc) f32 ->
    (K, P) f32`` with each chunk rescaled by its stored scale."""
    K, P = values.shape
    vp = _pad_to_chunks(values, chunk)
    nc = vp.shape[1] // chunk
    out = pl.pallas_call(
        _dequantize_i8_kernel,
        grid=(K, nc),
        in_specs=[pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, nc * chunk), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(vp, scales)
    return out[:, :P]


# ---------------------------------------------------------------------------
# Fused compressed aggregation + quality
# ---------------------------------------------------------------------------

def _agg_quality_i8_kernel(w_ref, v_ref, s_ref, o_ref, dots_ref, sq_ref,
                           asq_ref):
    i = pl.program_id(0)
    # dequantize in-register: (K, C) int8 * (K, 1) chunk scales
    u = v_ref[...].astype(jnp.float32) * s_ref[...]
    w = w_ref[...].astype(jnp.float32)                   # (1, K)
    agg = jax.lax.dot(w, u, preferred_element_type=jnp.float32)  # (1, C)
    o_ref[...] = agg[0]
    part_dots = jax.lax.dot(u, agg.T,
                            preferred_element_type=jnp.float32)  # (K, 1)
    part_sq = jnp.sum(u * u, axis=1, keepdims=True)              # (K, 1)
    part_asq = jnp.sum(agg * agg).reshape(1, 1)

    @pl.when(i == 0)
    def _init():
        dots_ref[...] = part_dots
        sq_ref[...] = part_sq
        asq_ref[...] = part_asq

    @pl.when(i > 0)
    def _accumulate():
        dots_ref[...] += part_dots
        sq_ref[...] += part_sq
        asq_ref[...] += part_asq


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fedavg_agg_quality_i8(values, scales, weights, *, chunk: int = 256,
                          interpret: bool = False):
    """Fused Δ_t + quality pass over *quantized* payloads.

    values: (K, P) int8, scales: (K, ceil(P/chunk)) f32, weights: (K,).
    Returns ``(agg (P,) f32, dots (K,), sq (K,), asq ())`` — exactly
    :func:`~repro.kernels.fedavg_agg.fedavg_agg_quality` applied to
    ``dequantize_i8(values, scales)``, but the dequantized (K, P)
    matrix never leaves registers (zero-padding of the ragged tail
    dequantizes to 0 and cannot perturb the sums).
    """
    K, P = values.shape
    vp = _pad_to_chunks(values, chunk)
    nc = vp.shape[1] // chunk
    w2 = weights.astype(jnp.float32).reshape(1, K)
    agg, dots, sq, asq = pl.pallas_call(
        _agg_quality_i8_kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, K), lambda i: (0, 0)),
                  pl.BlockSpec((K, chunk), lambda i: (0, i)),
                  pl.BlockSpec((K, 1), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((chunk,), lambda i: (i,)),
                   pl.BlockSpec((K, 1), lambda i: (0, 0)),
                   pl.BlockSpec((K, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((nc * chunk,), jnp.float32),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32),
                   jax.ShapeDtypeStruct((K, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w2, vp, scales)
    return agg[:P], dots[:, 0], sq[:, 0], asq[0, 0]
