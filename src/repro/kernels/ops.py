"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches: Pallas kernel on TPU (or in interpret mode when
``interpret=True``), pure-jnp oracle (ref.py) otherwise — so models can
call these unconditionally and stay runnable on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .fedavg_agg import fedavg_agg as _fedavg_pallas
from .fedavg_agg import fedavg_agg_quality as _fedavg_quality_pallas
from .fedavg_agg import fedavg_agg_tree
from .flash_attention import flash_attention as _flash_pallas
from .mkp_utility import mkp_utility as _mkp_utility_pallas
from .segmented_topk import segmented_topk as _segmented_topk_pallas
from .mlstm_scan import mlstm_scan as _mlstm_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .swiglu import swiglu as _swiglu_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, interpret=None):
    """q: (B,H,Sq,hd); k/v: (B,G,Sk,hd)."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=bool(interpret))
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def flash_attention_bshd(q, k, v, *, causal=True, window=0, interpret=None):
    """Adapter for models.layers (B,S,H,hd) layout."""
    t = lambda x: jnp.swapaxes(x, 1, 2)
    o = flash_attention(t(q), t(k), t(v), causal=causal, window=window,
                        interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


def rmsnorm(x, scale, *, eps=1e-6, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _rmsnorm_pallas(x, scale, eps=eps, interpret=bool(interpret))
    return ref.rmsnorm_ref(x, scale, eps=eps)


def swiglu(x, w_gate, w_up, *, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _swiglu_pallas(x, w_gate, w_up, interpret=bool(interpret))
    return ref.swiglu_ref(x, w_gate, w_up)


def fedavg_agg(updates, weights, *, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _fedavg_pallas(updates, weights, interpret=bool(interpret))
    return ref.fedavg_agg_ref(updates, weights)


def fedavg_agg_quality(updates, weights, *, interpret=None):
    """Fused Δ_t aggregation + per-client quality pass (single read of
    the stacked updates). Returns (agg (P,), dots (K,), sq (K,), asq ())
    — see kernels.fedavg_agg.fedavg_agg_quality."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _fedavg_quality_pallas(updates, weights,
                                      interpret=bool(interpret))
    return ref.fedavg_agg_quality_ref(updates, weights)


def mkp_utility(values, weights, residual, selectable, *, interpret=None):
    """Toyoda pseudo-utility update for the MKP greedy (core.engine).

    values: (n,), weights: (n, m), residual: (m,), selectable: (n,).
    Returns (n,) f32 utilities, −inf where the item can't be picked.
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _mkp_utility_pallas(values, weights, residual, selectable,
                                   interpret=bool(interpret))
    return ref.mkp_utility_ref(values, weights, residual, selectable)


def segmented_topk(x, k, *, interpret=None):
    """Per-segment top-k frontier for hierarchical selection
    (core.engine / core.device_pool).

    x: (S, C) per-segment rows (``-inf``-padded). Returns
    ``(values (S, k) f32, lane_indices (S, k) int32)``, descending per
    segment, ties to the lowest lane.
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _segmented_topk_pallas(x, int(k), interpret=bool(interpret))
    return ref.segmented_topk_ref(x, int(k))


def mlstm_scan(q, k, v, log_f, log_i=None, *, chunk=64, normalize=True,
               interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _mlstm_pallas(q, k, v, log_f, log_i, chunk=chunk,
                             normalize=normalize, interpret=bool(interpret))
    return ref.mlstm_scan_ref(q, k, v, log_f, log_i, chunk=chunk,
                              normalize=normalize)


__all__ = ["flash_attention", "flash_attention_bshd", "rmsnorm", "swiglu",
           "fedavg_agg", "fedavg_agg_quality", "fedavg_agg_tree",
           "mkp_utility", "mlstm_scan", "segmented_topk"]
