"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches: Pallas kernel on TPU (or in interpret mode when
``interpret=True``), pure-jnp oracle (ref.py) otherwise — so models can
call these unconditionally and stay runnable on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .compression import dequantize_i8 as _dequantize_i8_pallas
from .compression import fedavg_agg_quality_i8 as _agg_quality_i8_pallas
from .compression import quantize_i8 as _quantize_i8_pallas
from .compression import topk_sparsify as _topk_sparsify_pallas
from .fedavg_agg import fedavg_agg as _fedavg_pallas
from .fedavg_agg import fedavg_agg_quality as _fedavg_quality_pallas
from .fedavg_agg import fedavg_agg_tree
from .flash_attention import flash_attention as _flash_pallas
from .mkp_utility import mkp_utility as _mkp_utility_pallas
from .segmented_topk import segmented_topk as _segmented_topk_pallas
from .mlstm_scan import mlstm_scan as _mlstm_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas
from .swiglu import swiglu as _swiglu_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, interpret=None):
    """q: (B,H,Sq,hd); k/v: (B,G,Sk,hd)."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=bool(interpret))
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def flash_attention_bshd(q, k, v, *, causal=True, window=0, interpret=None):
    """Adapter for models.layers (B,S,H,hd) layout."""
    t = lambda x: jnp.swapaxes(x, 1, 2)
    o = flash_attention(t(q), t(k), t(v), causal=causal, window=window,
                        interpret=interpret)
    return jnp.swapaxes(o, 1, 2)


def rmsnorm(x, scale, *, eps=1e-6, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _rmsnorm_pallas(x, scale, eps=eps, interpret=bool(interpret))
    return ref.rmsnorm_ref(x, scale, eps=eps)


def swiglu(x, w_gate, w_up, *, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _swiglu_pallas(x, w_gate, w_up, interpret=bool(interpret))
    return ref.swiglu_ref(x, w_gate, w_up)


def fedavg_agg(updates, weights, *, interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _fedavg_pallas(updates, weights, interpret=bool(interpret))
    return ref.fedavg_agg_ref(updates, weights)


def fedavg_agg_quality(updates, weights, *, interpret=None):
    """Fused Δ_t aggregation + per-client quality pass (single read of
    the stacked updates). Returns (agg (P,), dots (K,), sq (K,), asq ())
    — see kernels.fedavg_agg.fedavg_agg_quality."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _fedavg_quality_pallas(updates, weights,
                                      interpret=bool(interpret))
    return ref.fedavg_agg_quality_ref(updates, weights)


def mkp_utility(values, weights, residual, selectable, *, interpret=None):
    """Toyoda pseudo-utility update for the MKP greedy (core.engine).

    values: (n,), weights: (n, m), residual: (m,), selectable: (n,).
    Returns (n,) f32 utilities, −inf where the item can't be picked.
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _mkp_utility_pallas(values, weights, residual, selectable,
                                   interpret=bool(interpret))
    return ref.mkp_utility_ref(values, weights, residual, selectable)


def segmented_topk(x, k, *, interpret=None):
    """Per-segment top-k frontier for hierarchical selection
    (core.engine / core.device_pool).

    x: (S, C) per-segment rows (``-inf``-padded). Returns
    ``(values (S, k) f32, lane_indices (S, k) int32)``, descending per
    segment, ties to the lowest lane.
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _segmented_topk_pallas(x, int(k), interpret=bool(interpret))
    return ref.segmented_topk_ref(x, int(k))


def topk_sparsify(x, k, *, interpret=None):
    """Magnitude top-k packing of flattened client deltas
    (fl.compression codec "topk").

    x: (K, P). Returns ``(values (K, k) f32, indices (K, k) int32)`` —
    each row's k largest-|x| entries (signed values), descending by
    magnitude, ties to the lowest index (== ``lax.top_k(|x|, k)``).
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _topk_sparsify_pallas(x, int(k), interpret=bool(interpret))
    return ref.topk_sparsify_ref(x, int(k))


def quantize_i8(x, *, chunk=256, interpret=None):
    """Per-chunk symmetric int8 quantization (fl.compression codec
    "int8"): x (K, P) -> ``(values (K, P) int8,
    scales (K, ceil(P/chunk)) f32)`` with scale = amax/127 per chunk.
    """
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _quantize_i8_pallas(x, chunk=int(chunk),
                                   interpret=bool(interpret))
    return ref.quantize_i8_ref(x, int(chunk))


def dequantize_i8(values, scales, *, chunk=256, interpret=None):
    """Inverse of :func:`quantize_i8`: rescale int8 chunks back to f32."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _dequantize_i8_pallas(values, scales, chunk=int(chunk),
                                     interpret=bool(interpret))
    return ref.dequantize_i8_ref(values, scales, int(chunk))


def fedavg_agg_quality_i8(values, scales, weights, *, chunk=256,
                          interpret=None):
    """Compressed sibling of :func:`fedavg_agg_quality`: the weighted
    aggregate Δ_t and per-client quality Gram terms computed directly
    from int8 payloads (dequantized in-kernel). Returns
    (agg (P,) f32, dots (K,), sq (K,), asq ())."""
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _agg_quality_i8_pallas(values, scales, weights,
                                      chunk=int(chunk),
                                      interpret=bool(interpret))
    return ref.fedavg_agg_quality_i8_ref(values, scales, weights, int(chunk))


def mlstm_scan(q, k, v, log_f, log_i=None, *, chunk=64, normalize=True,
               interpret=None):
    use_pallas = _on_tpu() if interpret is None else True
    if use_pallas:
        return _mlstm_pallas(q, k, v, log_f, log_i, chunk=chunk,
                             normalize=normalize, interpret=bool(interpret))
    return ref.mlstm_scan_ref(q, k, v, log_f, log_i, chunk=chunk,
                              normalize=normalize)


__all__ = ["dequantize_i8", "flash_attention", "flash_attention_bshd",
           "fedavg_agg", "fedavg_agg_quality", "fedavg_agg_quality_i8",
           "fedavg_agg_tree", "mkp_utility", "mlstm_scan", "quantize_i8",
           "rmsnorm", "segmented_topk", "swiglu", "topk_sparsify"]
