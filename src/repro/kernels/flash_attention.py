"""Blocked flash attention for TPU (Pallas): causal / sliding-window /
GQA, online softmax, f32 accumulation in VMEM scratch.

Layout: q (B,H,Sq,hd), k/v (B,G,Sk,hd). Grid = (B, H, Sq/bq, Sk/bk) with
the KV-block dimension innermost ("arbitrary" semantics => sequential),
so the (m, l, acc) scratch carries across KV blocks of one Q block and
is flushed to HBM on the last one. Block shapes default to MXU-aligned
(128, 128); hd rides along unblocked (<= 256 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, bq: int, bk: int,
                 seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # zero padded K rows (S % bk != 0): garbage values must not reach the
    # PV matmul (0 * garbage = NaN hazards).
    kvalid = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0) < seq_k

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
    k = jnp.where(kvalid, k_ref[0, 0].astype(jnp.float32), 0.0)  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    # absolute positions (right-aligned when Sq < Sk, e.g. decode)
    offset = seq_k - seq_q
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_k                                 # tail padding
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (everything -inf): keep exp at 0
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = jnp.where(kvalid, v_ref[0, 0].astype(jnp.float32), 0.0)  # (bk, hd)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B,H,Sq,hd); k/v: (B,G,Sk,hd). Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    G, Sk = k.shape[1], k.shape[2]
    if H % G:
        raise ValueError(f"H={H} not a multiple of G={G}")
    rep = H // G
    scale = float(hd ** -0.5) if scale is None else float(scale)

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Sk, bk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, seq_q=Sq, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, _rep=rep: (b, h // _rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, _rep=rep: (b, h // _rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),   # running max m
            _vmem((bq, 1), jnp.float32),   # running sum l
            _vmem((bq, hd), jnp.float32),  # output accumulator
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    try:
        from ._compat import CompilerParams
        return CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except Exception:
        return None
