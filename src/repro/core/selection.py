"""Stage 1: initial client pool selection (paper §V-A, §VI-A).

After threshold filtering (Eq. 8d) and the budget-floor check (Eq. 11),
the problem is a 0-1 knapsack (Eq. 12): maximize total Score subject to
total Cost <= B. We provide:

- ``select_greedy``  — the paper's O(n log n) score/cost-ratio greedy,
  vectorized (argsort + cumulative-sum prefix via ``core.engine``);
- ``select_greedy_legacy`` — the original per-client Python loop, kept
  as the bit-exact reference for equivalence tests and benchmarks;
- ``select_dp``      — exact dynamic programming, O(n·B) (integer costs);
- ``select_random``  — the paper's random baseline;
- ``select_score_prop`` — score-proportional sampling under the same
  budget (beyond-paper baseline, see ``core.policy``);

plus the full Stage-1 wrapper ``select_initial_pool`` implementing the
threshold filter and minimum-pool-size feasibility check. The wrapper
accepts either the legacy ``list[ClientProfile]`` or an array-native
``ClientPoolState`` (the internal representation; profile lists are
converted once and processed with masked array ops).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import engine
from .criteria import THRESHOLDED, ClientProfile
from .pool import ClientPoolState


@dataclasses.dataclass
class SelectionResult:
    selected: list[int]          # client ids, in selection order
    total_score: float
    total_cost: float
    feasible: bool = True
    note: str = ""

    def approx_ratio(self, optimal_score: float) -> float:
        """Paper's 'approximation ratio': relative gap to the optimum."""
        if optimal_score <= 0:
            return 0.0
        return (optimal_score - self.total_score) / optimal_score


def _totals(ids: Sequence[int], scores, costs) -> tuple[float, float]:
    idx = list(ids)
    return float(np.sum(scores[idx])) if idx else 0.0, \
        float(np.sum(costs[idx])) if idx else 0.0


# ---------------------------------------------------------------------------
# Knapsack solvers
# ---------------------------------------------------------------------------

def select_greedy(scores: np.ndarray, costs: np.ndarray, budget: float,
                  ids: Sequence[int] | None = None,
                  skip_unaffordable: bool = False) -> SelectionResult:
    """Greedy by non-increasing score/cost ratio (§VI-A), vectorized.

    With ``skip_unaffordable=False`` (paper-faithful, reproduces Table III:
    5 clients / 32.78) the scan stops at the first client whose cost
    exceeds the remaining budget. ``skip_unaffordable=True`` is the
    beyond-paper variant that keeps scanning for cheaper clients further
    down the ratio order — it dominates the paper's variant pointwise
    (recorded in EXPERIMENTS.md §Perf/control-plane).

    Selections are identical to :func:`select_greedy_legacy` (tested in
    tests/test_engine.py); the hot path is ``engine.greedy_knapsack``.
    """
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    chosen, ts, tc = engine.greedy_knapsack(
        scores, costs, budget, skip_unaffordable=skip_unaffordable)
    if ids is None:
        sel = [int(j) for j in chosen]
    else:
        ids = list(ids)
        sel = [ids[j] for j in chosen]
    return SelectionResult(sel, ts, tc)


def select_greedy_legacy(scores: np.ndarray, costs: np.ndarray, budget: float,
                         ids: Sequence[int] | None = None,
                         skip_unaffordable: bool = False) -> SelectionResult:
    """The original per-client Python-loop greedy, kept as the reference
    implementation the vectorized path is tested against (and as the
    baseline for benchmarks/bench_selection_time.py)."""
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    ids = list(range(len(scores))) if ids is None else list(ids)
    ratio = scores / np.maximum(costs, 1e-12)
    order = np.argsort(-ratio, kind="stable")
    chosen: list[int] = []
    remaining = float(budget)
    for j in order:
        c = float(costs[j])
        if c <= remaining:
            chosen.append(j)
            remaining -= c
        elif not skip_unaffordable:
            break
    ts, tc = _totals(chosen, scores, costs)
    return SelectionResult([ids[j] for j in chosen], ts, tc)


def select_dp(scores: np.ndarray, costs: np.ndarray, budget: float,
              ids: Sequence[int] | None = None) -> SelectionResult:
    """Exact 0-1 knapsack DP, O(n·B). Costs are rounded to integers
    (the paper rounds costs to the nearest integer for convenience)."""
    scores = np.asarray(scores, dtype=np.float64)
    icosts = np.rint(np.asarray(costs, dtype=np.float64)).astype(np.int64)
    if np.any(icosts < 0):
        raise ValueError("negative costs")
    ids = list(range(len(scores))) if ids is None else list(ids)
    B = int(np.floor(budget))
    n = len(scores)
    # dp[b] = best score with capacity b; keep[i] = bitset over capacities
    dp = np.zeros(B + 1, dtype=np.float64)
    keep = np.zeros((n, B + 1), dtype=bool)
    for i in range(n):
        c, s = int(icosts[i]), float(scores[i])
        if c > B:
            continue
        cand = dp[: B - c + 1] + s
        upd = cand > dp[c:]
        keep[i, c:][upd] = True
        dp[c:][upd] = cand[upd]
    # backtrack
    b = int(np.argmax(dp))
    chosen: list[int] = []
    for i in range(n - 1, -1, -1):
        if keep[i, b]:
            chosen.append(i)
            b -= int(icosts[i])
    chosen.reverse()
    ts, tc = _totals(chosen, scores, np.asarray(costs, dtype=np.float64))
    return SelectionResult([ids[j] for j in chosen], ts, tc)


def select_random(scores: np.ndarray, costs: np.ndarray, budget: float,
                  rng: np.random.Generator,
                  ids: Sequence[int] | None = None) -> SelectionResult:
    """Random baseline: add random clients until the budget is short.

    Matches the paper: "randomly selects clients until the budget is
    short" — i.e. stops at the first client that does not fit.
    """
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    ids = list(range(len(scores))) if ids is None else list(ids)
    order = rng.permutation(len(scores))
    chosen: list[int] = []
    remaining = float(budget)
    for j in order:
        if costs[j] > remaining:
            break
        chosen.append(int(j))
        remaining -= float(costs[j])
    ts, tc = _totals(chosen, scores, costs)
    return SelectionResult([ids[j] for j in chosen], ts, tc)


def select_score_prop(scores: np.ndarray, costs: np.ndarray, budget: float,
                      rng: np.random.Generator,
                      ids: Sequence[int] | None = None) -> SelectionResult:
    """Score-proportional sampling under the budget (beyond-paper
    baseline; backs the ``score_prop`` policy in ``core.policy``).

    Clients are ordered by a weighted random draw without replacement
    — Efraimidis–Spirakis keys, computed in log space
    (``log(u)/score``, the same ordering as ``u^(1/score)`` but immune
    to the underflow that collapses ``u^(1/w)`` to 0.0 for small
    scores and silently degenerates the draw into index order) — so
    the probability of being drawn early is proportional to the
    overall score; then the same stop-at-first-unaffordable budget
    scan as :func:`select_random` runs over that order. The two
    baselines thus differ *only* in the sampling weights.
    """
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    ids = list(range(len(scores))) if ids is None else list(ids)
    w = np.maximum(scores, 1e-12)
    u = np.maximum(rng.random(len(w)), np.finfo(np.float64).tiny)
    keys = np.log(u) / w
    order = np.argsort(-keys, kind="stable")
    chosen: list[int] = []
    remaining = float(budget)
    for j in order:
        if costs[j] > remaining:
            break
        chosen.append(int(j))
        remaining -= float(costs[j])
    ts, tc = _totals(chosen, scores, costs)
    return SelectionResult([ids[j] for j in chosen], ts, tc)


def select_score_prop_batch(scores: np.ndarray, costs: np.ndarray,
                            budgets: np.ndarray,
                            rngs: Sequence[np.random.Generator],
                            valid: np.ndarray | None = None
                            ) -> list[tuple[np.ndarray, float, float]]:
    """Batched :func:`select_score_prop` over T concurrent tasks sharing
    the client pool columns.

    Per task the Efraimidis–Spirakis keys are drawn exactly as the
    serial path does (``rng.random`` over that task's *valid* clients,
    in valid-position order), then the T budget scans collapse into one
    vectorized ``(T, n)`` sweep: stable argsort of the stacked keys
    (invalid clients get ``-inf`` keys and ``+inf`` costs, so they sort
    last and act as hard stops, same as never being visited) and the
    same left-fold remaining-budget recurrence as
    ``engine.greedy_knapsack_batch``. Selections are bit-identical to
    running the serial sampler per task with the same generators
    (asserted in tests/test_scale_plane.py).

    Returns per task ``(positions in pick order, total_score,
    total_cost)`` — positions index into ``scores``/``costs``.
    """
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    T, n = budgets.shape[0], scores.shape[0]
    if valid is None:
        valid = np.ones((T, n), dtype=bool)
    else:
        valid = np.asarray(valid, dtype=bool)
    tiny = np.finfo(np.float64).tiny
    keys = np.full((T, n), -np.inf)
    w = np.maximum(scores, 1e-12)
    for t in range(T):                      # rng consumption stays serial
        cols = np.flatnonzero(valid[t])
        u = np.maximum(rngs[t].random(cols.size), tiny)
        keys[t, cols] = np.log(u) / w[cols]
    order = np.argsort(-keys, axis=1, kind="stable")      # (T, n)
    oc = np.where(np.take_along_axis(valid, order, axis=1),
                  costs[order], np.inf)
    rem = np.subtract.accumulate(
        np.concatenate([budgets[:, None], oc], axis=1), axis=1)[:, :-1]
    unaff = oc > rem
    first = np.where(unaff.any(axis=1), unaff.argmax(axis=1), n)
    out = []
    for t in range(T):
        picks = order[t, : first[t]]
        out.append((picks, float(scores[picks].sum()),
                    float(costs[picks].sum())))
    return out


# ---------------------------------------------------------------------------
# Full Stage-1 pipeline
# ---------------------------------------------------------------------------

def threshold_filter(profiles: Sequence[ClientProfile],
                     thresholds: np.ndarray | None) -> list[ClientProfile]:
    """Eq. (8d): keep clients whose thresholded criterion scores all meet
    the per-criterion minimums s_th (the paper thresholds s_1..s_9).

    Legacy dataclass path (per-profile loop); the array-native pipeline
    uses ``ClientPoolState.threshold_mask`` instead.
    """
    if thresholds is None:
        return list(profiles)
    th = np.asarray(thresholds, dtype=np.float64)
    kept = []
    for p in profiles:
        if np.all(p.scores[list(THRESHOLDED)] >= th[: len(THRESHOLDED)]):
            kept.append(p)
    return kept


def budget_floor(profiles: Sequence[ClientProfile] | ClientPoolState,
                 n_star: int) -> float:
    """Eq. (11): minimal budget = sum of the top-n* costs among filtered
    clients, guaranteeing the |S| >= n* constraint is satisfiable."""
    if isinstance(profiles, ClientPoolState):
        return profiles.budget_floor(n_star)
    costs = sorted((p.cost for p in profiles), reverse=True)
    return float(sum(costs[:n_star]))


def select_initial_pool(
    profiles: Sequence[ClientProfile] | ClientPoolState,
    budget: float,
    n_star: int = 1,
    thresholds: np.ndarray | None = None,
    method: str = "greedy",
    rng: np.random.Generator | None = None,
) -> SelectionResult:
    """Stage 1 end-to-end: filter -> feasibility -> knapsack (Eq. 12).

    Accepts a ``ClientPoolState`` (array-native fast path) or a profile
    list (converted once — thin adapter, same results). Filtering, score
    aggregation and the greedy knapsack are all masked array ops; no
    per-client Python work remains.
    """
    pool = (profiles if isinstance(profiles, ClientPoolState)
            else ClientPoolState.from_profiles(profiles))
    if method == "greedy" and isinstance(profiles, ClientPoolState):
        from . import device_pool
        if pool.n >= device_pool.HIERARCHICAL_MIN_N:
            return _select_initial_pool_hierarchical(
                pool, budget, n_star, thresholds)
    mask = pool.threshold_mask(thresholds)
    n_kept = int(mask.sum())
    if n_kept < n_star:
        return SelectionResult([], 0.0, 0.0, feasible=False,
                               note=f"only {n_kept} clients pass thresholds, need {n_star}")
    scores = pool.overall[mask]
    costs = pool.costs[mask]
    ids = pool.client_ids[mask].tolist()
    if method == "greedy":
        res = select_greedy(scores, costs, budget, ids)
    elif method == "dp":
        res = select_dp(scores, costs, budget, ids)
    elif method == "random":
        res = select_random(scores, costs, budget,
                            rng or np.random.default_rng(0), ids)
    elif method == "score_prop":
        res = select_score_prop(scores, costs, budget,
                                rng or np.random.default_rng(0), ids)
    else:
        raise ValueError(f"unknown method {method!r}")
    if len(res.selected) < n_star:
        res.feasible = False
        floor = pool.budget_floor(n_star, mask)
        res.note = (f"budget {budget} selects only {len(res.selected)} < n*={n_star} "
                    f"clients; Eq.(11) floor is {floor:.1f}")
    return res


def _select_initial_pool_hierarchical(
        pool: ClientPoolState, budget: float, n_star: int,
        thresholds: np.ndarray | None) -> SelectionResult:
    """Fleet-scale Stage 1: the two-level device-mirror greedy
    (``engine.hierarchical_greedy_knapsack``) behind the same contract
    as the flat path — identical ids in pick order, totals, and
    feasibility notes (asserted in tests/test_scale_plane.py). Entered
    from :func:`select_initial_pool` for ``method="greedy"`` pools at
    or above ``device_pool.HIERARCHICAL_MIN_N``; eligibility counting
    runs on the device mask, the Eq. (11) floor (infeasible path only)
    on the host mask."""
    rows, ts, tc, n_kept = engine.hierarchical_greedy_knapsack(
        pool, budget, thresholds)
    if n_kept < n_star:
        return SelectionResult(
            [], 0.0, 0.0, feasible=False,
            note=f"only {n_kept} clients pass thresholds, need {n_star}")
    res = SelectionResult(pool.client_ids[rows].tolist(), ts, tc)
    if len(res.selected) < n_star:
        res.feasible = False
        floor = pool.budget_floor(n_star, pool.threshold_mask(thresholds))
        res.note = (f"budget {budget} selects only {len(res.selected)} "
                    f"< n*={n_star} clients; Eq.(11) floor is {floor:.1f}")
    return res
