"""0-1 Multidimensional Knapsack (MKP) solver (paper §VI-B).

The paper solves its MKP instances with IBM CPLEX. CPLEX is not
available offline, so we implement the solver ourselves:

- ``solve_mkp_greedy`` — Toyoda-style pseudo-utility greedy: items are
  added in decreasing value per unit of *scarcity-weighted* capacity
  consumption, recomputed as knapsacks fill up; followed by a repair-free
  add pass and a 1-swap local search. This is the production path. The
  per-pick rescoring of all candidates is ``engine.mkp_pseudo_utility``
  (shared with the jax/Pallas path, see core/engine.py).
- ``solve_mkp_bnb`` — exact depth-first branch-and-bound with an
  LP-style fractional bound, for small instances; used by tests to bound
  the greedy's optimality gap and by the scheduler for tiny tail pools.

Conventions: ``values``(n,), ``weights``(n, m) [m knapsacks], and
``capacities``(m,). A selection S is feasible iff
``weights[S].sum(0) <= capacities`` elementwise and |S| <= max_size.
The subset-size *minimum* of problem (9b) is handled by the scheduler
(mandatory clients + complementary knapsacks), per the paper.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_EPS = 1e-12


@dataclasses.dataclass
class MKPResult:
    selected: list[int]
    value: float
    used: np.ndarray           # (m,) total weight per knapsack
    optimal: bool = False


def _check(values, weights, capacities):
    values = np.asarray(values, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    capacities = np.asarray(capacities, dtype=np.float64)
    if weights.ndim != 2 or weights.shape[0] != values.shape[0]:
        raise ValueError("weights must be (n_items, n_knapsacks)")
    if capacities.shape != (weights.shape[1],):
        raise ValueError("capacities must be (n_knapsacks,)")
    if np.any(weights < 0):
        raise ValueError("negative weights")
    return values, weights, capacities


def is_feasible(weights: np.ndarray, capacities: np.ndarray,
                selected: list[int], slack: float = 1e-9) -> bool:
    if not selected:
        return True
    return bool(np.all(weights[selected].sum(axis=0) <= capacities + slack))


# ---------------------------------------------------------------------------
# Greedy + local search
# ---------------------------------------------------------------------------

def solve_mkp_greedy(values, weights, capacities, max_size: int | None = None,
                     local_search: bool = True) -> MKPResult:
    values, weights, capacities = _check(values, weights, capacities)
    n, m = weights.shape
    max_size = n if max_size is None else int(max_size)

    selected: list[int] = []
    used = np.zeros(m)
    in_sel = np.zeros(n, dtype=bool)

    # -- pseudo-utility greedy (recompute scarcity each pick) --
    # The whole candidate set is rescored at once per pick; the scoring
    # formula lives in engine.mkp_pseudo_utility (one source of truth for
    # the numpy, jax and Pallas paths).
    from .engine import mkp_pseudo_utility
    while len(selected) < max_size:
        residual = capacities - used
        util, fits = mkp_pseudo_utility(values, weights, residual, ~in_sel)
        if not np.any(fits):
            break
        j = int(np.argmax(util))
        selected.append(j)
        in_sel[j] = True
        used += weights[j]

    # -- 1-swap local search: replace one selected with one unselected of
    # higher value if feasible; repeat until no improvement --
    if local_search and selected:
        improved = True
        order_out = np.argsort(values)  # try swapping low-value items out first
        while improved:
            improved = False
            for j_out in order_out:
                if not in_sel[j_out]:
                    continue
                residual = capacities - used + weights[j_out]
                cand = ~in_sel & (values > values[j_out] + _EPS) \
                    & np.all(weights <= residual + _EPS, axis=1)
                if np.any(cand):
                    j_in = int(np.argmax(np.where(cand, values, -np.inf)))
                    in_sel[j_out] = False
                    in_sel[j_in] = True
                    used = used - weights[j_out] + weights[j_in]
                    selected[selected.index(int(j_out))] = j_in
                    improved = True
            # greedy add pass after swaps freed capacity
            while len(selected) < max_size:
                residual = capacities - used
                fits = ~in_sel & np.all(weights <= residual + _EPS, axis=1)
                if not np.any(fits):
                    break
                j = int(np.argmax(np.where(fits, values, -np.inf)))
                selected.append(j)
                in_sel[j] = True
                used += weights[j]
                improved = True

    return MKPResult(sorted(selected), float(values[selected].sum()) if selected else 0.0,
                     used, optimal=False)


# ---------------------------------------------------------------------------
# Exact branch and bound (small instances / tests)
# ---------------------------------------------------------------------------

def _fractional_bound(values, weights, residual, order, start, max_items):
    """Upper bound for the remaining items ``order[start:]``.

    min of two valid relaxations:
      (a) the LP (fractional) bound of the single *tightest* knapsack,
          with that knapsack's items taken in its own density order
          (any multi-constraint optimum satisfies each single constraint);
      (b) the cardinality bound: sum of the ``max_items`` largest values.
    """
    rest = order[start:]
    if not rest or max_items <= 0:
        return 0.0
    rest_vals = values[rest]
    # (b) cardinality bound
    if len(rest) > max_items:
        card = float(np.sort(rest_vals)[-max_items:].sum())
    else:
        card = float(rest_vals.sum())
    # (a) single-knapsack fractional bound on the tightest knapsack
    denom = np.maximum(weights.mean(axis=0), _EPS)
    k = int(np.argmin(residual / denom))
    wk = weights[rest, k]
    dens = rest_vals / np.maximum(wk, _EPS)
    by_density = np.argsort(-dens, kind="stable")
    cap = residual[k]
    frac = 0.0
    for idx in by_density:
        w = wk[idx]
        if w <= _EPS or w <= cap:
            frac += rest_vals[idx]
            cap -= w
        else:
            frac += rest_vals[idx] * (cap / w)
            break
    return min(card, frac)


def solve_mkp_bnb(values, weights, capacities, max_size: int | None = None,
                  node_limit: int = 2_000_000) -> MKPResult:
    values, weights, capacities = _check(values, weights, capacities)
    n, m = weights.shape
    max_size = n if max_size is None else int(max_size)
    # order by single-knapsack density for bounding
    density = values / np.maximum(weights.sum(axis=1), _EPS)
    order = list(np.argsort(-density, kind="stable"))

    best_val = -1.0
    best_sel: list[int] = []
    nodes = 0

    # seed with greedy for pruning power
    g = solve_mkp_greedy(values, weights, capacities, max_size)
    best_val, best_sel = g.value, list(g.selected)

    stack = [(0, 0.0, capacities.copy(), [])]  # (depth, value, residual, chosen)
    while stack:
        nodes += 1
        if nodes > node_limit:
            break
        depth, val, residual, chosen = stack.pop()
        if val > best_val:
            best_val, best_sel = val, list(chosen)
        if depth >= n or len(chosen) >= max_size:
            continue
        ub = val + _fractional_bound(values, weights, residual, order, depth,
                                     max_size - len(chosen))
        if ub <= best_val + _EPS:
            continue
        j = order[depth]
        # branch: exclude j (pushed first -> explored last), include j
        stack.append((depth + 1, val, residual, chosen))
        if np.all(weights[j] <= residual + _EPS):
            stack.append((depth + 1, val + values[j], residual - weights[j],
                          chosen + [int(j)]))

    used = weights[best_sel].sum(axis=0) if best_sel else np.zeros(m)
    return MKPResult(sorted(best_sel), float(best_val), used,
                     optimal=nodes <= node_limit)


def solve_mkp(values, weights, capacities, max_size: int | None = None,
              exact_threshold: int = 18, backend: str = "numpy") -> MKPResult:
    """Dispatch: exact B&B for tiny instances, greedy+LS otherwise.

    ``backend="jax"`` routes large instances through the jit'd
    ``engine.solve_mkp_greedy_jax`` while-loop (Pallas utility update on
    TPU) — greedy phase only, no local search.
    """
    values = np.asarray(values, dtype=np.float64)
    if values.shape[0] <= exact_threshold:
        return solve_mkp_bnb(values, weights, capacities, max_size)
    if backend == "jax":
        from .engine import solve_mkp_greedy_jax
        mask, used = solve_mkp_greedy_jax(values, weights, capacities,
                                          max_size)
        sel = np.flatnonzero(mask)
        val = float(values[sel].sum()) if sel.size else 0.0
        return MKPResult([int(j) for j in sel], val,
                         np.asarray(used, dtype=np.float64), optimal=False)
    return solve_mkp_greedy(values, weights, capacities, max_size)
