"""Device-resident selection plane: the sharded jnp mirror of
``ClientPoolState`` (ROADMAP "million-client control plane").

``ClientPoolState`` stays the host-side source of truth — churn, id
maps, checkpointing and the dataclass adapters all live there — but at
fleet scale (1M–10M registered clients) the stage-1 hot path cannot
afford to re-stage host buffers onto the device (or re-argsort the full
pool) every sweep. :class:`DevicePoolState` keeps the columns stage 1
actually reads — overall scores, costs, the thresholded criterion
columns, and the registered/alive mask — as ``(num_shards, shard_cap)``
sharded jnp arrays, kept coherent through a **dirty-region sync
protocol**:

- every ``register``/``deregister`` on the host pool appends the
  touched rows to the pool's mutation log
  (``ClientPoolState.dirty_rows_since``);
- :meth:`DevicePoolState.sync` replays only those rows as in-place
  scatters (``.at[shards, lanes].set``) — thousands of churn events
  per sweep are absorbed in O(events) instead of O(pool), and no
  derived cache is invalidated wholesale;
- only when the log no longer reaches back to the mirror's synced
  version (a laggard mirror, or a bulk import) does the mirror fall
  back to a full restage.

Row ``r`` of the host pool lives at shard ``r // shard_cap``, lane
``r % shard_cap``; rows past ``pool.n`` are padding with
``registered=False``, so they can never enter a selection. Growth
appends whole shards (device arrays are immutable — an append is one
concatenate, not a per-row copy).

The mirror feeds the hierarchical two-level greedy
(:func:`repro.core.engine.hierarchical_greedy_knapsack`): per-shard
top-``k`` ratio frontiers via the ``segmented_topk`` Pallas kernel
(jnp oracle off-TPU), then an exact host-side merge. Precision note:
the mirror stores f32 — frontier *membership* and threshold masks are
decided in f32, while the final merge re-ranks candidates with the
host's f64 values (see ``docs/scaling.md`` for the tie-break
contract).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .criteria import THRESHOLDED, overall_score
from .pool import ClientPoolState

_EPS = 1e-12

# Geometry / routing defaults. ``HIERARCHICAL_MIN_N`` is the pool size
# above which the default greedy selection policy routes stage 1
# through the hierarchical device plane (tests shrink it to force the
# path at toy sizes; REPRO_HIERARCHICAL_MIN_N overrides it at launch).
DEFAULT_SHARD_CAP = 131072
HIERARCHICAL_MIN_N = 200_000

_THI = np.asarray(THRESHOLDED, dtype=np.int64)


def _load_env() -> None:
    import os
    global HIERARCHICAL_MIN_N
    v = os.environ.get("REPRO_HIERARCHICAL_MIN_N")
    if v:
        HIERARCHICAL_MIN_N = int(v)


_load_env()


@jax.jit
def _valid_registered(registered):
    return registered


@jax.jit
def _valid_thresholded(registered, th_scores, thresholds):
    return registered & jnp.all(th_scores >= thresholds, axis=-1)


@jax.jit
def _masked_ratio(overall, costs, valid):
    r = overall / jnp.maximum(costs, _EPS)
    return jnp.where(valid, r, -jnp.inf)


@jax.jit
def _shard_stats(costs, valid):
    """Per-shard valid counts (S,) plus the global valid cost sum —
    one fused pass, used for frontier sizing and feasibility."""
    counts = jnp.sum(valid, axis=1, dtype=jnp.int32)
    # f32 sum: only feeds the frontier-size estimate, precision ample
    cost_sum = jnp.sum(jnp.where(valid, costs, 0.0))
    return counts, cost_sum


@dataclasses.dataclass
class DevicePoolState:
    """Sharded device mirror of a host :class:`ClientPoolState`.

    All device arrays are ``(num_shards, shard_cap)`` (plus a trailing
    criteria/class axis where noted), f32/bool, padding rows
    unregistered. ``histograms`` is optional — stage 1 never reads it;
    mirror it only for device-side scheduling experiments.
    """

    shard_cap: int
    n_rows: int                       # host rows mirrored (pool.n)
    overall: jnp.ndarray              # (S, C) f32 — Eq. (6) scores
    costs: jnp.ndarray                # (S, C) f32
    th_scores: jnp.ndarray            # (S, C, len(THRESHOLDED)) f32
    registered: jnp.ndarray           # (S, C) bool — alive mask
    histograms: jnp.ndarray | None    # (S, C, c) f32, optional
    synced_version: int               # host pool.version at last sync
    syncs: int = 0                    # incremental syncs applied
    restages: int = 0                 # full restages (incl. the build)

    @property
    def num_shards(self) -> int:
        return int(self.overall.shape[0])

    @property
    def capacity(self) -> int:
        return self.num_shards * self.shard_cap

    # -- construction / sync -------------------------------------------------
    @classmethod
    def from_host(cls, pool: ClientPoolState, shard_cap: int | None = None,
                  include_histograms: bool = False) -> "DevicePoolState":
        cap = int(shard_cap or DEFAULT_SHARD_CAP)
        m = cls(shard_cap=cap, n_rows=0,
                overall=None, costs=None, th_scores=None, registered=None,
                histograms=None, synced_version=-1)
        m._restage(pool, include_histograms=include_histograms)
        return m

    def _restage(self, pool: ClientPoolState,
                 include_histograms: bool | None = None) -> None:
        """Full (re)staging: pad host columns to whole shards and ship
        them. O(pool) — the slow path the dirty-region sync avoids."""
        if include_histograms is None:
            include_histograms = self.histograms is not None
        n, cap = pool.n, self.shard_cap
        S = max(1, -(-n // cap))

        def shard(host, dtype, fill=0.0):
            a = np.asarray(host)
            out = np.full((S * cap,) + a.shape[1:], fill, dtype=dtype)
            out[:n] = a
            return jnp.asarray(out.reshape((S, cap) + a.shape[1:]))

        self.overall = shard(overall_score(pool.scores), np.float32)
        self.costs = shard(pool.costs, np.float32)
        self.th_scores = shard(pool.scores[:, _THI], np.float32)
        self.registered = shard(pool.registered, np.bool_, fill=False)
        self.histograms = shard(pool.histograms, np.float32) \
            if include_histograms else None
        self.n_rows = n
        self.synced_version = pool.version
        self.restages += 1

    def sync(self, pool: ClientPoolState) -> "DevicePoolState":
        """Bring the mirror up to the host pool's version.

        Fast path: replay the dirty rows logged since
        ``synced_version`` as in-place scatters — O(churn events), not
        O(pool). Appends whole shards first if the pool grew past the
        mirrored capacity. Falls back to a full restage when the log
        has been pruned past our watermark.
        """
        if pool.version == self.synced_version:
            return self
        rows = pool.dirty_rows_since(self.synced_version)
        if rows is None:
            self._restage(pool)
            return self
        cap = self.shard_cap
        if pool.n > self.capacity:              # grow by whole shards
            extra = -(-(pool.n - self.capacity) // cap)

            def pad(a, fill):
                blank = jnp.full((extra,) + a.shape[1:], fill, a.dtype)
                return jnp.concatenate([a, blank], axis=0)

            self.overall = pad(self.overall, 0.0)
            self.costs = pad(self.costs, 0.0)
            self.th_scores = pad(self.th_scores, 0.0)
            self.registered = pad(self.registered, False)
            if self.histograms is not None:
                self.histograms = pad(self.histograms, 0.0)
        if rows.size:
            # Bucket the scatter width to a power of two (>= 4096) so
            # XLA compiles one scatter per bucket, not one per distinct
            # churn-wave size; padding repeats row 0 (rewriting the same
            # value is a no-op), so correctness is unaffected.
            bucket = max(4096, 1 << int(np.ceil(np.log2(rows.size))))
            pad = bucket - rows.size
            if pad:
                rows = np.concatenate([rows, np.repeat(rows[:1], pad)])
            sh, ln = rows // cap, rows % cap
            scores = pool.scores[rows]          # O(events) host gathers
            self.overall = self.overall.at[sh, ln].set(
                jnp.asarray(overall_score(scores), jnp.float32))
            self.costs = self.costs.at[sh, ln].set(
                jnp.asarray(pool.costs[rows], jnp.float32))
            self.th_scores = self.th_scores.at[sh, ln].set(
                jnp.asarray(scores[:, _THI], jnp.float32))
            self.registered = self.registered.at[sh, ln].set(
                jnp.asarray(pool.registered[rows]))
            if self.histograms is not None:
                self.histograms = self.histograms.at[sh, ln].set(
                    jnp.asarray(pool.histograms[rows], jnp.float32))
        self.n_rows = pool.n
        self.synced_version = pool.version
        self.syncs += 1
        return self

    # -- stage-1 device queries ----------------------------------------------
    def valid_mask(self, thresholds: np.ndarray | None) -> jnp.ndarray:
        """(S, C) bool eligibility under Eq. (8d): registered, and all
        thresholded criteria at/above their minimums (f32 compare)."""
        if thresholds is None:
            return _valid_registered(self.registered)
        th = jnp.asarray(np.asarray(thresholds, np.float64)[: _THI.size],
                         jnp.float32)
        return _valid_thresholded(self.registered, self.th_scores, th)

    def masked_ratio(self, valid: jnp.ndarray) -> jnp.ndarray:
        """(S, C) f32 score/cost greedy ratios, ``-inf`` outside
        ``valid`` (the segmented top-k input)."""
        return _masked_ratio(self.overall, self.costs, valid)

    def shard_stats(self, valid: jnp.ndarray) -> tuple[np.ndarray, float]:
        """((S,) per-shard valid counts, total valid cost) on host."""
        counts, cost_sum = _shard_stats(self.costs, valid)
        return np.asarray(counts), float(cost_sum)

    def frontier(self, ratio: jnp.ndarray, k: int,
                 interpret: bool | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
        """Per-shard top-``k`` frontier of ``ratio``: host-side
        ``(values (S, k) f32, global row indices (S, k) int64)`` via the
        ``segmented_topk`` kernel (Pallas on TPU, jnp oracle on CPU)."""
        from ..kernels import ops
        vals, lanes = ops.segmented_topk(ratio, int(k), interpret=interpret)
        vals = np.asarray(vals)
        rows = (np.arange(self.num_shards, dtype=np.int64)[:, None]
                * self.shard_cap + np.asarray(lanes, np.int64))
        return vals, rows
