"""Reputation tracking and pool maintenance (paper §V-B steps 2-4).

Per-round model quality q_t = sim(w_l, w_g) (Eq. in §IV-C) and behavior
b_t ∈ {0,1} (Eq. 4) are recorded for each participating client; per-task
values are the averages over participated rounds (Eqs. 3/5); the
reputation score is s_rep = q_task + b_task.

``ReputationTracker`` stores everything as struct-of-arrays keyed by
pool position: per-round q/b histories live in ``(P, C)`` buffers
(capacity-doubled on both axes) next to the per-client round cursor and
suspension counter, so the whole tracker serializes to plain numpy
arrays (``to_arrays``/``from_arrays`` — the ``core.lifecycle`` TaskState
checkpoint path) with no dataclass pickling. The legacy per-client
``records`` mapping survives as a live view: ``tracker.records[cid]``
returns a :class:`ReputationRecord` proxy whose ``q_rounds``/``b_rounds``
are array slices of the shared buffers.

``update_pool`` implements step 4 of the scheduling period:
  - remove clients unavailable in the next period;
  - remove clients with bad reputation in the current period (suspend);
  - re-add clients whose suspension has expired.
"""
from __future__ import annotations

from typing import Iterator, Mapping, Sequence

import numpy as np

from .criteria import cosine_similarity, per_task_average


class ReputationRecord:
    """Per-client view into a :class:`ReputationTracker`'s arrays.

    Mirrors the pre-SoA dataclass API (``q_rounds``, ``b_rounds``,
    ``q_task``, ``b_task``, ``s_rep``, ``suspended_until``) but owns no
    storage: reads and writes go straight to the tracker's buffers.
    """

    __slots__ = ("_tracker", "_pos")

    def __init__(self, tracker: "ReputationTracker", pos: int):
        self._tracker = tracker
        self._pos = int(pos)

    @property
    def num_rounds(self) -> int:
        return int(self._tracker._n[self._pos])

    @property
    def q_rounds(self) -> np.ndarray:
        return self._tracker._q[self._pos, : self.num_rounds]

    @property
    def b_rounds(self) -> np.ndarray:
        return self._tracker._b[self._pos, : self.num_rounds]

    @property
    def q_task(self) -> float:
        return per_task_average(self.q_rounds)

    @property
    def b_task(self) -> float:
        return per_task_average(self.b_rounds)

    @property
    def s_rep(self) -> float:
        """s_rep = q_task + b_task (paper §V-B)."""
        return self.q_task + self.b_task

    @property
    def suspended_until(self) -> int:
        return int(self._tracker._susp[self._pos])

    @suspended_until.setter
    def suspended_until(self, period: int) -> None:
        self._tracker._susp[self._pos] = int(period)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ReputationRecord(rounds={self.num_rounds}, "
                f"s_rep={self.s_rep:.3f}, "
                f"suspended_until={self.suspended_until})")


class _RecordsView(Mapping):
    """Dict-compatible live view: ``client_id -> ReputationRecord``."""

    __slots__ = ("_tracker",)

    def __init__(self, tracker: "ReputationTracker"):
        self._tracker = tracker

    def __getitem__(self, client_id: int) -> ReputationRecord:
        return ReputationRecord(self._tracker,
                                self._tracker._pos[int(client_id)])

    def __iter__(self) -> Iterator[int]:
        return iter(self._tracker._pos)

    def __len__(self) -> int:
        return len(self._tracker._pos)

    def __contains__(self, client_id) -> bool:
        return int(client_id) in self._tracker._pos


class ReputationTracker:
    """Tracks per-round scores within one FL task and maintains the pool.

    Struct-of-arrays over pool positions: row ``i`` belongs to
    ``client_ids[i]`` (insertion order — stage-1 selection order, then
    any churn admissions via :meth:`add_clients`).
    """

    _ROUNDS_CAP0 = 8     # initial per-client round capacity

    def __init__(self, client_ids, suspension_periods: int = 1,
                 rep_threshold: float = 0.5):
        ids = [int(k) for k in client_ids]
        self.suspension_periods = int(suspension_periods)
        self.rep_threshold = float(rep_threshold)
        self.period = 0
        P = len(ids)
        self._ids = np.array(ids, dtype=np.int64)
        self._q = np.zeros((P, self._ROUNDS_CAP0), dtype=np.float64)
        self._b = np.zeros((P, self._ROUNDS_CAP0), dtype=np.float64)
        self._n = np.zeros(P, dtype=np.int64)          # per-client cursor
        self._susp = np.full(P, -1, dtype=np.int64)    # suspended until
        self._tf = np.zeros(P, dtype=np.int64)         # timing failures:
        # rounds this client was scheduled but missed the collect close
        # (fed by lifecycle fault-mode dispatch; selection policies read
        # it to penalize chronic stragglers)
        self._pos = {cid: i for i, cid in enumerate(ids)}
        if len(self._pos) != P:
            raise ValueError("duplicate client ids")

    # -- shape / views -------------------------------------------------------
    @property
    def client_ids(self) -> np.ndarray:
        return self._ids

    @property
    def records(self) -> _RecordsView:
        """Legacy ``dict[int, record]`` compatibility view (live)."""
        return _RecordsView(self)

    def add_clients(self, client_ids) -> None:
        """Register additional clients (churn admissions between periods).

        New rows start with zero rounds and no suspension, exactly like
        clients present from stage 1.
        """
        new = []
        for k in client_ids:
            k = int(k)
            if k in self._pos:
                raise ValueError(f"client {k} already tracked")
            new.append(k)
        if not new:
            return
        P, C = self._q.shape
        self._ids = np.concatenate([self._ids,
                                    np.array(new, dtype=np.int64)])
        grow = np.zeros((len(new), C), dtype=np.float64)
        self._q = np.concatenate([self._q, grow])
        self._b = np.concatenate([self._b, grow.copy()])
        self._n = np.concatenate([self._n, np.zeros(len(new), np.int64)])
        self._susp = np.concatenate([self._susp,
                                     np.full(len(new), -1, np.int64)])
        self._tf = np.concatenate([self._tf,
                                   np.zeros(len(new), np.int64)])
        for j, cid in enumerate(new):
            self._pos[cid] = P + j

    def _grow_rounds(self) -> None:
        P, C = self._q.shape
        pad = np.zeros((P, C), dtype=np.float64)
        self._q = np.concatenate([self._q, pad], axis=1)
        self._b = np.concatenate([self._b, pad.copy()], axis=1)

    # -- step 2: per-round updates -----------------------------------------
    def record_round(self, client_id: int, returned: bool,
                     local_update=None, global_update=None,
                     q_value: float | None = None) -> None:
        """Record one round's participation for one client.

        q_t is the cosine similarity between the client's local update and
        the aggregated global update (computed by the caller or here from
        the raw vectors); on a dropped round (returned=False) q_t
        contributes 0 and b_t = 0 per Eq. (4).
        """
        i = self._pos[int(client_id)]
        if returned:
            if q_value is None:
                if local_update is None or global_update is None:
                    raise ValueError(
                        "need q_value or (local_update, global_update)")
                q_value = cosine_similarity(local_update, global_update)
            q, b = float(q_value), 1.0
        else:
            q, b = 0.0, 0.0
        j = int(self._n[i])
        if j >= self._q.shape[1]:
            self._grow_rounds()
        self._q[i, j] = q
        self._b[i, j] = b
        self._n[i] = j + 1

    def record_timeout(self, client_id: int) -> None:
        """Charge one timing failure: the client was scheduled for a
        round but had not reported by the round's close (straggler,
        crash, or outage under a fault plan). Orthogonal to
        :meth:`record_round` — a timed-out client of a *committed* round
        is additionally recorded there as ``returned=False``."""
        self._tf[self._pos[int(client_id)]] += 1

    def timeout_counts(self) -> dict[int, int]:
        """``client_id -> timing failures`` over the task so far."""
        return {int(cid): int(self._tf[i])
                for i, cid in enumerate(self._ids)}

    @property
    def timeout_failures(self) -> np.ndarray:
        """(P,) int64 — timing failures per tracked client, aligned with
        :attr:`client_ids`. Copy; mutating it does not touch the
        tracker. The lifecycle publishes this as the ``obs/timeouts``
        policy-state column every period (docs/workloads.md)."""
        return self._tf.copy()

    @property
    def round_counts(self) -> np.ndarray:
        """(P,) int64 — committed rounds recorded per tracked client,
        aligned with :attr:`client_ids` (copy; the ``obs/rounds``
        column)."""
        return self._n.copy()

    # -- steps 3-4: period rollover -----------------------------------------
    def update_pool(self, pool: set[int],
                    availability: Mapping[int, bool] | None = None) -> set[int]:
        """End-of-period pool update. Returns the new active pool."""
        availability = availability or {}
        self.period += 1
        new_pool = set()
        for cid, rec in self.records.items():
            if rec.suspended_until >= self.period:
                continue  # still suspended
            if not availability.get(cid, True):
                continue  # unavailable next period (comes back when available)
            participated = cid in pool and rec.num_rounds > 0
            if participated and rec.s_rep < self.rep_threshold:
                rec.suspended_until = self.period + self.suspension_periods - 1
                continue  # bad reputation: suspend
            new_pool.add(cid)
        return new_pool

    def scores(self) -> dict[int, float]:
        return {cid: rec.s_rep for cid, rec in self.records.items()}

    # -- serialization (TaskState checkpoint path) ---------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        """Flat numpy-array form (no dataclasses, no pickle)."""
        C = int(self._n.max()) if self._n.size else 0
        return {
            "ids": self._ids.copy(),
            "q": self._q[:, :C].copy(),
            "b": self._b[:, :C].copy(),
            "n": self._n.copy(),
            "suspended": self._susp.copy(),
            "meta": np.array([self.period, self.suspension_periods],
                             dtype=np.int64),
            "threshold": np.array([self.rep_threshold], dtype=np.float64),
            "tf": self._tf.copy(),
        }

    @classmethod
    def from_arrays(cls, arrays: Mapping[str, np.ndarray]) -> "ReputationTracker":
        meta = np.asarray(arrays["meta"], dtype=np.int64)
        tr = cls(np.asarray(arrays["ids"], dtype=np.int64),
                 suspension_periods=int(meta[1]),
                 rep_threshold=float(np.asarray(arrays["threshold"])[0]))
        tr.period = int(meta[0])
        P = tr._ids.size
        q = np.asarray(arrays["q"], dtype=np.float64)
        b = np.asarray(arrays["b"], dtype=np.float64)
        q = q.reshape(P, -1) if q.size else q.reshape(P, 0)
        b = b.reshape(P, -1) if b.size else b.reshape(P, 0)
        C = max(q.shape[1], cls._ROUNDS_CAP0)
        tr._q = np.zeros((P, C), dtype=np.float64)
        tr._b = np.zeros((P, C), dtype=np.float64)
        tr._q[:, : q.shape[1]] = q
        tr._b[:, : b.shape[1]] = b
        tr._n = np.asarray(arrays["n"], dtype=np.int64).copy()
        tr._susp = np.asarray(arrays["suspended"], dtype=np.int64).copy()
        tf = arrays.get("tf")      # absent in pre-fault checkpoints
        if tf is not None:
            tr._tf = np.asarray(tf, dtype=np.int64).copy()
        return tr


def model_quality_batch(local_updates: np.ndarray,
                        global_update: np.ndarray) -> np.ndarray:
    """Vectorized q_t for a round: cosine(local_k, global) for each k.

    local_updates: (K, P) flattened client updates; global_update: (P,).
    """
    L = np.asarray(local_updates, dtype=np.float64)
    g = np.asarray(global_update, dtype=np.float64).ravel()
    ln = np.linalg.norm(L, axis=1)
    gn = np.linalg.norm(g)
    denom = np.maximum(ln * gn, 1e-12)
    return (L @ g) / denom
