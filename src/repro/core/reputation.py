"""Reputation tracking and pool maintenance (paper §V-B steps 2-4).

Per-round model quality q_t = sim(w_l, w_g) (Eq. in §IV-C) and behavior
b_t ∈ {0,1} (Eq. 4) are recorded for each participating client; per-task
values are the averages over participated rounds (Eqs. 3/5); the
reputation score is s_rep = q_task + b_task.

``update_pool`` implements step 4 of the scheduling period:
  - remove clients unavailable in the next period;
  - remove clients with bad reputation in the current period (suspend);
  - re-add clients whose suspension has expired.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from .criteria import cosine_similarity, per_task_average


@dataclasses.dataclass
class ReputationRecord:
    q_rounds: list = dataclasses.field(default_factory=list)   # per-round q_t
    b_rounds: list = dataclasses.field(default_factory=list)   # per-round b_t
    suspended_until: int = -1    # period index until which the client is out

    @property
    def q_task(self) -> float:
        return per_task_average(self.q_rounds)

    @property
    def b_task(self) -> float:
        return per_task_average(self.b_rounds)

    @property
    def s_rep(self) -> float:
        """s_rep = q_task + b_task (paper §V-B)."""
        return self.q_task + self.b_task


class ReputationTracker:
    """Tracks per-round scores within one FL task and maintains the pool."""

    def __init__(self, client_ids, suspension_periods: int = 1,
                 rep_threshold: float = 0.5):
        self.records: dict[int, ReputationRecord] = {
            int(k): ReputationRecord() for k in client_ids}
        self.suspension_periods = int(suspension_periods)
        self.rep_threshold = float(rep_threshold)
        self.period = 0

    # -- step 2: per-round updates -----------------------------------------
    def record_round(self, client_id: int, returned: bool,
                     local_update=None, global_update=None,
                     q_value: float | None = None) -> None:
        """Record one round's participation for one client.

        q_t is the cosine similarity between the client's local update and
        the aggregated global update (computed by the caller or here from
        the raw vectors); on a dropped round (returned=False) q_t
        contributes 0 and b_t = 0 per Eq. (4).
        """
        rec = self.records[int(client_id)]
        rec.b_rounds.append(1.0 if returned else 0.0)
        if not returned:
            rec.q_rounds.append(0.0)
            return
        if q_value is None:
            if local_update is None or global_update is None:
                raise ValueError("need q_value or (local_update, global_update)")
            q_value = cosine_similarity(local_update, global_update)
        rec.q_rounds.append(float(q_value))

    # -- steps 3-4: period rollover -----------------------------------------
    def update_pool(self, pool: set[int],
                    availability: Mapping[int, bool] | None = None) -> set[int]:
        """End-of-period pool update. Returns the new active pool."""
        availability = availability or {}
        self.period += 1
        new_pool = set()
        for cid, rec in self.records.items():
            if rec.suspended_until >= self.period:
                continue  # still suspended
            if not availability.get(cid, True):
                continue  # unavailable next period (comes back when available)
            participated = cid in pool and len(rec.b_rounds) > 0
            if participated and rec.s_rep < self.rep_threshold:
                rec.suspended_until = self.period + self.suspension_periods - 1
                continue  # bad reputation: suspend
            new_pool.add(cid)
        return new_pool

    def scores(self) -> dict[int, float]:
        return {cid: rec.s_rep for cid, rec in self.records.items()}


def model_quality_batch(local_updates: np.ndarray,
                        global_update: np.ndarray) -> np.ndarray:
    """Vectorized q_t for a round: cosine(local_k, global) for each k.

    local_updates: (K, P) flattened client updates; global_update: (P,).
    """
    L = np.asarray(local_updates, dtype=np.float64)
    g = np.asarray(global_update, dtype=np.float64).ravel()
    ln = np.linalg.norm(L, axis=1)
    gn = np.linalg.norm(g)
    denom = np.maximum(ln * gn, 1e-12)
    return (L @ g) / denom
