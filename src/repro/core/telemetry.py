"""SLA telemetry for the online workload harness.

The online driver (:mod:`repro.core.driver`) appends one
:class:`TelemetryEvent` per observable service action — task submitted,
submission rejected (backpressure), task accepted into INTAKE, round
completed, task reaching a terminal phase — all stamped with the
driver's virtual clock. :meth:`TelemetryLog.summary` folds the log into
the SLA aggregates the workload bench publishes
(``BENCH_service.json["workload"]``, field docs in docs/benchmarks.md):

- ``round_latency_p50`` / ``round_latency_p99`` — per-round simulated
  latency (the lifecycle's fault-mode ``metrics["round_latency"]``);
- ``queue_wait_p50`` / ``queue_wait_p99`` — trace arrival → accepted
  into INTAKE, i.e. time spent bouncing off ``max_queue`` backpressure
  plus retry backoff;
- ``completion_p50`` / ``completion_p99`` — trace arrival → terminal
  phase, the end-to-end task SLO;
- ``degraded_rate`` — fraction of finished tasks parked DEGRADED
  rather than DONE;
- ``jain_fairness`` — Jain's index over realized per-client round
  participation counts across all tasks (fairness under contention);
- plus counters: ``tasks_submitted`` / ``tasks_finished`` /
  ``rejects`` / ``rounds`` / ``makespan``.

The log is plain data (no service references), so benches can merge,
diff and JSON-serialize summaries freely.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

from .fairness import jain_index


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One driver-observed service action at virtual time ``time``.

    ``kind`` is one of ``submit`` / ``reject`` / ``accept`` / ``round``
    / ``done``; ``task`` is the driver's trace-arrival index (stable
    across rejects/requeues — the scheduler's tid only exists after
    acceptance and lives in ``data["tid"]``).
    """

    kind: str
    time: float
    task: int
    data: dict


class TelemetryLog:
    """Append-only event log + SLA aggregation."""

    def __init__(self) -> None:
        self.events: list[TelemetryEvent] = []
        # realized participation: client id -> rounds participated
        self.participation: Counter = Counter()

    # -- recording (called by the driver) -----------------------------------

    def record(self, kind: str, time: float, task: int, **data) -> None:
        self.events.append(TelemetryEvent(kind, float(time), int(task), data))

    def record_round(self, time: float, task: int, event) -> None:
        """Fold one lifecycle :class:`RoundEvent` in (participation +
        latency metrics when the fault path emitted them)."""
        for cid in event.subset:
            self.participation[int(cid)] += 1
        self.record("round", time, task,
                    period=event.period, round_index=event.round_index,
                    round_latency=event.metrics.get("round_latency"),
                    n_scheduled=event.metrics.get("n_scheduled"),
                    n_arrived=event.metrics.get("n_arrived"))

    # -- views ---------------------------------------------------------------

    def of_kind(self, kind: str) -> list[TelemetryEvent]:
        return [e for e in self.events if e.kind == kind]

    def round_latencies(self) -> np.ndarray:
        lat = [e.data["round_latency"] for e in self.of_kind("round")
               if e.data.get("round_latency") is not None]
        return np.asarray(lat, dtype=np.float64)

    def queue_waits(self) -> np.ndarray:
        """Arrival -> acceptance delay per accepted task."""
        arrived = {e.task: e.data["arrival"] for e in self.of_kind("submit")}
        return np.asarray([e.time - arrived[e.task]
                           for e in self.of_kind("accept")
                           if e.task in arrived], dtype=np.float64)

    def completions(self) -> np.ndarray:
        """Arrival -> terminal-phase delay per finished task."""
        arrived = {e.task: e.data["arrival"] for e in self.of_kind("submit")}
        return np.asarray([e.time - arrived[e.task]
                           for e in self.of_kind("done")
                           if e.task in arrived], dtype=np.float64)

    # -- aggregation ---------------------------------------------------------

    def summary(self) -> dict:
        """The SLA aggregate dict (all plain floats/ints, JSON-ready)."""
        done = self.of_kind("done")
        degraded = sum(1 for e in done if e.data.get("phase") == "DEGRADED")
        counts = np.asarray(sorted(self.participation.values()),
                            dtype=np.float64)
        out = {
            "tasks_submitted": len(self.of_kind("submit")),
            "tasks_finished": len(done),
            "rejects": len(self.of_kind("reject")),
            "rounds": len(self.of_kind("round")),
            "degraded_rate": round(degraded / max(len(done), 1), 4),
            "jain_fairness": (round(float(jain_index(counts)), 4)
                              if counts.size else 1.0),
            "makespan": round(max((e.time for e in self.events),
                                  default=0.0), 3),
        }
        for name, values in (("round_latency", self.round_latencies()),
                             ("queue_wait", self.queue_waits()),
                             ("completion", self.completions())):
            out[f"{name}_p50"] = _pct(values, 50)
            out[f"{name}_p99"] = _pct(values, 99)
        return out

    def format_summary(self) -> str:
        """Human-readable SLA table (the demo prints this)."""
        s = self.summary()
        rows = [("tasks (submitted/finished)",
                 f"{s['tasks_submitted']} / {s['tasks_finished']}"),
                ("backpressure rejects", str(s["rejects"])),
                ("rounds", str(s["rounds"])),
                ("round latency p50 / p99",
                 f"{s['round_latency_p50']} / {s['round_latency_p99']}"),
                ("queue wait p50 / p99",
                 f"{s['queue_wait_p50']} / {s['queue_wait_p99']}"),
                ("completion p50 / p99",
                 f"{s['completion_p50']} / {s['completion_p99']}"),
                ("DEGRADED rate", f"{s['degraded_rate']:.2%}"),
                ("Jain fairness (participation)",
                 f"{s['jain_fairness']:.4f}"),
                ("makespan (sim time)", str(s["makespan"]))]
        width = max(len(k) for k, _ in rows)
        return "\n".join(f"  {k.ljust(width)}  {v}" for k, v in rows)


def _pct(values: np.ndarray, q: float) -> float | None:
    if values.size == 0:
        return None
    return round(float(np.percentile(values, q)), 3)
