"""Virtual-clock online driver: trace-driven `ServiceScheduler` runs.

Everything before ISSUE-8 drove the service *offline*: submit a fixed
fleet of tasks, sweep until quiet. :class:`OnlineDriver` replays a
:class:`~repro.core.workload.WorkloadTrace` against a live
:class:`~repro.core.lifecycle.ServiceScheduler` on a **virtual clock**:

- tasks are submitted when their trace arrival time is reached (the
  template builds each :class:`TaskRequest` from its arrival index, so
  arms sharing a trace see identical traffic);
- a :class:`~repro.core.lifecycle.RejectedTask` (``max_queue``
  backpressure) is **requeued from its own echo** — the rejection
  carries the request plus the queue depth, so the driver needs no
  side-channel bookkeeping — with exponential backoff
  (``backoff * 2**attempt``); no task is ever silently dropped
  (property-tested in tests/test_workload.py);
- the trace's diurnal availability wave is adapted onto the
  lifecycle's ``availability_fn`` seam, evaluated at the *virtual
  time* each period checkpoint actually happens;
- after every sweep the clock advances by the wall-clock of that
  sweep's simulated work: tenants run concurrently, so the sweep
  duration is the **max** over tenants of their chunk's summed
  ``round_latency`` metrics (``default_round_latency`` per round when
  the trainer carries no fault plan, ``idle_tick`` when the sweep did
  nothing but the service still waits);
- every observable action lands in a
  :class:`~repro.core.telemetry.TelemetryLog` with virtual timestamps,
  and terminal tenants are retired so the pool of live tenants stays
  bounded no matter how long the trace runs.

With an empty trace (``initial_tasks`` only, no availability, no
plan), the driver performs *exactly* the submit-then-sweep sequence of
driving ``ServiceScheduler`` by hand — the no-trace path is
bit-identical to the offline scheduler (asserted in tests and in
benchmarks/bench_workload.py).
"""
from __future__ import annotations

import heapq

from .lifecycle import RejectedTask, ServiceScheduler
from .telemetry import TelemetryLog
from .workload import WorkloadTrace


class OnlineDriver:
    """Drive ``scheduler`` with ``trace``, return SLA telemetry.

    ``trainer_factory()`` builds one trainer per accepted task (the
    driver attaches ``trace.plan`` to it when the trainer exposes a
    ``fault_plan`` attribute and the factory left it unset, so traces
    carry device behaviour without the factory knowing). ``scheduler``
    is caller-built — backpressure (``max_queue``), the in-flight
    window and eviction deadlines are service configuration, not trace
    configuration.
    """

    def __init__(self, scheduler: ServiceScheduler, trace: WorkloadTrace,
                 trainer_factory, *, backoff: float = 1.0,
                 backoff_cap: float = 64.0,
                 default_round_latency: float = 1.0, idle_tick: float = 1.0,
                 max_sweeps: int = 100_000):
        self.scheduler = scheduler
        self.trace = trace
        self.trainer_factory = trainer_factory
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)   # max retry delay: keeps
        # repeated rejections from exploding the exponential into the
        # dominant completion-time term (the queue, not the backoff,
        # should set the SLA under saturation)
        self.default_round_latency = float(default_round_latency)
        self.idle_tick = float(idle_tick)
        self.max_sweeps = int(max_sweeps)
        self.telemetry = TelemetryLog()
        self.now = 0.0
        # task_index -> (tid, arrival_time) for accepted, live tenants
        self._live: dict[int, tuple[int, float]] = {}
        self.phases: dict[int, str] = {}      # task_index -> terminal phase
        self.results: dict[int, list] = {}    # task_index -> [RoundEvent]

    # -- internals -----------------------------------------------------------

    def _availability_fn(self):
        if self.trace.availability is None:
            return None
        return self.trace.availability.availability_fn(lambda: self.now)

    def _make_trainer(self):
        trainer = self.trainer_factory()
        if (self.trace.plan is not None
                and getattr(trainer, "fault_plan", None) is None
                and hasattr(trainer, "fault_plan")):
            trainer.fault_plan = self.trace.plan
        return trainer

    def _submit(self, index: int, task, arrival: float, attempt: int,
                retries: list) -> None:
        out = self.scheduler.submit(task, self._make_trainer(),
                                    availability_fn=self._availability_fn())
        if isinstance(out, RejectedTask):
            # requeue from the echo: out.task IS the request, out.queued
            # the backlog depth — nothing else needed to resubmit
            delay = min(self.backoff * (2.0 ** attempt), self.backoff_cap)
            self.telemetry.record("reject", self.now, index,
                                  queued=out.queued, reason=out.reason,
                                  attempt=attempt, retry_at=self.now + delay)
            heapq.heappush(retries,
                           (self.now + delay, index, attempt + 1, out.task))
        else:
            # device_of: placement fabric seam (core/placement.py) — which
            # mesh device the tenant's chunks dispatch on. 0 until the
            # scheduler's next sweep places the tenant.
            self.telemetry.record("accept", self.now, index, tid=int(out),
                                  attempt=attempt,
                                  device=self.scheduler.device_of(int(out)))
            self._live[index] = (int(out), arrival)

    def _sweep_duration(self, swept: dict) -> float:
        if not swept:
            return self.idle_tick
        per_tenant = [sum(e.metrics.get("round_latency",
                                        self.default_round_latency)
                          for e in evs)
                      for evs in swept.values() if evs]
        return max(per_tenant) if per_tenant else self.idle_tick

    # -- the loop ------------------------------------------------------------

    def run(self, initial_tasks: list | None = None) -> TelemetryLog:
        """Replay the trace to completion; returns the telemetry log.

        ``initial_tasks`` are submitted at time zero ahead of any trace
        arrival (the no-trace identity path uses only these).
        """
        arrivals: list[tuple[float, int, object]] = []
        for i, task in enumerate(initial_tasks or []):
            arrivals.append((0.0, i, task))
        base = len(arrivals)
        for j, t in enumerate(self.trace.arrivals.arrivals(
                self.trace.horizon)):
            task = self.trace.template(base + j, float(t))
            arrivals.append((float(t), base + j, task))
        arrivals.sort(key=lambda a: (a[0], a[1]))

        retries: list[tuple[float, int, int, object]] = []  # (due, idx, att, task)
        cursor = 0
        sweeps = 0
        while True:
            # 1) submit everything due at the current virtual time, in
            # time order across fresh arrivals and backoff retries
            while True:
                fresh_due = (cursor < len(arrivals)
                             and arrivals[cursor][0] <= self.now)
                retry_due = retries and retries[0][0] <= self.now
                if fresh_due and (not retry_due
                                  or arrivals[cursor][0] <= retries[0][0]):
                    t_arr, idx, task = arrivals[cursor]
                    cursor += 1
                    # observed now (>= t_arr when a long sweep jumped
                    # the clock past the arrival); queue-wait is
                    # measured from the trace arrival either way
                    self.telemetry.record("submit", self.now, idx,
                                          arrival=t_arr)
                    self._submit(idx, task, t_arr, 0, retries)
                elif retry_due:
                    _, idx, attempt, task = heapq.heappop(retries)
                    arrival = dict((e.task, e.data["arrival"])
                                   for e in self.telemetry.of_kind("submit")
                                   )[idx]
                    self._submit(idx, task, arrival, attempt, retries)
                else:
                    break

            pending = cursor < len(arrivals) or bool(retries)
            if not self.scheduler.active and not pending:
                break               # drained: all tasks terminal + retired
            if sweeps >= self.max_sweeps:
                break               # safety valve; telemetry still valid

            if self.scheduler.active:
                # 2) one sweep of real work, clock += its wall time
                swept = self.scheduler.sweep()
                sweeps += 1
                self.now += self._sweep_duration(swept)
                for tid, evs in swept.items():
                    index = self._tid_index(tid)
                    self.results.setdefault(index, []).extend(evs)
                    for e in evs:
                        self.telemetry.record_round(self.now, index, e)
                self._retire_terminal()
            else:
                # 3) idle service, future arrivals: jump to the next due
                nxt = min(([arrivals[cursor][0]]
                           if cursor < len(arrivals) else [])
                          + ([retries[0][0]] if retries else []))
                self.now = max(self.now, nxt)
        return self.telemetry

    def _tid_index(self, tid: int) -> int:
        for index, (t, _) in self._live.items():
            if t == tid:
                return index
        return -1

    def _retire_terminal(self) -> None:
        for index in list(self._live):
            tid, arrival = self._live[index]
            st = self.scheduler.state(tid)
            if st.phase.terminal:
                self.phases[index] = st.phase.name
                self.telemetry.record("done", self.now, index,
                                      tid=tid, phase=st.phase.name,
                                      periods=st.period,
                                      device=self.scheduler.device_of(tid))
                self.scheduler.retire(tid)
                del self._live[index]
