"""Resumable service lifecycle: the task state machine behind the FL
service provider (paper §III Fig. 1, deployed form).

The blocking ``FLServiceProvider.run_task`` loop owned the Python
control flow for a task's whole lifetime: one task, one frozen client
registry, convergence-or-bust. This module inverts that control. A task
is an explicit, serializable :class:`TaskState` advanced by *pure-ish*
transition functions::

    INTAKE -> POOL_SELECTED -> SCHEDULED -> TRAINING -> ... -> TRAINING
                 ^                                               |
                 +--------------- PERIOD_CHECKPOINT <------------+
                                        |
                                DONE / INFEASIBLE

- :func:`submit` runs stage 1 (pool selection) and returns the state;
- :func:`step` advances exactly one transition, returning the new state
  plus the :class:`RoundEvent` s it produced (a TRAINING step dispatches
  one round chunk to the trainer; everything else is bookkeeping);
- :func:`drain` is the convenience loop (step until DONE/INFEASIBLE) —
  ``run_task`` is now a deprecated shim over ``submit`` + ``drain`` that
  reproduces the pre-redesign results bit-for-bit.

The TRAINING transition additionally splits into an asynchronous half
pair (ISSUE-4 overlapped dispatch):

- :func:`dispatch` *enqueues* one round chunk — an
  :class:`AsyncTrainer` returns an opaque handle over still-unmaterialized
  device arrays (JAX async dispatch), a plain :class:`Trainer` falls
  back to running the chunk eagerly — and parks it on
  ``TaskState.pending``;
- :func:`collect` materializes the pending handle into
  :class:`RoundEvent` s and advances the phase exactly as a blocking
  step would have.

``step`` on a SCHEDULED/TRAINING state is literally ``dispatch`` +
``collect``, so stepping stays bit-identical to the pre-split code;
:class:`ServiceScheduler` exploits the split to overlap device work
across tasks (dispatch every runnable task, then collect in completion
order) while host-only transitions fill the gaps.

Because the state between steps is explicit, the API expresses the three
things the blocking loop structurally could not:

- **multi-tenant serving** — :class:`ServiceScheduler` holds N in-flight
  TaskStates against one shared ``ClientPoolState``, batches stage-1
  intake through ``select_pools_batch`` and pumps the dispatch/collect
  split so device work from different tasks overlaps (round-robin
  blocking sweeps remain available via ``overlap=False``);
- **client churn** — clients joining the shared pool between periods
  (``ClientPoolState.register``) are admitted into running tasks at
  their next PERIOD_CHECKPOINT (budget permitting, same score/cost-ratio
  greedy as stage 1) without re-running stage 1; deregistered clients
  are dropped from task pools at the same point;
- **checkpoint/resume** — :meth:`TaskState.to_arrays` /
  :meth:`TaskState.from_arrays` round-trip the full control state
  (cursors, pool, reputation arrays, PCG64 rng state, pending schedule,
  the task's policy names and its ``policy_state`` cursor arrays)
  through plain numpy arrays, serialized via the existing
  ``repro.checkpoint`` msgpack path (:func:`save_state` /
  :func:`load_state`), so a killed provider resumes mid-period with
  identical remaining rounds.

Selection and scheduling strategies are pluggable
(:mod:`repro.core.policy`): ``TaskRequest.selection_policy`` /
``scheduling_policy`` name registered policies, resolved by the
provider at each transition — the lifecycle itself never imports a
concrete strategy.

Trainers implement the explicit :class:`Trainer` protocol (one required
method, ``run_rounds``) instead of being duck-typed via
``hasattr("run_rounds")``; :func:`single_round_adapter` wraps legacy
per-round callables.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import (Any, Callable, Mapping, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

from . import placement as placement_mod
from .scheduling import ScheduleResult
from .selection import SelectionResult
from .reputation import ReputationTracker

# rounds of fault-mode round_latency retained in the policy_state
# "obs/latency" window (read by the deadline_aware scheduling policy)
_OBS_LATENCY_WINDOW = 128

_STATE_FORMAT = 4             # to_arrays layout version (4: +
_STATE_FORMATS = (1, 2, 3, 4)  # TaskRequest.compression and
# trainer_state arrays; 3 added fault/mitigation TaskRequest fields,
# retry/backoff cursors, DEGRADED phase, task id; 2 added policy names
# and policy_state arrays; older formats still restore, with defaults)


# ---------------------------------------------------------------------------
# Task intake types (previously in core.service; moved here so the
# provider can shim run_task over the lifecycle without an import cycle)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskRequest:
    """An FL task as submitted by a task requester."""
    budget: float
    n_star: int = 1                       # minimum pool size (Eq. 8c)
    thresholds: np.ndarray | None = None  # per-criterion minimums (Eq. 8d)
    subset_size: int = 10                 # n
    subset_delta: int = 3                 # δ
    x_star: int = 3                       # max selections per period
    max_periods: int = 20
    max_rounds: int | None = None         # hard round budget; chunked
    # dispatch never trains past it (unlike a stop_fn, which a chunk can
    # only observe at its host checkpoint)
    rep_threshold: float = 0.5
    suspension_periods: int = 1
    scheduler: str = "mkp"                # legacy alias: "mkp" (ours) |
    # "random" (baseline -> the "random_partition" scheduling policy)
    nid_threshold: float = 0.35
    seed: int = 0
    selection_policy: str | None = None       # stage-1 strategy, by
    # registry name (core.policy): "paper_greedy" | "dp" | "random" |
    # "score_prop" | anything registered. None = not set: an explicit
    # legacy ``method=`` wins, else the default ("paper_greedy")
    scheduling_policy: str | None = None      # stage-2 strategy:
    # "iid_subsets" | "random_partition" | "fair_ema" | registered.
    # None = not set: the legacy ``scheduler`` alias decides ("mkp" ->
    # "iid_subsets", "random" -> "random_partition"); an explicit name
    # always wins over the alias
    round_chunk: int = 1                  # rounds per trainer dispatch (>1 =
    # chunked driver; requires a chunk-capable Trainer)
    admit_joiners: bool = True            # churn: admit clients registered
    # after stage 1 at the next PERIOD_CHECKPOINT, budget permitting
    overschedule_factor: float = 1.0      # straggler mitigation: dispatch
    # ceil(factor * n) clients per round (extras drawn from the task
    # pool by the task rng); the round still closes at the first n
    # arrivals. 1.0 = off. Only observable under an active FaultPlan.
    quorum_frac: float = 0.0              # minimum fraction of the
    # *scheduled* subset that must arrive for a round to commit (at
    # least one arrival is always required under a fault plan); a
    # missed quorum triggers the retry/backoff path
    collect_deadline: float = 0.0         # per-round arrival deadline in
    # FaultPlan latency units; 0 = none (close at the first-k arrivals)
    max_retries: int = 3                  # quorum-miss retries per round
    # (fresh subset redraw + exponential backoff) before the task
    # degrades to the terminal DEGRADED phase
    retry_backoff: float = 1.0            # initial backoff penalty (in
    # latency units) charged per retry, doubling each consecutive miss
    compression: str | None = None        # client-update codec spec
    # (repro.fl.compression grammar: "int8" | "topk:F" | "topk:F+int8",
    # optional "@chunk=N"); None / "none" = uncompressed. Forwarded to
    # compression-aware trainers; recorded in format-4 checkpoints


@dataclasses.dataclass
class RoundEvent:
    """One completed FL round, as emitted by a TRAINING step."""
    period: int
    round_index: int
    subset: list[int]
    weights: np.ndarray
    nid: float
    metrics: dict


# Pre-redesign name for the same record (ServiceRunResult.rounds entries).
RoundLog = RoundEvent


@dataclasses.dataclass
class ServiceRunResult:
    pool: SelectionResult
    rounds: list[RoundEvent]
    schedules: list[ScheduleResult]
    reputation: dict[int, float]

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)


# ---------------------------------------------------------------------------
# Trainer protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Trainer(Protocol):
    """Explicit trainer contract (replaces ``hasattr("run_rounds")``).

    ``run_rounds(start_round, subsets, weights)`` runs
    ``len(subsets)`` consecutive FL rounds and returns one
    ``(returned_flags, q_values, metrics)`` tuple per round. A trainer
    that can fuse consecutive rounds into one device dispatch (e.g.
    ``fl.simulation.DeviceFLSim``) simply implements this over the whole
    chunk; a sequential trainer loops internally. Set the class
    attribute ``chunkable = False`` to force one-round chunks regardless
    of ``TaskRequest.round_chunk`` (the default is chunk-capable).

    A trainer may additionally implement the :class:`AsyncTrainer` pair
    (``dispatch_rounds`` / ``collect``) to let the service overlap its
    device work with other tasks; ``run_rounds`` alone is always enough
    (the lifecycle falls back to eager execution at dispatch time).
    """

    def run_rounds(self, start_round: int,
                   subsets: Sequence[Sequence[int]],
                   weights: Sequence[np.ndarray]
                   ) -> list[tuple[np.ndarray, np.ndarray, dict]]: ...


@runtime_checkable
class AsyncTrainer(Trainer, Protocol):
    """Optional asynchronous extension of :class:`Trainer`.

    ``dispatch_rounds(start_round, subsets, weights)`` *enqueues* the
    chunk and returns an opaque handle without blocking on the device
    (with JAX this means returning unmaterialized device arrays);
    ``collect(handle)`` blocks, materializes, and returns exactly what
    ``run_rounds`` would have: one ``(returned_flags, q_values,
    metrics)`` tuple per round. The contract is
    ``collect(dispatch_rounds(*a)) == run_rounds(*a)`` bit-for-bit —
    ``fl.simulation.DeviceFLSim`` implements ``run_rounds`` as exactly
    that composition.

    Handles must tolerate interleaving: between a task's
    ``dispatch_rounds`` and its ``collect``, other trainers (other
    tasks) may dispatch and collect their own chunks.
    """

    def dispatch_rounds(self, start_round: int,
                        subsets: Sequence[Sequence[int]],
                        weights: Sequence[np.ndarray]) -> Any: ...

    def collect(self, handle: Any
                ) -> list[tuple[np.ndarray, np.ndarray, dict]]: ...


class single_round_adapter:
    """Wrap a legacy per-round callable ``fn(round, subset, weights)``
    into the :class:`Trainer` protocol. ``chunkable = False`` keeps the
    deprecated callback contract: exactly one round per dispatch."""

    chunkable = False

    def __init__(self, fn: Callable[[int, Sequence[int], np.ndarray], tuple]):
        self.fn = fn

    def run_rounds(self, start_round, subsets, weights):
        return [self.fn(start_round + j, subsets[j], weights[j])
                for j in range(len(subsets))]


def resolve_trainer(trainer) -> Trainer:
    """Coerce ``trainer`` into the protocol: real Trainers pass through,
    bare callables get wrapped in :class:`single_round_adapter`."""
    if isinstance(trainer, Trainer):
        return trainer
    if callable(trainer):
        return single_round_adapter(trainer)
    raise TypeError(f"trainer {trainer!r} is neither a Trainer "
                    f"(run_rounds) nor a per-round callable")


def _chunk_size(task: TaskRequest, trainer: Trainer) -> int:
    return max(1, int(task.round_chunk)) \
        if getattr(trainer, "chunkable", True) else 1


class InFlightError(RuntimeError):
    """Raised when an operation that needs a settled :class:`TaskState`
    (serialization, a fresh dispatch) meets an un-collected in-flight
    chunk. Call :func:`collect` first, or ``save_state(..., flush=True)``.
    The message names the task id and the pending round range so the
    offending tenant is identifiable in multi-task sweeps."""


@dataclasses.dataclass
class PendingChunk:
    """An in-flight TRAINING chunk: everything :func:`collect` needs to
    turn the trainer's handle into :class:`RoundEvent` s.

    ``handle`` is whatever ``AsyncTrainer.dispatch_rounds`` returned
    (unmaterialized device arrays), or — for a plain sync
    :class:`Trainer` — the already-computed ``run_rounds`` result list
    (``sync=True``). Transient by design: never serialized
    (``TaskState.to_arrays`` refuses while one is pending).
    """

    trainer: Trainer
    handle: Any
    chunk: list[list[int]]          # the dispatched subsets
    ws: list[np.ndarray]            # their FedAvg weights
    t: int                          # subset_index at dispatch time
    stop_fn: Callable[[dict], bool] | None
    sync: bool                      # handle already holds results
    arrivals: list[np.ndarray] | None = None   # fault mode: per-round
    # bool arrival masks over the dispatched members (first-k-collect)
    close_times: list[float] | None = None     # fault mode: per-round
    # simulated close times (-> metrics["round_latency"])
    penalty: float = 0.0            # accumulated retry latency charged
    # to this chunk's first committed round
    pool: Any = None                # ClientPoolState ref, for unpinning
    pinned: list[int] | None = None  # ids pinned against deregister
    # while this chunk is in flight (core.pool deferred-dereg guard)


# ---------------------------------------------------------------------------
# Task state
# ---------------------------------------------------------------------------

class TaskPhase(enum.IntEnum):
    INTAKE = 0             # submitted, stage 1 not yet run
    POOL_SELECTED = 1      # pool known; next step schedules a period
    SCHEDULED = 2          # period schedule pending, no round trained yet
    TRAINING = 3           # mid-period: >=1 chunk dispatched
    PERIOD_CHECKPOINT = 4  # period over; next step updates the pool
    DONE = 5
    INFEASIBLE = 6
    DEGRADED = 7           # graceful degradation: a round missed quorum
    # max_retries times (or the scheduler evicted a wedged in-flight
    # chunk) — the task is parked terminal instead of wedging the
    # service; its accumulated rounds/results stay available

    @property
    def terminal(self) -> bool:
        return self in (TaskPhase.DONE, TaskPhase.INFEASIBLE,
                        TaskPhase.DEGRADED)


@dataclasses.dataclass
class TaskState:
    """Everything ``run_task`` kept in locals, made explicit.

    Advanced exclusively by :func:`step`; serialized by
    :meth:`to_arrays` / :meth:`from_arrays` (control state only — the
    accumulated ``rounds``/``schedules`` histories are *event streams*,
    already delivered to the caller, and are not checkpointed; a
    restored task reproduces the remaining rounds exactly).
    """

    task: TaskRequest
    phase: TaskPhase = TaskPhase.INTAKE
    rng: np.random.Generator | None = None     # created at construction
    pool_selected: SelectionResult | None = None
    tracker: ReputationTracker | None = None
    pool: set[int] = dataclasses.field(default_factory=set)
    admitted: list[int] = dataclasses.field(default_factory=list)
    admitted_cost: float = 0.0
    schedule: ScheduleResult | None = None     # pending period schedule
    subset_index: int = 0                      # cursor into schedule.subsets
    period: int = 0
    global_round: int = 0
    stop: bool = False                         # stop_fn/max_rounds fired
    pool_watermark: int = 0                    # pool_state.reg_counter at
    # the last joiner scan (registration *events*, not row count, so
    # tombstone-reactivating rejoins are seen too)
    rounds: list[RoundEvent] = dataclasses.field(default_factory=list)
    schedules: list[ScheduleResult] = dataclasses.field(default_factory=list)
    pending: PendingChunk | None = None        # in-flight dispatched chunk
    # (transient — set by dispatch(), cleared by collect(), never
    # serialized; to_arrays() refuses while one is outstanding)
    policy_state: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)                  # scheduling-policy cursor
    # arrays (e.g. fair_ema participation EMAs), owned by the task and
    # serialized with it — string keys, numpy-array values only
    retry_count: int = 0                       # consecutive quorum misses
    # on the round at subset_index (fault mode; reset on a commit)
    retry_latency: float = 0.0                 # accumulated close-time +
    # backoff penalty, charged to the next committed round's latency
    task_id: int | None = None                 # scheduler-assigned tenant
    # id (ServiceScheduler.submit/adopt); used in error messages
    trainer_state: dict = dataclasses.field(default_factory=dict)
    # flat {path: numpy array} export of the trainer's server state
    # (params + optimizer moments — checkpoint.tree_to_arrays form),
    # attached by attach_trainer_state / save_state(trainer=...) and
    # serialized with the task (format 4) so a restored run resumes the
    # model exactly; empty when the trainer has no export_state()

    def __post_init__(self):
        if self.rng is None:
            self.rng = np.random.default_rng(self.task.seed)

    @property
    def eligible(self) -> set[int]:
        """Clients allowed back into the pool after suspension: the
        stage-1 selection plus churn admissions."""
        sel = self.pool_selected.selected if self.pool_selected else []
        return set(sel) | set(self.admitted)

    def _inflight_desc(self) -> str:
        """Human-readable identity of the in-flight chunk, for
        :class:`InFlightError` messages (which task, which rounds)."""
        tid = "unassigned" if self.task_id is None else str(self.task_id)
        if self.pending is None:
            return f"task id {tid}, period {self.period}"
        lo = self.global_round
        hi = lo + len(self.pending.chunk) - 1
        rounds = str(lo) if hi == lo else f"{lo}..{hi}"
        return (f"task id {tid}, period {self.period}, "
                f"pending rounds {rounds}")

    # -- serialization -------------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        """Flat ``{key: numpy array}`` form of the control state, ready
        for ``repro.checkpoint.save`` (msgpack; no pickle anywhere).

        Raises :class:`InFlightError` while a dispatched chunk is
        pending — device handles are not serializable, so an in-flight
        state must be settled first (``lifecycle.collect(state)``, or
        ``save_state(..., flush=True)`` which does it for you).
        """
        if self.pending is not None:
            raise InFlightError(
                f"TaskState ({self._inflight_desc()}) has an in-flight "
                f"dispatched chunk; call lifecycle.collect(state) (or "
                f"save_state(..., flush=True)) before serializing")
        a: dict[str, np.ndarray] = {}
        t = self.task
        a["format"] = np.array([_STATE_FORMAT], dtype=np.int64)
        a["meta"] = np.array(
            [int(self.phase), self.period, self.subset_index,
             self.global_round, int(self.stop), self.pool_watermark,
             int(self.schedule is not None),
             int(self.pool_selected is not None),
             int(self.tracker is not None)], dtype=np.int64)
        a["task/floats"] = np.array(
            [t.budget, t.rep_threshold, t.nid_threshold,
             t.overschedule_factor, t.quorum_frac, t.collect_deadline,
             t.retry_backoff], dtype=np.float64)
        a["task/ints"] = np.array(
            [t.n_star, t.subset_size, t.subset_delta, t.x_star,
             t.max_periods,
             0 if t.max_rounds is None else 1,
             0 if t.max_rounds is None else int(t.max_rounds),
             t.suspension_periods, t.seed, t.round_chunk,
             int(t.admit_joiners), t.max_retries], dtype=np.int64)
        a["retry"] = np.array([float(self.retry_count),
                               self.retry_latency], dtype=np.float64)
        a["task_id"] = np.array(
            [int(self.task_id is not None),
             0 if self.task_id is None else int(self.task_id)],
            dtype=np.int64)
        a["task/scheduler"] = _encode_str(t.scheduler)
        # None (policy not set) encodes as the empty string — no
        # registered policy can have an empty name
        a["task/selection_policy"] = _encode_str(t.selection_policy or "")
        a["task/scheduling_policy"] = _encode_str(t.scheduling_policy or "")
        # likewise: None (no codec) encodes as the empty string
        a["task/compression"] = _encode_str(t.compression or "")
        for k, v in self.trainer_state.items():
            a[f"trn/{k}"] = np.asarray(v)
        a["task/thresholds"] = (np.zeros(0) if t.thresholds is None
                                else np.asarray(t.thresholds, np.float64))
        a["task/has_thresholds"] = np.array(
            [t.thresholds is not None], dtype=np.int64)
        a["rng"] = _encode_rng(self.rng)
        for k, v in self.policy_state.items():
            a[f"pol/{k}"] = np.asarray(v)
        a["pool/ids"] = np.array(sorted(self.pool), dtype=np.int64)
        a["admitted/ids"] = np.array(self.admitted, dtype=np.int64)
        a["admitted/cost"] = np.array([self.admitted_cost], dtype=np.float64)
        if self.pool_selected is not None:
            s = self.pool_selected
            a["sel/ids"] = np.array(s.selected, dtype=np.int64)
            a["sel/totals"] = np.array(
                [s.total_score, s.total_cost, float(s.feasible)],
                dtype=np.float64)
            a["sel/note"] = _encode_str(s.note)
        if self.tracker is not None:
            for k, v in self.tracker.to_arrays().items():
                a[f"rep/{k}"] = v
        if self.schedule is not None:
            for k, v in _encode_schedule(self.schedule).items():
                a[f"sched/{k}"] = v
        return a

    @classmethod
    def from_arrays(cls, arrays: Mapping[str, Any]) -> "TaskState":
        a = {k: np.asarray(v) for k, v in arrays.items()}
        fmt = int(a["format"][0])
        if fmt not in _STATE_FORMATS:
            raise ValueError(f"unsupported TaskState format {fmt}")
        meta = a["meta"].astype(np.int64)
        tf = a["task/floats"].astype(np.float64)
        ti = a["task/ints"].astype(np.int64)
        task = TaskRequest(
            budget=float(tf[0]), n_star=int(ti[0]), subset_size=int(ti[1]),
            subset_delta=int(ti[2]), x_star=int(ti[3]),
            max_periods=int(ti[4]),
            max_rounds=int(ti[6]) if ti[5] else None,
            rep_threshold=float(tf[1]), suspension_periods=int(ti[7]),
            scheduler=_decode_str(a["task/scheduler"]),
            nid_threshold=float(tf[2]), seed=int(ti[8]),
            round_chunk=int(ti[9]), admit_joiners=bool(ti[10]),
            thresholds=(a["task/thresholds"].astype(np.float64)
                        if int(a["task/has_thresholds"][0]) else None))
        if fmt >= 2:
            task.selection_policy = \
                _decode_str(a["task/selection_policy"]) or None
            task.scheduling_policy = \
                _decode_str(a["task/scheduling_policy"]) or None
        if fmt >= 3:
            task.overschedule_factor = float(tf[3])
            task.quorum_frac = float(tf[4])
            task.collect_deadline = float(tf[5])
            task.retry_backoff = float(tf[6])
            task.max_retries = int(ti[11])
        if fmt >= 4:
            task.compression = _decode_str(a["task/compression"]) or None
        state = cls(task=task, phase=TaskPhase(int(meta[0])),
                    rng=_decode_rng(a["rng"]))
        if fmt >= 4:
            state.trainer_state = {k[len("trn/"):]: v for k, v in a.items()
                                   if k.startswith("trn/")}
        if fmt >= 3:
            retry = a["retry"].astype(np.float64)
            state.retry_count = int(retry[0])
            state.retry_latency = float(retry[1])
            tid = a["task_id"].astype(np.int64)
            state.task_id = int(tid[1]) if int(tid[0]) else None
        state.policy_state = {k[len("pol/"):]: v for k, v in a.items()
                              if k.startswith("pol/")}
        state.period = int(meta[1])
        state.subset_index = int(meta[2])
        state.global_round = int(meta[3])
        state.stop = bool(meta[4])
        state.pool_watermark = int(meta[5])
        state.pool = {int(c) for c in a["pool/ids"]}
        state.admitted = [int(c) for c in a["admitted/ids"]]
        state.admitted_cost = float(a["admitted/cost"][0])
        if int(meta[7]):
            tot = a["sel/totals"].astype(np.float64)
            state.pool_selected = SelectionResult(
                [int(c) for c in a["sel/ids"]], float(tot[0]), float(tot[1]),
                feasible=bool(tot[2]), note=_decode_str(a["sel/note"]))
        if int(meta[8]):
            state.tracker = ReputationTracker.from_arrays(
                {k[len("rep/"):]: v for k, v in a.items()
                 if k.startswith("rep/")})
        if int(meta[6]):
            state.schedule = _decode_schedule(
                {k[len("sched/"):]: v for k, v in a.items()
                 if k.startswith("sched/")})
            # the pending schedule was appended to the history when it
            # was generated; keep the resumed result self-consistent
            state.schedules.append(state.schedule)
        return state


# Issue/title name for the explicit service-side state.
ServiceState = TaskState


# -- serialization helpers ---------------------------------------------------

def _encode_str(s: str) -> np.ndarray:
    return np.frombuffer(s.encode("utf-8"), dtype=np.uint8).copy()


def _decode_str(a: np.ndarray) -> str:
    return bytes(np.asarray(a, dtype=np.uint8).tolist()).decode("utf-8")


def _encode_rng(rng: np.random.Generator) -> np.ndarray:
    st = rng.bit_generator.state
    if st.get("bit_generator") != "PCG64":
        raise ValueError("TaskState serialization requires the default "
                         "PCG64 bit generator (np.random.default_rng)")
    M = (1 << 64) - 1
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array([s & M, (s >> 64) & M, inc & M, (inc >> 64) & M,
                     st["has_uint32"], st["uinteger"]], dtype=np.uint64)


def _decode_rng(a: np.ndarray) -> np.random.Generator:
    a = np.asarray(a, dtype=np.uint64)
    rng = np.random.default_rng(0)
    st = rng.bit_generator.state
    st["state"]["state"] = int(a[0]) | (int(a[1]) << 64)
    st["state"]["inc"] = int(a[2]) | (int(a[3]) << 64)
    st["has_uint32"] = int(a[4])
    st["uinteger"] = int(a[5])
    rng.bit_generator.state = st
    return rng


def _encode_schedule(s: ScheduleResult) -> dict[str, np.ndarray]:
    P = len(s.subsets)
    L = max((len(x) for x in s.subsets), default=0)
    subs = np.full((P, L), -1, dtype=np.int64)
    lens = np.zeros(P, dtype=np.int64)
    for i, x in enumerate(s.subsets):
        subs[i, : len(x)] = x
        lens[i] = len(x)
    cids = np.array(list(s.counts.keys()), dtype=np.int64)
    cvals = np.array([s.counts[int(c)] for c in cids], dtype=np.int64)
    return {"subsets": subs, "lens": lens,
            "nids": np.asarray(s.nids, dtype=np.float64),
            "count_ids": cids, "count_vals": cvals,
            "capacities": np.asarray(s.capacities, dtype=np.float64)}


def _decode_schedule(a: Mapping[str, np.ndarray]) -> ScheduleResult:
    subs = np.asarray(a["subsets"], dtype=np.int64)
    lens = np.asarray(a["lens"], dtype=np.int64)
    if subs.size == 0:
        subs = subs.reshape(lens.size, 0)
    subsets = [[int(v) for v in subs[i, : lens[i]]]
               for i in range(lens.size)]
    counts = {int(c): int(v) for c, v in
              zip(np.asarray(a["count_ids"]), np.asarray(a["count_vals"]))}
    return ScheduleResult(subsets,
                          [float(x) for x in np.asarray(a["nids"])],
                          counts,
                          np.asarray(a["capacities"], dtype=np.float64))


def attach_trainer_state(state: TaskState, trainer) -> TaskState:
    """Snapshot the trainer's server state into
    ``state.trainer_state`` (format-4 checkpoints carry it).

    Uses the trainer's ``export_state()`` — a flat
    ``{path: numpy array}`` mapping (``checkpoint.tree_to_arrays``
    form) covering params and any server-optimizer moments. Trainers
    without the hook leave ``trainer_state`` untouched (control-plane
    state still checkpoints; the caller owns the model). Returns
    ``state`` for chaining.
    """
    export = getattr(trainer, "export_state", None)
    if export is not None:
        state.trainer_state = dict(export())
    return state


def restore_trainer_state(state: TaskState, trainer) -> bool:
    """Load ``state.trainer_state`` back into a fresh trainer via its
    ``import_state(arrays)`` hook. Returns ``True`` if arrays were
    applied, ``False`` when the checkpoint carried none (pre-format-4
    payloads, or a trainer that never exported)."""
    if not state.trainer_state:
        return False
    trainer.import_state(state.trainer_state)
    return True


def save_state(path: str, state: TaskState, flush: bool = False,
               trainer=None) -> list[RoundEvent]:
    """Serialize ``state`` through the repo checkpoint path (msgpack,
    zstd when available).

    A state captured between :func:`dispatch` and :func:`collect` holds
    unmaterialized device arrays and cannot be serialized as-is:
    ``flush=False`` (default) raises :class:`InFlightError`;
    ``flush=True`` collects the pending chunk first (blocking on the
    device) and returns its :class:`RoundEvent` s — they are also
    appended to ``state.rounds``, so a caller that streams events should
    take them from the return value exactly once. Returns ``[]`` when
    nothing was in flight.

    ``trainer``: optionally attach the trainer's exported server state
    (:func:`attach_trainer_state`) before serializing, so the single
    checkpoint file carries control plane *and* model; restore with
    :func:`load_state` + :func:`restore_trainer_state`.
    """
    from repro import checkpoint
    events: list[RoundEvent] = []
    if state.pending is not None and flush:
        _, events = collect(state)
    if trainer is not None:
        attach_trainer_state(state, trainer)
    checkpoint.save(path, state.to_arrays())
    return events


def load_state(path: str) -> TaskState:
    """Inverse of :func:`save_state` (structure-free restore)."""
    from repro import checkpoint
    return TaskState.from_arrays(checkpoint.restore_dict(path))


# ---------------------------------------------------------------------------
# Transition functions
# ---------------------------------------------------------------------------

def submit(provider, task: TaskRequest,
           method: str | None = None) -> TaskState:
    """Task intake + stage 1 (paper Eq. 8): select the task's client
    pool from the provider's shared registry under the budget,
    ``n_star`` and per-criterion thresholds, and return the resulting
    :class:`TaskState` — POOL_SELECTED on success, INFEASIBLE when the
    budget/thresholds cannot seat ``n_star`` clients (then the state is
    terminal and :func:`step` is a no-op).

    ``provider`` is an ``FLServiceProvider``. Stage 1 runs the task's
    registered selection policy (``task.selection_policy``, default
    ``paper_greedy`` — see :mod:`repro.core.policy`); an explicitly
    passed legacy ``method`` ("greedy" | "dp" | "random") always wins
    over the field. For many concurrent tasks, prefer
    ``ServiceScheduler.submit`` — its intake batches all queued tasks
    through the policies' batched path (one vectorized knapsack sweep
    for the default).
    """
    state = TaskState(task=task)
    sel = provider.select_pool(task, method=method, rng=state.rng)
    return apply_pool_selection(provider, state, sel)


def apply_pool_selection(provider, state: TaskState,
                         sel: SelectionResult) -> TaskState:
    """Attach a stage-1 result to an INTAKE state (used by
    :func:`submit` and by the batched ``ServiceScheduler`` intake)."""
    if state.phase != TaskPhase.INTAKE:
        raise ValueError(f"stage 1 already applied (phase={state.phase.name})")
    state.pool_selected = sel
    if not sel.feasible:
        state.phase = TaskPhase.INFEASIBLE
        return state
    state.pool = set(sel.selected)
    state.tracker = ReputationTracker(
        sel.selected, suspension_periods=state.task.suspension_periods,
        rep_threshold=state.task.rep_threshold)
    state.pool_watermark = provider.pool_state.reg_counter
    state.phase = TaskPhase.POOL_SELECTED
    return state


def step(provider, state: TaskState, trainer,
         availability_fn: Callable[[int, int], bool] | None = None,
         stop_fn: Callable[[dict], bool] | None = None,
         ) -> tuple[TaskState, list[RoundEvent]]:
    """Advance the task by exactly one transition.

    POOL_SELECTED steps generate the next period's schedule (or finish
    the task when a loop guard fires); SCHEDULED/TRAINING steps dispatch
    one round chunk to ``trainer`` and emit the resulting
    :class:`RoundEvent` s; PERIOD_CHECKPOINT steps run the reputation
    pool update, churn admission, and either loop or finish. Terminal
    states are no-ops.

    ``trainer`` may be a :class:`Trainer` or a legacy per-round callable
    (wrapped via :func:`single_round_adapter`); ``availability_fn`` /
    ``stop_fn`` keep their ``run_task`` semantics. The state is mutated
    in place and also returned.

    A SCHEDULED/TRAINING step is exactly :func:`dispatch` followed by
    :func:`collect`; stepping a state that already has an in-flight
    chunk simply collects it (finishing the half-done transition).
    """
    if state.pending is not None:
        return collect(state)
    if state.phase.terminal:
        return state, []
    if state.phase == TaskPhase.INTAKE:
        raise ValueError("cannot step an INTAKE state: run submit() or a "
                         "ServiceScheduler intake first")
    if state.phase == TaskPhase.POOL_SELECTED:
        return _schedule_next_period(provider, state), []
    if state.phase in (TaskPhase.SCHEDULED, TaskPhase.TRAINING):
        dispatch(provider, state, trainer, stop_fn=stop_fn)
        return collect(state)
    # PERIOD_CHECKPOINT
    return _period_checkpoint(provider, state, availability_fn), []


def dispatch(provider, state: TaskState, trainer,
             stop_fn: Callable[[dict], bool] | None = None) -> TaskState:
    """Asynchronous half of a TRAINING transition: *enqueue* the next
    round chunk without waiting for its results.

    Valid on SCHEDULED/TRAINING states only (terminal states are
    no-ops). If the period is already exhausted (or ``max_rounds`` /
    ``stop`` fired) this performs the host-side phase advance to
    PERIOD_CHECKPOINT and leaves nothing in flight; otherwise it
    computes the chunk's subsets/weights on the host, hands them to the
    trainer — ``AsyncTrainer.dispatch_rounds`` enqueues and returns
    immediately; a plain :class:`Trainer` runs eagerly as a sync
    fallback — and parks the handle on ``state.pending``.

    Until :func:`collect` settles the chunk, the state is *in flight*:
    ``to_arrays``/``save_state`` refuse it and a second ``dispatch``
    raises :class:`InFlightError`. :class:`ServiceScheduler` uses this
    split to enqueue every runnable task's chunk back-to-back, so task
    B's device work overlaps task A's (JAX async dispatch), then
    collects in completion order.
    """
    if state.pending is not None:
        raise InFlightError(
            f"a chunk is already in flight ({state._inflight_desc()}); "
            f"collect() it before dispatching another")
    if state.phase.terminal:
        return state
    if state.phase not in (TaskPhase.SCHEDULED, TaskPhase.TRAINING):
        raise ValueError(f"dispatch needs a SCHEDULED/TRAINING state, "
                         f"got {state.phase.name}")
    return _dispatch_chunk(provider, state, resolve_trainer(trainer),
                           stop_fn)


def collect(state: TaskState) -> tuple[TaskState, list[RoundEvent]]:
    """Blocking half of a TRAINING transition: materialize the in-flight
    chunk into :class:`RoundEvent` s and advance the phase.

    Needs no provider — everything host-side was captured at
    :func:`dispatch` time. Settles reputation bookkeeping, appends the
    events to ``state.rounds``, advances ``subset_index`` /
    ``global_round``, runs ``stop_fn`` per round, and moves the phase to
    TRAINING or PERIOD_CHECKPOINT exactly as the blocking step did.
    A state with nothing in flight is a no-op returning ``[]``.
    """
    p = state.pending
    if p is None:
        return state, []
    results = p.handle if p.sync else p.trainer.collect(p.handle)
    state.pending = None
    return _settle_chunk(state, p, results)


def drain(provider, state: TaskState, trainer,
          availability_fn: Callable[[int, int], bool] | None = None,
          stop_fn: Callable[[dict], bool] | None = None,
          max_steps: int | None = None,
          ) -> tuple[TaskState, list[RoundEvent]]:
    """Step until the task reaches DONE/INFEASIBLE (the convenience
    loop ``run_task`` shims over). Returns the final state and every
    :class:`RoundEvent` produced along the way; ``max_steps`` bounds
    the loop for callers that want to pause mid-task (the state can be
    resumed by another ``drain``/``step``, checkpointed via
    :func:`save_state`, or handed to ``ServiceScheduler.adopt``)."""
    events: list[RoundEvent] = []
    steps = 0
    while not state.phase.terminal:
        state, ev = step(provider, state, trainer,
                         availability_fn=availability_fn, stop_fn=stop_fn)
        events.extend(ev)
        steps += 1
        if max_steps is not None and steps >= max_steps:
            break
    return state, events


def as_run_result(state: TaskState) -> ServiceRunResult:
    """The accumulated ``ServiceRunResult`` view of a task state."""
    rep = state.tracker.scores() if state.tracker is not None else {}
    pool_sel = state.pool_selected if state.pool_selected is not None \
        else SelectionResult([], 0.0, 0.0, feasible=False, note="no stage 1")
    return ServiceRunResult(pool_sel, state.rounds, state.schedules, rep)


# -- internal transitions ----------------------------------------------------

def _drop_deregistered(provider, state: TaskState) -> None:
    """Remove members that churned out of the shared pool from the
    task's pool (used at both churn windows: before a schedule draw and
    at the period checkpoint)."""
    if not state.pool:
        return
    ids = np.array(sorted(state.pool), dtype=np.int64)
    state.pool -= {int(c)
                   for c in ids[~provider.pool_state.is_registered(ids)]}


def _schedule_next_period(provider, state: TaskState) -> TaskState:
    task = state.task
    # churn can strike between the last checkpoint and this step
    # (including right after submit): drop deregistered members before
    # drawing the schedule
    _drop_deregistered(provider, state)
    if (not state.pool or state.period >= task.max_periods
            or (task.max_rounds is not None
                and state.global_round >= task.max_rounds)):
        state.phase = TaskPhase.DONE
        return state
    # publish the task's timing observability columns before drawing the
    # schedule: the reputation tracker's aligned timing arrays plus the
    # rolling round-latency window maintained by _settle_chunk. They live
    # in policy_state (string keys -> numpy arrays) so deadline/straggler
    # -aware scheduling policies can react mid-task and the columns ride
    # checkpoints; policies that don't read them are unaffected.
    state.policy_state["obs/ids"] = state.tracker.client_ids.copy()
    state.policy_state["obs/timeouts"] = state.tracker.timeout_failures
    state.policy_state["obs/rounds"] = state.tracker.round_counts
    state.schedule = provider.schedule_period(sorted(state.pool), task,
                                              state.rng,
                                              policy_state=state.policy_state)
    state.schedules.append(state.schedule)
    state.subset_index = 0
    state.stop = False
    state.phase = TaskPhase.SCHEDULED
    return state


def _fault_plan(trainer):
    """The trainer's attached :class:`~repro.core.faults.FaultPlan`, or
    ``None`` when fault injection is off. An inactive plan (all rates
    zero) is treated as absent, so the unmodified no-fault code path —
    and its bit-exact results — is taken whenever nothing can fail."""
    plan = getattr(trainer, "fault_plan", None)
    if plan is None or not plan.active:
        return None
    return plan


def _redraw_subset(state: TaskState, n: int) -> list[int]:
    """Fresh subset draw for a quorum-miss retry: uniform n-of-pool from
    the task's own rng (checkpointed, so a mid-backoff restore redraws
    identically)."""
    pool = np.array(sorted(state.pool), dtype=np.int64)
    k = min(int(n), pool.size)
    picks = state.rng.choice(pool.size, size=k, replace=False)
    return [int(c) for c in pool[np.sort(picks)]]


def _eval_round(state: TaskState, plan, base: Sequence[int], rnd: int):
    """Overschedule ``base`` and evaluate the round's arrival outcome
    under the fault plan. Deterministic given (plan, members, round), so
    dispatch can pre-compute which scheduled clients will report by the
    close and mask the rest on device before any training runs."""
    task = state.task
    n = len(base)
    members = list(base)
    want = int(np.ceil(n * max(1.0, task.overschedule_factor)))
    if want > n:
        cand = np.array(sorted(state.pool - set(members)), dtype=np.int64)
        if cand.size:
            k = min(want - n, cand.size)
            picks = state.rng.choice(cand.size, size=k, replace=False)
            members += [int(c) for c in cand[np.sort(picks)]]
    quorum_k = max(1, int(np.ceil(task.quorum_frac * n)))
    out = plan.round_outcome(members, rnd, task.collect_deadline,
                             target_k=n, quorum_k=quorum_k)
    return members, out


def _plan_chunk(provider, state: TaskState, plan, t: int, limit: int):
    """Evaluate the prospective chunk's arrivals round by round, stopping
    before the first quorum miss. Returns ``(chunk, arrivals,
    close_times, miss)`` where ``miss`` is the failing round's
    :class:`~repro.core.faults.RoundOutcome` (or ``None``). Non-arrived
    members are charged a timing failure whether or not the round
    commits — chronic stragglers must not hide behind retries."""
    sched = state.schedule
    chunk: list[list[int]] = []
    arrivals: list[np.ndarray] = []
    closes: list[float] = []
    for j in range(min(limit, len(sched.subsets) - t)):
        base = sched.subsets[t + j]
        if j == 0 and state.retry_count > 0:
            base = _redraw_subset(state, len(base))
        members, out = _eval_round(state, plan, base,
                                   state.global_round + j)
        rows = provider.pool_state.positions(members,
                                             include_deregistered=True)
        provider.pool_state.note_timing(rows, rows[~out.arrival])
        for i, cid in enumerate(members):
            if not out.arrival[i]:
                state.tracker.record_timeout(cid)
        if not out.quorum_met:
            return chunk, arrivals, closes, out
        chunk.append(members)
        arrivals.append(out.arrival)
        closes.append(out.close_time)
    return chunk, arrivals, closes, None


def _quorum_miss(state: TaskState, out) -> TaskState:
    """A round's arrivals missed quorum before anything was dispatched:
    charge the close time plus an exponential backoff to the task's
    latency account, then either leave the state in TRAINING (the next
    dispatch retries against a fresh subset draw) or — past
    ``max_retries`` — degrade the task to the terminal DEGRADED phase
    rather than wedging the service."""
    task = state.task
    state.retry_count += 1
    backoff = task.retry_backoff * (2.0 ** (state.retry_count - 1))
    state.retry_latency += out.close_time + backoff
    if state.retry_count > task.max_retries:
        state.phase = TaskPhase.DEGRADED
    return state


def _dispatch_chunk(provider, state: TaskState, trainer: Trainer,
                    stop_fn) -> TaskState:
    """Host half of the TRAINING transition: pick the chunk, compute its
    weights, hand it to the trainer, park the handle on ``pending``.

    Under an active :class:`~repro.core.faults.FaultPlan` on the trainer
    the chunk is first *arrival-evaluated* (:func:`_plan_chunk`):
    subsets are over-scheduled per ``task.overschedule_factor``, each
    round closes at its first-k arrivals / deadline, a quorum-missing
    round truncates the chunk (and, when it is the first round, routes
    through the retry/backoff path leaving nothing in flight), and the
    arrival masks ride along so the device (or :func:`_settle_chunk`)
    masks non-reporting clients out of the aggregate."""
    task, sched = state.task, state.schedule
    t = state.subset_index
    if sched is None or t >= len(sched.subsets) or state.stop:
        state.phase = TaskPhase.PERIOD_CHECKPOINT   # defensive guard
        return state
    limit = _chunk_size(task, trainer)
    if task.max_rounds is not None:
        remaining = task.max_rounds - state.global_round
        if remaining <= 0:
            state.stop = True
            state.phase = TaskPhase.PERIOD_CHECKPOINT
            return state
        limit = min(limit, remaining)
    plan = _fault_plan(trainer)
    arrivals = close_times = None
    penalty = 0.0
    if plan is None:
        chunk = sched.subsets[t: t + limit]
    else:
        chunk, arrivals, close_times, miss = _plan_chunk(
            provider, state, plan, t, limit)
        if not chunk:                   # first round missed quorum
            return _quorum_miss(state, miss)
        penalty, state.retry_latency = state.retry_latency, 0.0
        state.retry_count = 0
    data_sizes = provider.pool_state.data_sizes()
    ws = []
    for subset in chunk:
        # include_deregistered: a client churned out mid-period keeps
        # training this period's schedule against its (still resident)
        # tombstoned row; the next PERIOD_CHECKPOINT drops it.
        rows = provider.pool_state.positions(subset,
                                             include_deregistered=True)
        sizes = data_sizes[rows]
        ws.append(sizes / np.maximum(sizes.sum(), 1e-12))
    pinned = sorted({int(c) for subset in chunk for c in subset})
    provider.pool_state.pin(pinned)
    aware = arrivals is not None and getattr(trainer, "accepts_arrivals",
                                             False)
    if isinstance(trainer, AsyncTrainer):
        if aware:
            handle = trainer.dispatch_rounds(state.global_round, chunk, ws,
                                             arrivals=arrivals)
        else:
            handle = trainer.dispatch_rounds(state.global_round, chunk, ws)
        sync = False
    else:                                           # eager sync fallback
        if aware:
            handle = trainer.run_rounds(state.global_round, chunk, ws,
                                        arrivals=arrivals)
        else:
            handle = trainer.run_rounds(state.global_round, chunk, ws)
        sync = True
    state.pending = PendingChunk(trainer, handle, chunk, ws, t, stop_fn,
                                 sync, arrivals=arrivals,
                                 close_times=close_times, penalty=penalty,
                                 pool=provider.pool_state, pinned=pinned)
    state.phase = TaskPhase.TRAINING                # mid-period, in flight
    return state


def _settle_chunk(state: TaskState, p: PendingChunk, results
                  ) -> tuple[TaskState, list[RoundEvent]]:
    """Bookkeeping half of the TRAINING transition, shared by the
    blocking step and the overlapped collect path.

    When the chunk was dispatched under a fault plan (``p.arrivals``),
    clients that missed the round's close are masked out of ``returned``
    and ``q_vals`` before reputation bookkeeping (their timing failure
    was already charged at dispatch), and each round's metrics gain its
    simulated ``round_latency`` (close time, plus any retry backoff
    carried over from preceding quorum misses)."""
    if p.pinned is not None and p.pool is not None:
        p.pool.unpin(p.pinned)
    sched, t = state.schedule, p.t
    penalty = p.penalty
    events: list[RoundEvent] = []
    for j, (returned, q_vals, metrics) in enumerate(results):
        subset = p.chunk[j]
        if p.arrivals is not None:
            arr = np.asarray(p.arrivals[j], dtype=bool)
            returned = np.asarray(returned, dtype=bool) & arr
            q_vals = np.where(arr, np.asarray(q_vals, dtype=np.float64),
                              0.0)
            metrics = dict(metrics)
            metrics["round_latency"] = p.close_times[j] + penalty
            metrics["n_scheduled"] = len(subset)
            metrics["n_arrived"] = int(arr.sum())
            # rolling latency window for deadline-aware scheduling
            # (policy_state -> checkpointed; absent on the no-fault path)
            lat = np.append(
                state.policy_state.get("obs/latency",
                                       np.zeros(0, dtype=np.float64)),
                metrics["round_latency"])
            state.policy_state["obs/latency"] = lat[-_OBS_LATENCY_WINDOW:]
            if penalty:
                metrics["retry_penalty"] = penalty
            penalty = 0.0
        for i, cid in enumerate(subset):
            state.tracker.record_round(cid, bool(returned[i]),
                                       q_value=float(q_vals[i]))
        ev = RoundEvent(state.period, state.global_round, list(subset),
                        p.ws[j], sched.nids[t + j], metrics)
        state.rounds.append(ev)
        events.append(ev)
        state.global_round += 1
        if p.stop_fn is not None and p.stop_fn(metrics):
            state.stop = True
            break
    state.subset_index = t + len(p.chunk)
    state.phase = TaskPhase.TRAINING
    if state.stop or state.subset_index >= len(sched.subsets):
        state.phase = TaskPhase.PERIOD_CHECKPOINT
    return state, events


def _period_checkpoint(provider, state: TaskState,
                       availability_fn) -> TaskState:
    avail = {cid: (availability_fn(cid, state.period + 1)
                   if availability_fn else True)
             for cid in state.tracker.records}
    state.pool = state.tracker.update_pool(state.pool, avail) \
        & state.eligible
    state.schedule = None
    state.period += 1
    if state.stop:
        state.phase = TaskPhase.DONE
        return state
    _apply_churn(provider, state)
    state.phase = TaskPhase.POOL_SELECTED
    return state


def _apply_churn(provider, state: TaskState) -> None:
    """Between periods, sync the task with pool churn: drop deregistered
    clients, then admit qualifying joiners while the stage-1 budget
    lasts — an incremental stage 1, not a re-run. Rows are found by
    their registration-event stamp (``reg_seq``), so a rejoin that
    reactivated a tombstoned row below the old row-count is seen too.

    Admission routes through the task's *resolved selection policy*
    (optional ``select_joiners`` hook, see ``core.policy``): a ``dp``
    task admits joiners with the exact knapsack, a ``score_prop`` task
    samples them, etc. Policies without the hook — and the default
    ``paper_greedy`` — use the skip-unaffordable score/cost-ratio
    greedy, bit-identical to the pre-policy hard-coded rule. Rejoining
    clients the task already tracks (``state.eligible``) are filtered
    out *before* the policy sees the candidates: their seat is already
    paid for, and this checkpoint's ``update_pool ∩ eligible`` already
    decided their membership — no second charge."""
    from .policy import resolve_selection_policy
    from .selection import select_greedy
    ps = provider.pool_state
    _drop_deregistered(provider, state)
    task = state.task
    if not task.admit_joiners:
        state.pool_watermark = ps.reg_counter
        return
    if ps.reg_counter <= state.pool_watermark:
        return
    rows = np.flatnonzero(ps.reg_seq > state.pool_watermark)
    state.pool_watermark = ps.reg_counter
    ok = ps.threshold_mask(task.thresholds)[rows]
    rows = rows[ok]
    if rows.size:
        eligible = state.eligible
        free = np.fromiter((int(c) not in eligible
                            for c in ps.client_ids[rows]),
                           dtype=bool, count=rows.size)
        rows = rows[free]
    if rows.size == 0:
        return
    budget_left = (task.budget - state.pool_selected.total_cost
                   - state.admitted_cost)
    policy = resolve_selection_policy(task)
    hook = getattr(policy, "select_joiners", None)
    if hook is not None:
        picks = hook(ps.overall[rows], ps.costs[rows], budget_left,
                     state.rng)
    else:                       # legacy rule for hook-less custom policies
        picks = np.asarray(select_greedy(
            ps.overall[rows], ps.costs[rows], budget_left,
            skip_unaffordable=True).selected, dtype=np.int64)
    if picks.size == 0:
        return
    admitted = [int(c) for c in ps.client_ids[rows[picks]]]
    for c in ps.costs[rows[picks]]:
        state.admitted_cost += float(c)    # legacy fold order, bit-exact
    state.admitted.extend(admitted)
    state.pool.update(admitted)
    state.tracker.add_clients(admitted)   # one batched row append


# ---------------------------------------------------------------------------
# Multi-tenant scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RejectedTask:
    """Returned by :meth:`ServiceScheduler.submit` instead of a task id
    when the intake queue is full (``max_queue``). Nothing was enqueued,
    and the rejection carries everything needed to resubmit without any
    caller-side bookkeeping: ``task`` is the *same* :class:`TaskRequest`
    object echoed back (resubmitting it later is exactly equivalent to
    the original submit), and ``queued`` is the INTAKE backlog depth at
    rejection time — a congestion signal for sizing the retry backoff.
    The online driver (:class:`repro.core.driver.OnlineDriver`) requeues
    rejected tasks from this echo alone; tests/test_workload.py asserts
    the echo identity and that no rejected task is ever dropped."""

    task: TaskRequest
    reason: str
    queued: int         # INTAKE backlog size at the time of rejection


@dataclasses.dataclass
class _Tenant:
    state: TaskState
    trainer: Trainer
    availability_fn: Callable[[int, int], bool] | None = None
    stop_fn: Callable[[dict], bool] | None = None
    inflight_age: int = 0   # consecutive sweeps the pending chunk has
    # been polled not-ready (wedged-tenant eviction clock)


class ServiceScheduler:
    """N in-flight tasks against one shared client pool.

    ``submit`` queues a task in INTAKE; each ``sweep`` first serves every
    queued intake through the provider's *batched* stage 1
    (``select_pools_batch`` — one vectorized knapsack sweep for all new
    tasks), then pumps every active task one transition. Per-task
    results are identical to serial execution: each task owns its rng,
    reputation arrays and cursors, and the shared pool is only read by
    selection/scheduling.

    With ``overlap=True`` (the default) a sweep is a **two-phase pump**
    over the dispatch/collect split of the TRAINING transition: phase 1
    fills a bounded in-flight window by *enqueueing* runnable tasks'
    round chunks (:func:`dispatch` — task B's device work is in the
    queue while task A's still computes, courtesy of JAX async
    dispatch); phase 2 :func:`collect` s the window in completion order
    (on a single device the FIFO execution stream makes dispatch order
    completion order), and each collected task is immediately pumped
    back into flight — its host-only transitions (POOL_SELECTED
    scheduling, PERIOD_CHECKPOINT reputation/churn sync) and its next
    enqueue run while the rest of the window is still computing, so the
    device never idles behind host bookkeeping and vice versa.
    ``max_inflight`` bounds how many un-collected chunks may be
    outstanding at once, so host/device memory for pending handles
    stays flat no matter how many tenants are served; when tenants
    outnumber the window, a FIFO ready queue rotates them through it
    (each sweep still collects at most one chunk per task, so round
    pacing across tasks stays fair). ``overlap=False`` restores the
    ISSUE-3 round-robin behaviour (one blocking :func:`step` per task
    per sweep); both modes produce bit-identical per-task results,
    overlapped is just faster (benchmarks/bench_service_multitask.py).
    The one observable difference: overlapped dispatches are issued one
    sweep early, so shared-pool churn between sweeps lands one chunk
    later than under round-robin stepping.

    **Multi-device placement** (``n_devices > 1``): tenants are spread
    over device indices ``0..n_devices-1`` by a
    :class:`~repro.core.placement.PlacementPolicy` (``placement=``, by
    registry name — ``bin_pack`` packs on estimated per-round cost from
    the ``obs/latency`` telemetry window, ``round_robin`` deals
    cyclically), and the scheduler keeps one ready queue and one
    ``max_inflight``-bounded window *per device*, pumped independently
    — so a straggling chunk on one device never stalls another
    device's tenants. Trainers opt into physical placement via a
    ``place_on(device_index)`` hook (resolve ``jax.devices()[i]``
    there; the scheduler itself never touches jax). With
    ``rebalance_threshold`` set, a sweep whose estimated per-device
    load imbalance (max/mean) exceeds the threshold re-places tenants
    sitting at a period boundary (``POOL_SELECTED`` /
    ``PERIOD_CHECKPOINT``, nothing in flight) — migration is flush →
    re-place → resume over the ``TaskState.to_arrays`` checkpoint
    path, so per-task results are bit-identical whether or not a
    tenant ever moved. ``n_devices=1`` (the default) reduces exactly
    to the single-window pump above. See ``docs/placement.md``.

    A continuously serving provider should :meth:`retire` finished
    tasks; completed tenants are otherwise retained (with their full
    round histories) so ``results()`` stays available.
    """

    def __init__(self, provider, max_inflight: int = 8,
                 overlap: bool = True, max_queue: int | None = None,
                 inflight_deadline: int | None = None,
                 n_devices: int = 1,
                 placement: "str | placement_mod.PlacementPolicy | None"
                 = None,
                 rebalance_threshold: float | None = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got "
                             f"{max_inflight}")
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if rebalance_threshold is not None and rebalance_threshold <= 1.0:
            raise ValueError(f"rebalance_threshold is a max/mean load "
                             f"ratio and must be > 1.0, got "
                             f"{rebalance_threshold}")
        self.provider = provider
        self.max_inflight = max_inflight   # per-device window bound
        self.overlap = overlap
        # backpressure: submit() returns RejectedTask once this many
        # tasks sit un-swept in INTAKE (None = unbounded, pre-ISSUE-7)
        self.max_queue = max_queue
        # wedged-tenant guard: a pending chunk polled not-ready for this
        # many consecutive sweeps is evicted to DEGRADED, freeing its
        # window slot (None = wait forever, pre-ISSUE-7). Only trainers
        # exposing poll(handle) participate; others collect eagerly.
        self.inflight_deadline = inflight_deadline
        self.n_devices = n_devices
        self.placement_policy = placement_mod.resolve_placement_policy(
            placement)
        self.rebalance_threshold = rebalance_threshold
        self.migrations = 0          # total tenants moved by rebalance()
        self._tenants: dict[int, _Tenant] = {}
        self._next_id = 0
        self._placement: dict[int, int] = {}   # tid -> device index
        # per-device FIFOs: [d] holds that device's tids
        self._inflight: list[list[int]] = [[] for _ in range(n_devices)]
        self._ready: list[list[int]] = [[] for _ in range(n_devices)]
        # _inflight[d]: tids with a chunk in flight on device d;
        # _ready[d]: dispatchable, waiting for a slot in d's window

    # -- intake --------------------------------------------------------------
    def submit(self, task: TaskRequest, trainer,
               availability_fn: Callable[[int, int], bool] | None = None,
               stop_fn: Callable[[dict], bool] | None = None
               ) -> int | RejectedTask:
        """Queue a task (INTAKE). Stage 1 runs batched at the next sweep.
        Returns the task id — or, when ``max_queue`` un-swept intakes are
        already waiting, a :class:`RejectedTask` (backpressure; nothing
        is enqueued)."""
        if self.max_queue is not None:
            backlog = sum(1 for t in self._tenants.values()
                          if t.state.phase == TaskPhase.INTAKE)
            if backlog >= self.max_queue:
                return RejectedTask(task=task, queued=backlog,
                                    reason=f"intake queue full "
                                           f"({backlog}/{self.max_queue}"
                                           f"); sweep() to drain")
        tid = self._next_id
        self._next_id += 1
        state = TaskState(task=task)
        state.task_id = tid
        self._tenants[tid] = _Tenant(state, resolve_trainer(trainer),
                                     availability_fn, stop_fn)
        return tid

    def adopt(self, state: TaskState, trainer,
              availability_fn: Callable[[int, int], bool] | None = None,
              stop_fn: Callable[[dict], bool] | None = None) -> int:
        """Take over an existing state (e.g. restored via
        :func:`load_state`) and drive it alongside the other tenants."""
        tid = self._next_id
        self._next_id += 1
        state.task_id = tid
        self._tenants[tid] = _Tenant(state, resolve_trainer(trainer),
                                     availability_fn, stop_fn)
        return tid

    def _intake(self) -> None:
        pending = [(tid, t) for tid, t in self._tenants.items()
                   if t.state.phase == TaskPhase.INTAKE]
        if not pending:
            return
        # the tenants' own rngs go along so stochastic selection
        # policies consume them exactly as a serial submit would
        sels = self.provider.select_pools_batch(
            [t.state.task for _, t in pending],
            rngs=[t.state.rng for _, t in pending])
        for (tid, t), sel in zip(pending, sels):
            apply_pool_selection(self.provider, t.state, sel)

    # -- stepping ------------------------------------------------------------
    @property
    def active(self) -> list[int]:
        return [tid for tid, t in self._tenants.items()
                if not t.state.phase.terminal]

    @property
    def task_ids(self) -> list[int]:
        return list(self._tenants)

    def state(self, tid: int) -> TaskState:
        return self._tenants[tid].state

    def sweep(self) -> dict[int, list[RoundEvent]]:
        """One scheduler tick: batched intake, then one transition per
        active task. Returns the events per task id, in the order the
        tasks' chunks were collected.

        Overlapped mode (see the class docstring) dispatches every
        runnable task's chunk before collecting any of them, interleaves
        host-only transitions into the gaps, and keeps at most
        ``max_inflight`` chunks outstanding. Per-task event streams are
        identical to ``overlap=False``; only wall-clock differs.
        """
        self._intake()
        self._place_new()
        if self.rebalance_threshold is not None and self.n_devices > 1:
            if placement_mod.imbalance(self._device_loads()) \
                    > self.rebalance_threshold:
                self.rebalance()
        out: dict[int, list[RoundEvent]] = {}
        if not self.overlap:                       # ISSUE-3 round-robin
            for tid, t in self._tenants.items():
                if t.state.phase.terminal:
                    continue
                t.state, ev = step(self.provider, t.state, t.trainer,
                                   availability_fn=t.availability_fn,
                                   stop_fn=t.stop_fn)
                if ev:
                    out[tid] = ev
            return out

        # refresh the ready queues with newly runnable tenants (fresh
        # intakes, adoptions, tasks bumped while the window was full);
        # each tenant joins its placed device's queue
        queued = set()
        for d in range(self.n_devices):
            queued.update(self._inflight[d], self._ready[d])
        for tid, t in self._tenants.items():
            if not t.state.phase.terminal and tid not in queued:
                self._ready[self._placement[tid]].append(tid)
        # phase 1: fill every device's in-flight window (cold start /
        # new tenants; in steady state the windows were already refilled
        # by phase 2 of the previous sweep, so every chunk computed
        # between sweeps)
        for d in range(self.n_devices):
            while (self._ready[d]
                   and len(self._inflight[d]) < self.max_inflight):
                self._pump_into_flight(self._ready[d].pop(0))
        # phase 2: collect each device's window in completion order (per
        # device the FIFO execution stream makes dispatch order
        # completion order). After each collect the task goes to the
        # back of its device's ready queue and the freed slot is
        # refilled at once — the refill runs the task's host-only
        # transitions (PERIOD_CHECKPOINT reputation/churn sync,
        # POOL_SELECTED scheduling) and enqueues its next chunk while
        # the rest of the windows are still computing, which is where
        # the overlap comes from.
        # The fixed-count loops poll each in-flight chunk at most once
        # per sweep: a not-ready (wedged) tenant is re-appended and
        # aged, never re-polled this sweep, so it cannot stall the
        # others — neither its own device's window (skipped, window
        # refilled around it) nor, since every window and queue is
        # per-device, any other device's tenants — and past
        # ``inflight_deadline`` consecutive not-ready sweeps it is
        # evicted to DEGRADED, freeing its window slot.
        for d in range(self.n_devices):
            for _ in range(len(self._inflight[d])):
                tid = self._inflight[d].pop(0)
                t = self._tenants[tid]
                if not self._handle_ready(t):
                    t.inflight_age += 1
                    if (self.inflight_deadline is not None
                            and t.inflight_age >= self.inflight_deadline):
                        self._evict(tid)
                    else:
                        self._inflight[d].append(tid)
                    continue
                t.inflight_age = 0
                t.state, ev = collect(t.state)
                if ev:
                    out.setdefault(tid, []).extend(ev)
                if not t.state.phase.terminal:
                    self._ready[d].append(tid)
                while (self._ready[d]
                       and len(self._inflight[d]) < self.max_inflight):
                    self._pump_into_flight(self._ready[d].pop(0))
        return out

    # -- placement -----------------------------------------------------------
    def device_of(self, tid: int) -> int:
        """The device index ``tid`` is placed on (0 for everything
        until the first sweep places it)."""
        return self._placement.get(tid, 0)

    def placements(self) -> dict[int, int]:
        """Snapshot of the current ``{tid: device_index}`` map."""
        return dict(self._placement)

    def _active_costs(self) -> dict[int, float]:
        return placement_mod.estimate_costs(
            {tid: t.state for tid, t in self._tenants.items()
             if not t.state.phase.terminal})

    def _device_loads(self) -> np.ndarray:
        costs = self._active_costs()
        live = {tid: d for tid, d in self._placement.items()
                if tid in costs}
        return placement_mod.device_loads(live, costs, self.n_devices)

    def _place_new(self) -> None:
        """Assign every not-yet-placed live tenant to a device and fire
        its trainer's ``place_on`` hook. Runs at the top of each sweep,
        right after intake, so placement sees post-stage-1 states."""
        fresh = [tid for tid, t in self._tenants.items()
                 if tid not in self._placement
                 and not t.state.phase.terminal]
        if not fresh:
            return
        costs = self._active_costs()
        live = {tid: d for tid, d in self._placement.items()
                if tid in costs}
        assignment = self.placement_policy.place(
            fresh, self.n_devices, costs,
            placement_mod.device_loads(live, costs, self.n_devices),
            placement_mod.device_counts(live, self.n_devices))
        for tid in fresh:
            dev = int(assignment[tid])
            if not 0 <= dev < self.n_devices:
                raise ValueError(
                    f"placement policy {self.placement_policy.name!r} "
                    f"put task {tid} on device {dev} "
                    f"(n_devices={self.n_devices})")
            self._placement[tid] = dev
            hook = getattr(self._tenants[tid].trainer, "place_on", None)
            if hook is not None:
                hook(dev)

    def rebalance(self) -> int:
        """Re-place every migratable tenant through the placement
        policy now; returns how many tenants actually moved.

        Migratable = live, nothing in flight, and sitting at a period
        boundary (``POOL_SELECTED`` / ``PERIOD_CHECKPOINT``) — a task
        mid-period keeps its device so its round stream is untouched.
        Called automatically by :meth:`sweep` when
        ``rebalance_threshold`` is set and the estimated max/mean
        device load exceeds it; safe to call manually any time.
        """
        movable = [tid for tid, t in self._tenants.items()
                   if not t.state.phase.terminal
                   and tid in self._placement
                   and t.state.pending is None
                   and t.state.phase in (TaskPhase.POOL_SELECTED,
                                         TaskPhase.PERIOD_CHECKPOINT)]
        if not movable:
            return 0
        costs = self._active_costs()
        pinned = {tid: d for tid, d in self._placement.items()
                  if tid in costs and tid not in movable}
        assignment = self.placement_policy.place(
            movable, self.n_devices, costs,
            placement_mod.device_loads(pinned, costs, self.n_devices),
            placement_mod.device_counts(pinned, self.n_devices))
        moved = 0
        for tid in movable:
            if self._migrate(tid, int(assignment[tid])):
                moved += 1
        self.migrations += moved
        return moved

    def _migrate(self, tid: int, new_dev: int) -> bool:
        """Move one boundary-parked tenant to ``new_dev`` over the
        checkpoint path: flush its control state through
        ``TaskState.to_arrays`` → ``from_arrays`` (proving the task
        would survive a cross-host move), re-home its queue entry, and
        re-place the trainer. Round/schedule histories are carried
        over — they live outside the serialized control state — so
        results are bit-identical to a never-migrated run."""
        old_dev = self._placement[tid]
        if new_dev == old_dev:
            return False
        t = self._tenants[tid]
        fresh = TaskState.from_arrays(t.state.to_arrays())
        fresh.rounds = t.state.rounds
        fresh.schedules = t.state.schedules
        t.state = fresh
        self._placement[tid] = new_dev
        if tid in self._ready[old_dev]:
            self._ready[old_dev].remove(tid)
            self._ready[new_dev].append(tid)
        hook = getattr(t.trainer, "place_on", None)
        if hook is not None:
            hook(new_dev)
        return True

    def _handle_ready(self, t: _Tenant) -> bool:
        """Whether the tenant's pending chunk can be collected without
        blocking. Trainers without a ``poll(handle) -> bool`` method (or
        sync chunks) are always treated as ready — collect() on them is
        the pre-ISSUE-7 behaviour."""
        p = t.state.pending
        if p is None or p.sync:
            return True
        poll = getattr(p.trainer, "poll", None)
        if poll is None:
            return True
        return bool(poll(p.handle))

    def _evict(self, tid: int) -> None:
        """Abandon a wedged tenant's in-flight chunk: unpin its clients,
        drop the handle, and degrade the task (terminal) so the window
        slot frees up and every other tenant keeps progressing."""
        t = self._tenants[tid]
        p = t.state.pending
        if p is not None and p.pinned is not None and p.pool is not None:
            p.pool.unpin(p.pinned)
        t.state.pending = None
        t.state.phase = TaskPhase.DEGRADED

    def _pump_into_flight(self, tid: int) -> None:
        """Advance ``tid`` until a chunk is in flight or the task is
        terminal: host-only transitions run inline (overlapping whatever
        is already enqueued), then :func:`dispatch`. A dispatch guard
        (period exhausted, ``max_rounds``/``stop`` hit) advances the
        phase host-side and the loop continues — mirroring what
        :func:`drain` does, minus the blocking collect."""
        t = self._tenants[tid]
        dev = self._placement.get(tid, 0)
        while not t.state.phase.terminal:
            if t.state.pending is not None:
                # already in flight (e.g. a state the caller dispatched
                # before adopt()): track it, don't re-dispatch
                t.inflight_age = 0
                self._inflight[dev].append(tid)
                return
            if t.state.phase in (TaskPhase.SCHEDULED, TaskPhase.TRAINING):
                # under a fault plan a dispatch may come back with
                # nothing in flight (quorum-miss retry); the loop then
                # retries inline, bounded by max_retries -> DEGRADED
                dispatch(self.provider, t.state, t.trainer,
                         stop_fn=t.stop_fn)
                if t.state.pending is not None:
                    t.inflight_age = 0
                    self._inflight[dev].append(tid)
                    return
            else:               # POOL_SELECTED / PERIOD_CHECKPOINT
                t.state, _ = step(self.provider, t.state, t.trainer,
                                  availability_fn=t.availability_fn,
                                  stop_fn=t.stop_fn)

    def run(self, max_sweeps: int = 1_000_000
            ) -> dict[int, ServiceRunResult]:
        """Drive every task to completion; returns per-task results."""
        sweeps = 0
        while self.active:
            self.sweep()
            sweeps += 1
            if sweeps >= max_sweeps:
                raise RuntimeError(f"tasks {self.active} still active "
                                   f"after {max_sweeps} sweeps")
        return self.results()

    def results(self) -> dict[int, ServiceRunResult]:
        return {tid: as_run_result(t.state)
                for tid, t in self._tenants.items()}

    def retire(self, tid: int) -> ServiceRunResult:
        """Evict a finished task and return its result. A continuously
        serving provider must retire completed tenants, or the scheduler
        retains every task's full round history forever."""
        t = self._tenants[tid]
        if not t.state.phase.terminal:
            raise ValueError(f"task {tid} still {t.state.phase.name}; "
                             f"only terminal tasks can be retired")
        del self._tenants[tid]
        self._placement.pop(tid, None)
        return as_run_result(t.state)
