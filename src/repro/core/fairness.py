"""Fairness metrics and guarantee checks (paper §VII).

The paper's fairness guarantee has two parts:
  1. every client meeting the minimum requirements is *considered* for
     the pool (threshold filter keeps them in the optimization);
  2. every pooled client participates in >= 1 round per scheduling
     period, and over-participation is bounded by x*.

This module provides checkable predicates for both plus standard
quantitative fairness measures used in the FL-fairness literature
(Jain's index, participation-count variance) so experiments can report
*how* fair a schedule is, not only that the guarantee holds.
"""
from __future__ import annotations

import numpy as np

from .scheduling import ScheduleResult


def coverage(result: ScheduleResult, pool_ids) -> bool:
    """Part 2a: every pooled client selected at least once."""
    return all(result.counts.get(k, 0) >= 1 for k in pool_ids)


def bounded_participation(result: ScheduleResult, x_star: int) -> bool:
    """Part 2b: no client selected more than x* times."""
    return all(v <= x_star for v in result.counts.values())


def participation_counts(result: ScheduleResult) -> np.ndarray:
    return np.array(sorted(result.counts.values()), dtype=np.float64)


def jain_index(counts: np.ndarray) -> float:
    """Jain's fairness index in (0, 1]; 1 = perfectly equal counts."""
    c = np.asarray(counts, dtype=np.float64)
    if c.size == 0 or np.all(c == 0):
        return 1.0
    return float((c.sum() ** 2) / (c.size * (c ** 2).sum()))


def over_selection_fraction(result: ScheduleResult) -> float:
    """Fraction of clients selected more than once (paper §VII argues this
    stays small, controlled by δ and x*)."""
    counts = participation_counts(result)
    if counts.size == 0:
        return 0.0
    return float(np.mean(counts > 1))


def selection_chance_ratio(selected_counts: np.ndarray,
                           trials: int) -> np.ndarray:
    """Part 1 empirical check: per-client probability of entering the pool
    across repeated stage-1 runs (with resampled costs/scores)."""
    return np.asarray(selected_counts, dtype=np.float64) / max(trials, 1)


def fairness_report(result: ScheduleResult, pool_ids, x_star: int) -> dict:
    counts = participation_counts(result)
    return {
        "coverage": coverage(result, pool_ids),
        "bounded": bounded_participation(result, x_star),
        "jain_index": jain_index(counts),
        "over_selection_fraction": over_selection_fraction(result),
        "mean_count": float(counts.mean()) if counts.size else 0.0,
        "max_count": int(counts.max()) if counts.size else 0,
        "rounds": result.num_rounds,
        "max_nid": result.max_nid(),
    }
