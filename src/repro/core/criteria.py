"""Client selection criteria (paper §IV).

Implements Table I's eleven per-criterion scores, the non-iid degree
``Nid`` (Eq. 2) and its alternatives (L2 / Hellinger / KL distances to
uniform), the overall weighted score (Eq. 6) and the linear cost model
(Eq. 7).

Everything here is plain numpy: this is the FL service provider's
control plane, executed once per task intake / scheduling period, not a
device-scale workload (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

# Canonical criterion order (paper rewrites s_CPU..s_Bhvr as s_1..s_11).
CRITERIA = (
    "cpu", "gpu", "mem", "str", "pow", "bdw", "con",  # resources (7)
    "data_size", "data_dist",                          # data quality (2)
    "model_q", "bhvr",                                 # reputation (2)
)
NUM_CRITERIA = len(CRITERIA)
# Indices of the nine "static" criteria thresholded in Eq. (8d): the paper
# thresholds s_1..s_9 (resources + data quality); reputation criteria are
# dynamic and handled by the scheduling-period pool update instead.
THRESHOLDED = tuple(range(9))

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Resource scores (§IV-A)
# ---------------------------------------------------------------------------

def resource_scores(raw: np.ndarray, minimums: np.ndarray) -> np.ndarray:
    """Convert raw resource readings into (0,1) scores.

    ``raw`` is (n_clients, n_resources); ``minimums`` is the task
    requester's minimal requirement per resource. Per the paper, each
    client's reading is divided by the minimum requirement and the
    resulting column is normalized into (0, 1).
    """
    raw = np.asarray(raw, dtype=np.float64)
    minimums = np.asarray(minimums, dtype=np.float64)
    if np.any(minimums <= 0):
        raise ValueError("minimal requirements must be positive")
    ratio = raw / minimums
    # Normalize each column into (0, 1] by its max (max-normalization keeps
    # the "meets requirement" semantics: ratio>=1 iff requirement met).
    denom = np.maximum(ratio.max(axis=0, keepdims=True), _EPS)
    return ratio / denom


def meets_minimums(raw: np.ndarray, minimums: np.ndarray) -> np.ndarray:
    """Boolean per-client mask: every resource >= the task minimum."""
    raw = np.asarray(raw, dtype=np.float64)
    minimums = np.asarray(minimums, dtype=np.float64)
    return np.all(raw >= minimums, axis=-1)


# ---------------------------------------------------------------------------
# Data distribution score (§IV-B)
# ---------------------------------------------------------------------------

def nid(hist: np.ndarray) -> np.ndarray:
    """Non-iid degree, Eq. (2): (max(h) - min(h)) / sum(h).

    Accepts a single histogram (c,) or a batch (n, c). Empty histograms
    (sum == 0) have Nid defined as 1 (maximally non-iid: no data).
    """
    h = np.asarray(hist, dtype=np.float64)
    total = h.sum(axis=-1)
    spread = h.max(axis=-1) - h.min(axis=-1)
    return np.where(total > 0, spread / np.maximum(total, _EPS), 1.0)


def data_dist_score(hist: np.ndarray) -> np.ndarray:
    """s_DataDist = 1 - Nid(h)."""
    return 1.0 - nid(hist)


def _normalize(hist: np.ndarray) -> np.ndarray:
    h = np.asarray(hist, dtype=np.float64)
    return h / np.maximum(h.sum(axis=-1, keepdims=True), _EPS)


def nid_l2(hist: np.ndarray) -> np.ndarray:
    """Alternative non-iid degree: L2 distance to uniform, scaled to [0,1]."""
    p = _normalize(hist)
    c = p.shape[-1]
    u = 1.0 / c
    d = np.sqrt(((p - u) ** 2).sum(axis=-1))
    # max L2 distance to uniform is sqrt((1-1/c)^2 + (c-1)/c^2) = sqrt(1-1/c)
    return d / np.sqrt(1.0 - 1.0 / c)


def nid_hellinger(hist: np.ndarray) -> np.ndarray:
    """Alternative non-iid degree: Hellinger distance to uniform, rescaled
    so a one-hot histogram maps to 1 (max H to uniform is sqrt(1-1/sqrt(c)))."""
    p = _normalize(hist)
    c = p.shape[-1]
    u = 1.0 / c
    h = np.sqrt(np.clip(1.0 - (np.sqrt(p) * np.sqrt(u)).sum(axis=-1), 0.0, None))
    return np.clip(h / np.sqrt(1.0 - np.sqrt(u)), 0.0, 1.0)


def nid_kl(hist: np.ndarray) -> np.ndarray:
    """Alternative non-iid degree: KL(p || uniform), normalized by log(c)."""
    p = _normalize(hist)
    c = p.shape[-1]
    kl = np.sum(np.where(p > 0, p * np.log(np.maximum(p, _EPS) * c), 0.0), axis=-1)
    return np.clip(kl / np.log(c), 0.0, 1.0)


NID_VARIANTS = {
    "range": nid,
    "l2": nid_l2,
    "hellinger": nid_hellinger,
    "kl": nid_kl,
}


# ---------------------------------------------------------------------------
# Historical model quality (§IV-C) and behavior (§IV-D)
# ---------------------------------------------------------------------------

def cosine_similarity(a: np.ndarray, b: np.ndarray) -> float:
    """Per-round model quality q_t = sim(w_l, w_g) (cosine)."""
    a = np.asarray(a, dtype=np.float64).ravel()
    b = np.asarray(b, dtype=np.float64).ravel()
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if na < _EPS or nb < _EPS:
        return 0.0
    return float(np.dot(a, b) / (na * nb))


def per_task_average(per_round: Sequence[float]) -> float:
    """Eqs. (3)/(5): average of per-round values over participated rounds."""
    vals = np.asarray(list(per_round), dtype=np.float64)
    if vals.size == 0:
        return 0.0
    return float(vals.mean())


def history_score(per_task: Sequence[float], window: int | None = None) -> float:
    """s_ModelQ / s_Bhvr: average of all (or the ``window`` most recent)
    per-task values."""
    vals = list(per_task)
    if window is not None:
        vals = vals[-window:]
    return per_task_average(vals)


# ---------------------------------------------------------------------------
# Overall score and cost (§IV-E)
# ---------------------------------------------------------------------------

def overall_score(scores: np.ndarray, weights: np.ndarray | None = None) -> np.ndarray:
    """Eq. (6): Score = w · s. ``scores`` is (..., 11)."""
    s = np.asarray(scores, dtype=np.float64)
    if s.shape[-1] != NUM_CRITERIA:
        raise ValueError(f"expected {NUM_CRITERIA} criteria, got {s.shape[-1]}")
    if weights is None:
        weights = np.ones(NUM_CRITERIA)
    w = np.asarray(weights, dtype=np.float64)
    return s @ w


def linear_cost(score: np.ndarray, a: float = 2.0, b: float = 5.0,
                integer: bool = False) -> np.ndarray:
    """Eq. (7): Cost = a·Score + b, a > 0. ``integer=True`` rounds to the
    nearest integer as in the paper's Experiment 1."""
    if a <= 0:
        raise ValueError("a must be > 0")
    c = a * np.asarray(score, dtype=np.float64) + b
    return np.rint(c) if integer else c


# ---------------------------------------------------------------------------
# Client record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientProfile:
    """A registered client as the FL service provider sees it (§III)."""

    client_id: int
    scores: np.ndarray                 # (11,) criterion scores in (0,1)
    histogram: np.ndarray              # (c,) label histogram of local data
    cost: float                        # per-round/task price
    available: bool = True
    # reputation bookkeeping (per-task vectors, §IV-C/D)
    model_q_history: list = dataclasses.field(default_factory=list)
    bhvr_history: list = dataclasses.field(default_factory=list)

    @property
    def data_size(self) -> int:
        return int(np.sum(self.histogram))

    @property
    def score(self) -> float:
        return float(overall_score(self.scores))

    def criterion(self, name: str) -> float:
        return float(self.scores[CRITERIA.index(name)])


def build_profiles(
    scores: np.ndarray,
    histograms: np.ndarray,
    costs: np.ndarray,
) -> list[ClientProfile]:
    """Vector inputs -> list of ClientProfile."""
    n = scores.shape[0]
    if histograms.shape[0] != n or np.shape(costs)[0] != n:
        raise ValueError("mismatched client counts")
    return [
        ClientProfile(
            client_id=i,
            scores=np.asarray(scores[i], dtype=np.float64),
            histogram=np.asarray(histograms[i], dtype=np.float64),
            cost=float(costs[i]),
        )
        for i in range(n)
    ]


def random_histograms(n_clients: int, n_classes: int,
                      rng: np.random.Generator,
                      lo: int = 10, hi: int = 200) -> np.ndarray:
    """Vectorized non-iid histogram sampler: per client a uniform label
    count k ~ U{1..c}, k distinct labels, counts ~ U{lo..hi-1}. O(n·c)
    array ops — no per-client Python loop, so 100k+ pools build in
    milliseconds (used by ``ClientPoolState.random``)."""
    perm = rng.random((n_clients, n_classes)).argsort(axis=1)
    k = rng.integers(1, n_classes + 1, size=n_clients)
    on = np.arange(n_classes) < k[:, None]
    vals = rng.integers(lo, hi, size=(n_clients, n_classes)).astype(np.float64)
    hists = np.zeros((n_clients, n_classes))
    np.put_along_axis(hists, perm, np.where(on, vals, 0.0), axis=1)
    return hists


def random_profiles(
    n_clients: int,
    n_classes: int,
    rng: np.random.Generator,
    cost_a: float = 2.0,
    cost_b: float = 5.0,
    integer_cost: bool = True,
) -> list[ClientProfile]:
    """Virtual clients with random criterion scores (paper §VIII-A) and
    random non-iid histograms; cost from Eq. (7)."""
    scores = rng.uniform(0.0, 1.0, size=(n_clients, NUM_CRITERIA))
    # histograms: random number of labels per client, random sizes
    hists = np.zeros((n_clients, n_classes))
    for i in range(n_clients):
        k = int(rng.integers(1, n_classes + 1))
        labels = rng.choice(n_classes, size=k, replace=False)
        hists[i, labels] = rng.integers(10, 200, size=k)
    # data-driven criteria overwrite the random placeholders
    sizes = hists.sum(axis=1)
    scores[:, CRITERIA.index("data_size")] = sizes / sizes.max()
    scores[:, CRITERIA.index("data_dist")] = data_dist_score(hists)
    total = overall_score(scores)
    costs = linear_cost(total, cost_a, cost_b, integer=integer_cost)
    return build_profiles(scores, hists, costs)
