"""Tenant -> device placement policies (the multi-device service seam).

Through PR 4 every tenant's round chunks funneled through one device
stream: ``ServiceScheduler`` kept a single global in-flight window, so
a JAX mesh beyond device 0 sat idle — the ROADMAP's named blocker for
"heavy traffic from millions of users" (arXiv 2312.14941 §III). This
module is the placement half of the fix: small, host-only policies
that map tenant ids onto device indices, mirroring the
``core.policy`` registry so deployments can swap strategies by name
(``ServiceScheduler(..., n_devices=8, placement="bin_pack")``).

The scheduler side (``core.lifecycle``) keeps one ready queue and one
in-flight window *per device* and pumps them independently, so one
device's straggler never stalls another device's tenants; at
``PERIOD_CHECKPOINT`` boundaries it may migrate tenants between
devices when the estimated load imbalance exceeds a threshold
(flush -> re-place -> resume over the PR 3 ``TaskState.to_arrays``
checkpoint path). See ``docs/placement.md``.

Everything here is numpy-only and device-agnostic: a "device" is just
an index ``0..n_devices-1``. Trainers opt into physical placement by
exposing a ``place_on(device_index)`` hook (looked up with ``getattr``,
like the policy hooks) and resolving ``jax.devices()[i]`` themselves —
the control plane never imports jax.

Protocol
--------

- :class:`PlacementPolicy` — ``place(tids, n_devices, costs, loads,
  counts)`` maps a batch of tenant ids to ``{tid: device_index}``.
  ``costs`` is the per-tenant estimated per-round cost (seconds; from
  the ``obs/latency`` telemetry window when available, 1.0 otherwise),
  ``loads`` the current estimated cost-weighted load per device and
  ``counts`` the current tenant count per device — all advisory;
  implementations must be deterministic in their inputs so a restored
  service re-places identically.

Shipped policies
----------------

- ``round_robin`` — cyclic assignment in submission order, continuing
  the cycle across incremental batches (the classic baseline).
- ``bin_pack`` — greedy longest-processing-time bin packing: place the
  costliest tenant first, always onto the least-loaded device. With
  per-tenant costs from ``obs/latency`` this approximates makespan-
  balanced placement (2-approximation, Graham 1969).
"""
from __future__ import annotations

from typing import Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

#: obs/latency window entries below this count fall back to the unit
#: cost — one or two samples are noise, not a signal worth packing on.
_MIN_LATENCY_SAMPLES = 1


# ---------------------------------------------------------------------------
# Protocol + registry (mirrors core.policy)
# ---------------------------------------------------------------------------

@runtime_checkable
class PlacementPolicy(Protocol):
    """Maps tenant ids to device indices.

    ``place`` receives the tenants to (re)place in submission order,
    the device count, per-tenant cost estimates, the current
    per-device cost-weighted load vector and the current per-device
    tenant counts (both length ``n_devices``, float64; contributions
    of tenants being re-placed are already subtracted). It returns a
    ``{tid: device_index}`` dict covering exactly ``tids``; indices
    must lie in ``[0, n_devices)``. Implementations are stateless —
    one shared instance serves every scheduler — and deterministic in
    their inputs.
    """

    name: str

    def place(self, tids: Sequence[int], n_devices: int,
              costs: Mapping[int, float], loads: np.ndarray,
              counts: np.ndarray) -> dict[int, int]: ...


_PLACEMENT: dict[str, PlacementPolicy] = {}

DEFAULT_PLACEMENT_POLICY = "bin_pack"


def register_placement_policy(policy):
    """Register a :class:`PlacementPolicy` class or instance under its
    ``name``. Usable as a class decorator; duplicate names raise."""
    inst = policy() if isinstance(policy, type) else policy
    if not isinstance(inst, PlacementPolicy):
        raise TypeError(f"{policy!r} does not implement PlacementPolicy "
                        f"(name, place)")
    if inst.name in _PLACEMENT:
        raise ValueError(f"placement policy {inst.name!r} already registered")
    _PLACEMENT[inst.name] = inst
    return policy


def placement_policy(name: str) -> PlacementPolicy:
    try:
        return _PLACEMENT[name]
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r}; registered: "
                       f"{available_placement_policies()}") from None


def available_placement_policies() -> list[str]:
    return sorted(_PLACEMENT)


def resolve_placement_policy(spec: "str | PlacementPolicy | None"
                             ) -> PlacementPolicy:
    """Registry lookup for a name, passthrough for an instance,
    ``bin_pack`` for ``None`` (the scheduler default)."""
    if spec is None:
        return placement_policy(DEFAULT_PLACEMENT_POLICY)
    if isinstance(spec, str):
        return placement_policy(spec)
    if isinstance(spec, PlacementPolicy):
        return spec
    raise TypeError(f"placement must be a registered name or a "
                    f"PlacementPolicy, got {spec!r}")


# ---------------------------------------------------------------------------
# Cost estimation (the obs/latency bridge)
# ---------------------------------------------------------------------------

def estimate_cost(policy_state: Mapping[str, np.ndarray] | None,
                  default: float = 1.0) -> float:
    """Per-round cost estimate for one tenant, in seconds.

    Reads the rolling ``obs/latency`` window the lifecycle maintains on
    ``TaskState.policy_state`` (mean observed round latency over the
    last <=128 settled rounds). Tenants without telemetry yet — fresh
    submissions, or services running without fault-mode timing — cost
    ``default`` (1.0), which degrades bin packing to count balancing.
    """
    if policy_state is None:
        return float(default)
    lat = policy_state.get("obs/latency")
    if lat is None:
        return float(default)
    lat = np.asarray(lat, dtype=np.float64).ravel()
    lat = lat[np.isfinite(lat) & (lat > 0.0)]
    if lat.size < _MIN_LATENCY_SAMPLES:
        return float(default)
    return float(lat.mean())


def estimate_costs(states: Mapping[int, object],
                   default: float = 1.0) -> dict[int, float]:
    """``{tid: cost}`` over ``{tid: TaskState}`` via :func:`estimate_cost`."""
    return {tid: estimate_cost(getattr(s, "policy_state", None), default)
            for tid, s in states.items()}


def device_loads(placement: Mapping[int, int], costs: Mapping[int, float],
                 n_devices: int) -> np.ndarray:
    """Estimated load per device: sum of placed tenants' costs,
    ``(n_devices,)`` float64."""
    loads = np.zeros(int(n_devices), dtype=np.float64)
    for tid, dev in placement.items():
        loads[dev] += float(costs.get(tid, 1.0))
    return loads


def device_counts(placement: Mapping[int, int], n_devices: int) -> np.ndarray:
    """Tenant count per device, ``(n_devices,)`` float64."""
    counts = np.zeros(int(n_devices), dtype=np.float64)
    for dev in placement.values():
        counts[dev] += 1.0
    return counts


def imbalance(loads: np.ndarray) -> float:
    """Max/mean device-load ratio (>= 1.0; 1.0 = perfectly balanced).

    An empty or all-zero load vector is balanced by definition. This is
    the migrate-on-imbalance trigger: the scheduler re-places when
    ``imbalance(loads) > rebalance_threshold``.
    """
    loads = np.asarray(loads, dtype=np.float64)
    if loads.size == 0:
        return 1.0
    mean = float(loads.mean())
    if mean <= 0.0:
        return 1.0
    return float(loads.max()) / mean


# ---------------------------------------------------------------------------
# Shipped policies
# ---------------------------------------------------------------------------

@register_placement_policy
class RoundRobinPlacement:
    """Cyclic assignment in submission order — the classic baseline.

    Cost-blind: each tenant goes to the device hosting the fewest
    tenants (ties -> lowest index), which on a fresh fleet is exactly
    the 0,1,...,n-1,0,1,... deal and keeps dealing cyclically across
    incremental batches (``counts`` carries the cycle position).
    """

    name = "round_robin"

    def place(self, tids, n_devices, costs, loads, counts):
        cnt = np.asarray(counts, dtype=np.float64).copy()
        out: dict[int, int] = {}
        for tid in tids:
            dev = int(np.argmin(cnt))     # first minimum -> lowest index
            out[int(tid)] = dev
            cnt[dev] += 1.0
        return out


@register_placement_policy
class BinPackPlacement:
    """Greedy LPT bin packing: costliest tenant first, least-loaded
    device always. Ties in cost break by tenant id (submission order),
    ties in load by device index — fully deterministic."""

    name = "bin_pack"

    def place(self, tids, n_devices, costs, loads, counts):
        load = np.asarray(loads, dtype=np.float64).copy()
        order = sorted(tids, key=lambda t: (-float(costs.get(t, 1.0)), t))
        out: dict[int, int] = {}
        for tid in order:
            dev = int(np.argmin(load))
            out[int(tid)] = dev
            load[dev] += float(costs.get(tid, 1.0))
        return out
