"""Trace generation for the online workload harness (production plane).

The benches up to ISSUE-7 drive the service with steady-state fleets of
identical tasks submitted all at once. A production FL *service* sees
none of that: tasks arrive as traffic (smooth or bursty), clients drift
in and out on diurnal waves, and device speeds span orders of magnitude
(the deployed-FL surveys in PAPERS.md call out exactly these gaps
between simulation and practice). This module generates all three
signals as **seeded, counter-based traces** in the
:class:`~repro.core.faults.FaultPlan` splitmix64 idiom:

- every draw is a pure function of ``(seed, stream, counter)`` — no
  stateful RNG anywhere — so a trace replays **bit-identically** and is
  **order-independent**: querying windows/clients/chunks in any order,
  or in any chunking, yields the same numbers (tested in
  tests/test_workload.py);
- traces are cheap to evaluate lazily: the driver
  (:mod:`repro.core.driver`) asks for exactly the windows it reaches.

Three generators plus a bundle:

- :class:`ArrivalTrace` — task arrivals. Time is cut into fixed
  ``window``-length windows; each window draws a Poisson arrival count
  (inverse-CDF from one counter-based uniform) and uniform arrival
  offsets. ``burst_prob``/``burst_rate`` turn the constant-rate Poisson
  process into a two-state MMPP (Markov-modulated Poisson): a window is
  a *burst* window with probability ``burst_prob`` and draws at
  ``burst_rate`` instead — bursty, overdispersed traffic from the same
  counter-based machinery.
- :class:`DiurnalAvailability` — per-client availability waves. Each
  client has a fixed phase and amplitude (drawn once from the seed);
  its duty cycle at time ``t`` is a clipped sinusoid over the ``day``
  period around ``base``, and availability is re-drawn per
  ``tick``-length window against that duty. Mean duty over a full day
  is ``base`` (the sinusoid averages out) — the tolerance checked in
  tests. Composable with the lifecycle's ``availability_fn`` seam via
  :meth:`DiurnalAvailability.availability_fn`.
- :class:`DeviceSpeedProfile` — heterogeneous device speeds. Each
  client draws a speed *class* (e.g. flagship/mid/low-end multipliers,
  weighted) plus per-client lognormal jitter (Box–Muller over two
  counter-based uniforms), going beyond the binary chronic-straggler
  trait of :class:`~repro.core.faults.FaultPlan`.
  :class:`HeterogeneousFaultPlan` composes the two: a ``FaultPlan``
  whose per-round latencies are scaled by the profile's per-client
  multiplier, so speed classes, chronic stragglers, crashes and
  outages all ride the same ``round_outcome`` evaluation.
- :class:`WorkloadTrace` — the bundle the driver consumes: an arrival
  trace, an optional availability trace, an optional fault plan, a
  per-arrival ``TaskRequest`` template factory, and a horizon.
  :func:`make_workload` ships the three named regimes the workload
  bench studies (``light`` / ``saturating`` / ``bursty``) plus
  ``steady`` and ``diurnal`` presets for the demo.

All times are unitless simulated time, the same axis as
``FaultPlan.base_latency`` and the lifecycle's
``metrics["round_latency"]`` (docs/robustness.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .faults import FaultPlan, _u01

# stream ids for this module's counter-based draws (FaultPlan owns 1-5)
_S_BURST = 11        # per-window burst state
_S_COUNT = 12        # per-window arrival count
_S_OFFSET = 13       # per-(window, j) arrival offset
_S_PHASE = 21        # per-client diurnal phase
_S_AMP = 22          # per-client diurnal amplitude
_S_AVAIL = 23        # per-(client, tick) availability draw
_S_CLASS = 31        # per-client speed class
_S_JIT1 = 32         # per-client lognormal jitter (Box-Muller u1)
_S_JIT2 = 33         # per-client lognormal jitter (Box-Muller u2)


def _poisson_icdf(mean: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Vectorized Poisson inverse CDF: the smallest k with
    ``CDF(k) > u``, evaluated by walking the pmf recurrence. Exact for
    the small per-window means traces use (the loop is bounded by the
    largest count actually drawn, not a fixed cap)."""
    mean = np.asarray(mean, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    pmf = np.exp(-mean)
    cdf = pmf.copy()
    counts = np.zeros(u.shape, dtype=np.int64)
    active = u >= cdf
    k = 0
    while active.any():
        k += 1
        pmf = pmf * mean / k
        cdf = cdf + pmf
        counts[active] = k
        active = u >= cdf
        if k > 1000:                    # numerically unreachable guard
            break                       # pragma: no cover
    return counts


def _box_muller(u1: np.ndarray, u2: np.ndarray) -> np.ndarray:
    """Standard normals from two counter-based uniform arrays."""
    r = np.sqrt(-2.0 * np.log(np.maximum(1.0 - u1, 1e-300)))
    return r * np.cos(2.0 * np.pi * u2)


# ---------------------------------------------------------------------------
# Task arrivals: Poisson / bursty MMPP
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """Counter-based task-arrival process.

    ``rate`` is the mean arrival rate (tasks per time unit) of a normal
    window; with ``burst_prob > 0`` each window is independently a
    *burst* window (probability ``burst_prob``) drawing at
    ``burst_rate`` instead — a discrete-window two-state MMPP. Every
    window's count and offsets are keyed by the window index alone, so
    any window can be evaluated independently, in any order.
    """

    seed: int = 0
    rate: float = 1.0
    window: float = 8.0
    burst_rate: float = 0.0
    burst_prob: float = 0.0

    def is_burst(self, w) -> np.ndarray:
        """(W,) bool — whether each window index draws at burst rate."""
        w = np.atleast_1d(np.asarray(w, dtype=np.int64))
        if self.burst_prob <= 0.0:
            return np.zeros(w.shape, dtype=bool)
        return _u01(self.seed, _S_BURST, w) < self.burst_prob

    def window_rate(self, w) -> np.ndarray:
        """(W,) float — each window's arrival rate."""
        w = np.atleast_1d(np.asarray(w, dtype=np.int64))
        return np.where(self.is_burst(w), self.burst_rate, self.rate)

    def counts(self, w) -> np.ndarray:
        """(W,) int — Poisson arrival counts per window."""
        w = np.atleast_1d(np.asarray(w, dtype=np.int64))
        mean = self.window_rate(w) * self.window
        return _poisson_icdf(mean, _u01(self.seed, _S_COUNT, w))

    def window_arrivals(self, w: int) -> np.ndarray:
        """Sorted arrival times inside window ``w`` (ascending)."""
        w = int(w)
        n = int(self.counts(w)[0])
        if n == 0:
            return np.zeros(0, dtype=np.float64)
        offs = _u01(self.seed, _S_OFFSET, np.arange(n), extra=w)
        return w * self.window + self.window * np.sort(offs)

    def arrivals(self, t_end: float) -> np.ndarray:
        """All arrival times in ``[0, t_end)``, ascending. Chunk- and
        order-independent: equals the concatenation of the per-window
        queries in any decomposition."""
        t_end = float(t_end)
        n_windows = int(np.ceil(t_end / self.window))
        parts = [self.window_arrivals(w) for w in range(n_windows)]
        times = (np.concatenate(parts) if parts
                 else np.zeros(0, dtype=np.float64))
        return times[times < t_end]


# ---------------------------------------------------------------------------
# Diurnal client availability
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DiurnalAvailability:
    """Per-client diurnal availability waves.

    Client ``c``'s duty cycle at time ``t`` is::

        duty(c, t) = clip(base + amp_c * sin(2*pi*(t/day + phase_c)), 0, 1)

    with ``phase_c`` uniform in [0, 1) and ``amp_c`` uniform in
    ``[amp_min, amp_max]``, both fixed per client by the seed. Whether
    the client is actually available is re-drawn once per
    ``tick``-length window against that duty — counter-based on
    ``(client, tick)``, so any (client, time) cell evaluates
    independently. Averaged over a full day the duty is ``base``.
    """

    seed: int = 0
    base: float = 0.75
    amp_min: float = 0.1
    amp_max: float = 0.4
    day: float = 96.0
    tick: float = 4.0

    def phase(self, ids) -> np.ndarray:
        return _u01(self.seed, _S_PHASE, ids)

    def amplitude(self, ids) -> np.ndarray:
        u = _u01(self.seed, _S_AMP, ids)
        return self.amp_min + (self.amp_max - self.amp_min) * u

    def duty(self, ids, t: float) -> np.ndarray:
        """(K,) float — each client's availability probability at ``t``."""
        ids = np.atleast_1d(np.asarray(ids))
        wave = np.sin(2.0 * np.pi * (float(t) / self.day + self.phase(ids)))
        return np.clip(self.base + self.amplitude(ids) * wave, 0.0, 1.0)

    def available(self, ids, t: float) -> np.ndarray:
        """(K,) bool — availability at time ``t`` (constant within a
        tick window)."""
        ids = np.atleast_1d(np.asarray(ids))
        tick = int(np.floor(float(t) / self.tick))
        u = _u01(self.seed, _S_AVAIL, ids, extra=tick)
        return u < self.duty(ids, tick * self.tick)

    def availability_fn(self, now_fn: Callable[[], float]
                        ) -> Callable[[int, int], bool]:
        """Adapter onto the lifecycle's ``availability_fn(cid, period)``
        seam: the period argument is ignored in favour of the driver's
        virtual clock (``now_fn``), so period checkpoints see the
        availability wave at the simulated time they actually happen."""
        def fn(cid: int, period: int) -> bool:
            return bool(self.available([int(cid)], now_fn())[0])
        return fn


# ---------------------------------------------------------------------------
# Heterogeneous device speeds
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceSpeedProfile:
    """Per-client speed multipliers: weighted speed classes with
    lognormal within-class jitter.

    ``class_mults``/``class_weights`` define the device tiers (a
    multiplier scales round latency, so 1.0 = reference speed, 4.0 =
    4x slower); each client draws its class once from the seed, then a
    lognormal jitter ``exp(sigma * z)`` (Box–Muller ``z`` from two
    counter-based uniforms) spreads devices within the class. All draws
    are keyed by client id — evaluation order never matters.
    """

    seed: int = 0
    class_mults: tuple[float, ...] = (1.0, 2.0, 4.0)
    class_weights: tuple[float, ...] = (0.5, 0.35, 0.15)
    sigma: float = 0.25

    def speed_class(self, ids) -> np.ndarray:
        """(K,) int — each client's speed-class index."""
        ids = np.atleast_1d(np.asarray(ids))
        w = np.asarray(self.class_weights, dtype=np.float64)
        cum = np.cumsum(w / w.sum())
        u = _u01(self.seed, _S_CLASS, ids)
        return np.minimum(np.searchsorted(cum, u, side="right"),
                          len(self.class_mults) - 1)

    def multiplier(self, ids) -> np.ndarray:
        """(K,) float — latency multiplier per client (class x jitter)."""
        ids = np.atleast_1d(np.asarray(ids))
        base = np.asarray(self.class_mults,
                          dtype=np.float64)[self.speed_class(ids)]
        z = _box_muller(_u01(self.seed, _S_JIT1, ids),
                        _u01(self.seed, _S_JIT2, ids))
        return base * np.exp(self.sigma * z)


@dataclasses.dataclass(frozen=True)
class HeterogeneousFaultPlan(FaultPlan):
    """A :class:`~repro.core.faults.FaultPlan` whose per-round latencies
    are additionally scaled by a :class:`DeviceSpeedProfile` — chronic
    stragglers, crashes, outages and device tiers all evaluated by the
    same ``round_outcome`` first-k/deadline machinery. A plan whose
    profile multiplies by anything other than 1 is *active* even with
    every failure rate at zero (its latencies differ from the
    homogeneous plan), so the lifecycle takes the fault-mode path and
    emits ``round_latency`` metrics."""

    speed: DeviceSpeedProfile | None = None

    @property
    def active(self) -> bool:
        if self.speed is not None:
            return True
        return FaultPlan.active.fget(self)

    def latency(self, ids, round_index: int) -> np.ndarray:
        lat = FaultPlan.latency(self, ids, round_index)
        if self.speed is None:
            return lat
        return lat * self.speed.multiplier(ids)


# ---------------------------------------------------------------------------
# The bundle the driver consumes
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkloadTrace:
    """One online workload: arrivals + availability + device behaviour
    + the per-arrival task template.

    ``template(index, time)`` builds the :class:`TaskRequest` for the
    ``index``-th arrival (at trace time ``time``); the driver varies
    nothing else, so two arms sharing a trace but differing in template
    (policy / mitigation knobs) see the *same* traffic. ``plan`` is
    attached to the trainers the driver builds (any object with a
    ``fault_plan`` attribute rides the lifecycle's fault seam).
    """

    arrivals: ArrivalTrace
    template: Callable[[int, float], "object"]
    horizon: float = 64.0
    availability: DiurnalAvailability | None = None
    plan: FaultPlan | None = None


def make_workload(regime: str, seed: int = 0,
                  template: Callable[[int, float], "object"] | None = None,
                  horizon: float | None = None) -> WorkloadTrace:
    """Named workload presets (the regimes the workload bench studies).

    - ``light`` — low-rate Poisson arrivals, straggler-laden
      heterogeneous fleet; the service is never queue-bound.
    - ``saturating`` — Poisson arrivals fast enough to keep the intake
      queue full; completion time is dominated by queueing + round
      latency (the regime the ISSUE-8 acceptance bar measures).
    - ``bursty`` — MMPP arrivals: long quiet stretches punctured by
      burst windows at many times the base rate.
    - ``steady`` — everything at time zero, no availability wave, no
      fault plan: the no-trace identity regime (bit-identical to
      driving the ``ServiceScheduler`` directly).
    - ``diurnal`` — light arrivals plus a strong availability wave
      (for the demo; period checkpoints visibly shed clients).

    ``template`` defaults to ``None`` — callers must set one before the
    driver runs (the bench and demo bring their own); it is a required
    argument of :class:`WorkloadTrace` consumers, not of the trace.
    """
    speed = DeviceSpeedProfile(seed=seed + 3)
    plan = HeterogeneousFaultPlan(
        seed=seed + 1, straggler_frac=0.2, straggler_slowdown=8.0,
        crash_prob=0.02, speed=speed)
    if regime == "light":
        arr = ArrivalTrace(seed=seed, rate=0.25, window=8.0)
        trace = WorkloadTrace(arr, template, horizon=64.0, plan=plan)
    elif regime == "saturating":
        arr = ArrivalTrace(seed=seed, rate=1.5, window=8.0)
        trace = WorkloadTrace(arr, template, horizon=48.0, plan=plan)
    elif regime == "bursty":
        arr = ArrivalTrace(seed=seed, rate=0.125, window=8.0,
                           burst_rate=3.0, burst_prob=0.25)
        trace = WorkloadTrace(arr, template, horizon=64.0, plan=plan)
    elif regime == "steady":
        arr = ArrivalTrace(seed=seed, rate=0.0, window=8.0)
        trace = WorkloadTrace(arr, template, horizon=8.0)
    elif regime == "diurnal":
        arr = ArrivalTrace(seed=seed, rate=0.25, window=8.0)
        avail = DiurnalAvailability(seed=seed + 2, base=0.7,
                                    amp_min=0.2, amp_max=0.5,
                                    day=48.0, tick=4.0)
        trace = WorkloadTrace(arr, template, horizon=64.0,
                              availability=avail, plan=plan)
    else:
        raise ValueError(f"unknown workload regime {regime!r}; known: "
                         f"light, saturating, bursty, steady, diurnal")
    if horizon is not None:
        trace.horizon = float(horizon)
    return trace
