"""Pluggable selection & scheduling policies (the control-plane seam).

Until ISSUE-5 the paper's two stages — budget-greedy pool selection
(§V) and iid-subset per-round scheduling (§VI, Algorithm 1) — were the
*only* strategies the service could run, hard-wired through
``core.selection`` / ``core.scheduling`` imports inside
``FLServiceProvider`` and the lifecycle transitions. This module
inverts that dependency: the provider and lifecycle talk to two small
protocols, and concrete strategies register themselves by name so a
:class:`~repro.core.lifecycle.TaskRequest` can pick its pair
(``selection_policy=\"paper_greedy\"``,
``scheduling_policy=\"iid_subsets\"``) — per task, on one shared pool,
A/B-able inside a single ``ServiceScheduler``.

Protocols
---------

- :class:`SelectionPolicy` — stage 1: ``select(pool, task, rng)`` maps
  the shared ``ClientPoolState`` + a ``TaskRequest`` to a
  ``SelectionResult`` (the task's client pool under its budget /
  ``n_star`` / thresholds). ``select_batch`` serves many concurrent
  tasks in one call — the multi-tenant intake path; the default simply
  loops, the paper policy overrides it with the jit+vmap knapsack
  sweep (``engine.greedy_knapsack_batch``).
- :class:`SchedulingPolicy` — stage 2: ``schedule(ids, histograms,
  task, rng, policy_state)`` maps the task's current pool (ascending-id
  ``(P,)`` ids + ``(P, c)`` label histograms) to a ``ScheduleResult``
  (the period's padded subset schedule the lifecycle consumes).
  ``policy_state`` is a mutable ``{key: numpy array}`` dict owned by
  the ``TaskState`` and checkpointed with it
  (``TaskState.to_arrays``), so stateful policies (participation
  EMAs, round-robin cursors) survive save → kill → restore.

Every registered scheduling policy must uphold the paper's §VII
fairness guarantee — every pooled client scheduled >= once per period,
nobody more than ``x_star`` times, subset sizes in ``[n-δ, n+δ]`` —
property-checked for all registered policies in
``tests/test_fairness.py``.

Shipped policies
----------------

Selection: ``paper_greedy`` (default; §VI-A score/cost-ratio greedy,
bit-identical to the pre-registry ``select_pool`` /
``select_pools_batch``), ``dp`` (exact knapsack), ``random`` (the
paper's uniform baseline), ``score_prop`` (score-proportional sampling
under the same budget — the softened baseline used by fairness-aware
selection papers).

Scheduling: ``iid_subsets`` (default; Algorithm 1, bit-identical to
the pre-registry ``generate_subsets`` path), ``random_partition``
(the paper's random baseline; also what the legacy
``TaskRequest.scheduler=\"random\"`` maps to), ``fair_ema``
(participation-EMA-penalized scheduling in the spirit of Shi et al.,
*Fairness-Aware Client Selection for Federated Learning*, 2023 — see
:class:`FairEMAScheduling`).

Adding a policy
---------------

::

    from repro.core import policy

    @policy.register_selection_policy
    class CheapestFirst:
        name = "cheapest_first"
        def select(self, pool, task, rng):
            ...
        def select_batch(self, pool, tasks, rngs):
            return [self.select(pool, t, r) for t, r in zip(tasks, rngs)]

    TaskRequest(budget=100.0, selection_policy="cheapest_first")

See ``docs/policies.md`` for the full contracts.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, Sequence, runtime_checkable

import numpy as np

from . import engine
from .criteria import nid
from .pool import ClientPoolState
from .scheduling import ScheduleResult, generate_subsets, random_subsets
from .selection import (SelectionResult, select_dp, select_greedy,
                        select_initial_pool, select_random,
                        select_score_prop, select_score_prop_batch)

if TYPE_CHECKING:                     # import cycle: lifecycle imports
    from .lifecycle import TaskRequest  # selection/scheduling like we do


# ---------------------------------------------------------------------------
# Protocols
# ---------------------------------------------------------------------------

@runtime_checkable
class SelectionPolicy(Protocol):
    """Stage 1 strategy: pool-state arrays + TaskRequest -> selected pool.

    Implementations must be stateless (one shared instance serves every
    task); anything that must persist belongs in the task's rng or its
    ``policy_state``. ``select`` consumes ``rng`` deterministically (or
    not at all), so a task restored from a checkpoint re-selects
    identically.

    Policies may additionally implement the *optional* hook
    ``select_joiners(scores, costs, budget_left, rng) -> positions``:
    the admission rule for threshold-eligible clients that join
    mid-period (``PERIOD_CHECKPOINT`` churn, see ``core.lifecycle``).
    It is looked up with ``getattr`` — deliberately NOT part of this
    protocol, so pre-existing custom policies keep registering; tasks
    running a policy without the hook fall back to the legacy greedy
    admission rule.
    """

    name: str

    def select(self, pool: ClientPoolState, task: "TaskRequest",
               rng: np.random.Generator | None) -> SelectionResult: ...

    def select_batch(self, pool: ClientPoolState,
                     tasks: Sequence["TaskRequest"],
                     rngs: Sequence[np.random.Generator | None],
                     ) -> list[SelectionResult]: ...


@runtime_checkable
class SchedulingPolicy(Protocol):
    """Stage 2 strategy: pool arrays + per-task history -> period schedule.

    ``ids``/``histograms`` are the task's *current* pool in ascending-id
    order (``(P,)`` int64, ``(P, c)`` float64). ``policy_state`` is the
    task-owned ``{key: numpy array}`` cursor dict — read what you wrote
    last period, write what the next period needs; it round-trips
    through ``TaskState.to_arrays`` so keys must be strings and values
    numpy arrays. Stateless policies simply ignore it.

    Every implementation must uphold the §VII guarantee: coverage
    (every pooled client in >= 1 subset), bounded participation
    (<= ``task.x_star``), and subset sizes in
    ``[task.subset_size - task.subset_delta, task.subset_size +
    task.subset_delta]`` (the final subset may be the smaller tail).
    ``tests/test_fairness.py`` property-checks all registered policies.
    """

    name: str

    def schedule(self, ids: np.ndarray, histograms: np.ndarray,
                 task: "TaskRequest", rng: np.random.Generator,
                 policy_state: dict[str, np.ndarray]) -> ScheduleResult: ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_SELECTION: dict[str, SelectionPolicy] = {}
_SCHEDULING: dict[str, SchedulingPolicy] = {}

DEFAULT_SELECTION_POLICY = "paper_greedy"
DEFAULT_SCHEDULING_POLICY = "iid_subsets"

# Legacy spellings kept alive by the registry: the stage-1 ``method=``
# argument (submit/run_task) and TaskRequest.scheduler="random".
_LEGACY_METHOD_TO_POLICY = {"greedy": "paper_greedy", "dp": "dp",
                            "random": "random"}
_LEGACY_SCHEDULER_TO_POLICY = {"mkp": "iid_subsets",
                               "random": "random_partition"}


def register_selection_policy(policy):
    """Register a :class:`SelectionPolicy` class or instance under its
    ``name``. Usable as a class decorator; duplicate names raise."""
    inst = policy() if isinstance(policy, type) else policy
    if not isinstance(inst, SelectionPolicy):
        raise TypeError(f"{policy!r} does not implement SelectionPolicy "
                        f"(name, select, select_batch)")
    if inst.name in _SELECTION:
        raise ValueError(f"selection policy {inst.name!r} already registered")
    _SELECTION[inst.name] = inst
    return policy


def register_scheduling_policy(policy):
    """Register a :class:`SchedulingPolicy` class or instance under its
    ``name``. Usable as a class decorator; duplicate names raise."""
    inst = policy() if isinstance(policy, type) else policy
    if not isinstance(inst, SchedulingPolicy):
        raise TypeError(f"{policy!r} does not implement SchedulingPolicy "
                        f"(name, schedule)")
    if inst.name in _SCHEDULING:
        raise ValueError(f"scheduling policy {inst.name!r} already registered")
    _SCHEDULING[inst.name] = inst
    return policy


def selection_policy(name: str) -> SelectionPolicy:
    try:
        return _SELECTION[name]
    except KeyError:
        raise KeyError(f"unknown selection policy {name!r}; registered: "
                       f"{available_selection_policies()}") from None


def scheduling_policy(name: str) -> SchedulingPolicy:
    try:
        return _SCHEDULING[name]
    except KeyError:
        raise KeyError(f"unknown scheduling policy {name!r}; registered: "
                       f"{available_scheduling_policies()}") from None


def available_selection_policies() -> list[str]:
    return sorted(_SELECTION)


def available_scheduling_policies() -> list[str]:
    return sorted(_SCHEDULING)


def resolve_selection_policy(task, method: str | None = None
                             ) -> SelectionPolicy:
    """The task's stage-1 policy. An explicitly passed legacy
    ``method=`` argument (``submit`` / ``run_task`` /
    ``select_pool``) always wins — including ``method=\"greedy\"``;
    otherwise ``task.selection_policy`` decides, falling back to the
    default (``paper_greedy``) when the field is unset (``None``)."""
    if method is not None:
        return selection_policy(_LEGACY_METHOD_TO_POLICY.get(method, method))
    name = getattr(task, "selection_policy", None)
    return selection_policy(name or DEFAULT_SELECTION_POLICY)


def resolve_scheduling_policy(task) -> SchedulingPolicy:
    """The task's stage-2 policy. An explicitly set
    ``task.scheduling_policy`` always wins; when unset (``None``) the
    legacy ``TaskRequest.scheduler`` alias decides (``\"mkp\"`` ->
    ``iid_subsets``, ``\"random\"`` -> ``random_partition``)."""
    name = getattr(task, "scheduling_policy", None)
    if name is None:
        legacy = getattr(task, "scheduler", "mkp")
        name = _LEGACY_SCHEDULER_TO_POLICY.get(legacy, legacy)
    return scheduling_policy(name)


# ---------------------------------------------------------------------------
# Selection policies
# ---------------------------------------------------------------------------

class _BudgetedSelection:
    """Shared stage-1 shape: threshold filter -> feasibility -> a
    knapsack-style solver, via :func:`selection.select_initial_pool`
    (so every budgeted policy shares the Eq. 8d / Eq. 11 handling and
    the infeasibility notes)."""

    name: str
    method: str                       # select_initial_pool solver key

    def select(self, pool, task, rng):
        return select_initial_pool(
            pool, budget=task.budget, n_star=task.n_star,
            thresholds=task.thresholds, method=self.method, rng=rng)

    def select_batch(self, pool, tasks, rngs):
        return [self.select(pool, t, r) for t, r in zip(tasks, rngs)]

    def select_joiners(self, scores, costs, budget_left, rng):
        """Admit mid-period joiners with this policy's own solver
        (thresholds were already applied by the lifecycle; the knapsack
        here is over the leftover budget). Returns candidate positions
        in pick order. The greedy solver runs in skip-unaffordable mode
        — bit-identical to the legacy hard-coded admission loop."""
        rng = rng or np.random.default_rng(0)
        if self.method == "dp":
            res = select_dp(scores, costs, budget_left)
        elif self.method == "random":
            res = select_random(scores, costs, budget_left, rng)
        elif self.method == "score_prop":
            res = select_score_prop(scores, costs, budget_left, rng)
        else:
            res = select_greedy(scores, costs, budget_left,
                                skip_unaffordable=True)
        return np.asarray(res.selected, dtype=np.int64)


@register_selection_policy
class PaperGreedySelection(_BudgetedSelection):
    """The paper's §VI-A score/cost-ratio greedy (the default).

    ``select`` is bit-identical to the pre-registry
    ``FLServiceProvider.select_pool``; ``select_batch`` is the
    pre-registry ``select_pools_batch`` — one vectorized threshold
    sweep + one jit+vmap greedy knapsack for every task at once
    (selected ids come back in pool order; same set/totals/feasibility
    as ``select``, which returns greedy pick order)."""

    name = "paper_greedy"
    method = "greedy"

    def select_batch(self, pool, tasks, rngs):
        if isinstance(pool, ClientPoolState):
            from . import device_pool
            if pool.n >= device_pool.HIERARCHICAL_MIN_N:
                return self._select_batch_hierarchical(pool, tasks)
        budgets = np.array([t.budget for t in tasks], dtype=np.float64)
        valid = np.stack([pool.threshold_mask(t.thresholds) for t in tasks])
        masks, _, _ = engine.greedy_knapsack_batch(
            pool.overall, pool.costs, budgets, valid)
        results: list[SelectionResult] = []
        for t, task in enumerate(tasks):
            n_kept = int(valid[t].sum())
            if n_kept < task.n_star:
                results.append(SelectionResult(
                    [], 0.0, 0.0, feasible=False,
                    note=f"only {n_kept} clients pass thresholds, "
                         f"need {task.n_star}"))
                continue
            sel = masks[t]
            res = SelectionResult(
                pool.client_ids[sel].tolist(),
                float(pool.overall[sel].sum()),
                float(pool.costs[sel].sum()))
            if len(res.selected) < task.n_star:
                res.feasible = False
                floor = pool.budget_floor(task.n_star, valid[t])
                res.note = (f"budget {task.budget} selects only "
                            f"{len(res.selected)} < n*={task.n_star} "
                            f"clients; Eq.(11) floor is {floor:.1f}")
            results.append(res)
        return results

    def _select_batch_hierarchical(self, pool, tasks):
        """Fleet-scale batch path: one device-mirror sync serves every
        task, each task runs the two-level frontier greedy
        (``engine.hierarchical_greedy_knapsack_batch``) instead of a
        host argsort over the full pool. Same ids (pool order), totals
        and feasibility notes as the flat batch path — asserted in
        tests/test_scale_plane.py."""
        from .criteria import overall_score
        outs = engine.hierarchical_greedy_knapsack_batch(
            pool, np.array([t.budget for t in tasks], dtype=np.float64),
            [t.thresholds for t in tasks])
        results: list[SelectionResult] = []
        for task, (rows, _, _, n_kept) in zip(tasks, outs):
            if n_kept < task.n_star:
                results.append(SelectionResult(
                    [], 0.0, 0.0, feasible=False,
                    note=f"only {n_kept} clients pass thresholds, "
                         f"need {task.n_star}"))
                continue
            rows = np.sort(rows)              # batch contract: pool order
            res = SelectionResult(
                pool.client_ids[rows].tolist(),
                float(overall_score(pool.scores[rows]).sum()),
                float(pool.costs[rows].sum()))
            if len(res.selected) < task.n_star:
                res.feasible = False
                floor = pool.budget_floor(
                    task.n_star, pool.threshold_mask(task.thresholds))
                res.note = (f"budget {task.budget} selects only "
                            f"{len(res.selected)} < n*={task.n_star} "
                            f"clients; Eq.(11) floor is {floor:.1f}")
            results.append(res)
        return results


@register_selection_policy
class DPSelection(_BudgetedSelection):
    """Exact 0-1 knapsack (O(n·B) DP) — the paper's optimal reference."""

    name = "dp"
    method = "dp"


@register_selection_policy
class RandomSelection(_BudgetedSelection):
    """The paper's uniform baseline: random clients until the budget is
    short."""

    name = "random"
    method = "random"


@register_selection_policy
class ScoreProportionalSelection(_BudgetedSelection):
    """Score-proportional sampling under the same budget: clients are
    drawn without replacement with probability proportional to their
    overall score (Efraimidis–Spirakis weighted order), with the same
    stop-at-first-unaffordable budget scan as ``random``. The softened
    baseline fairness-aware selection papers compare against — higher
    expected pool quality than uniform, a selection *chance* for every
    thresholded client unlike the deterministic greedy."""

    name = "score_prop"
    method = "score_prop"

    def select_batch(self, pool, tasks, rngs):
        """Batched weighted sampling: per-task Gumbel/Efraimidis–
        Spirakis keys drawn serially (identical rng consumption to
        ``select`` — infeasible tasks draw nothing), then ONE stacked
        ``(T, n)`` argsort + left-fold budget sweep
        (``selection.select_score_prop_batch``). Bit-identical to the
        serial loop per task (asserted in tests/test_scale_plane.py)."""
        if not isinstance(pool, ClientPoolState):
            return super().select_batch(pool, tasks, rngs)
        valid = np.stack([pool.threshold_mask(t.thresholds) for t in tasks])
        n_keeps = valid.sum(axis=1)
        run = [t for t in range(len(tasks)) if n_keeps[t] >= tasks[t].n_star]
        batch = select_score_prop_batch(
            pool.overall, pool.costs,
            np.array([tasks[t].budget for t in run], dtype=np.float64),
            [rngs[t] or np.random.default_rng(0) for t in run],
            valid[run]) if run else []
        results: list[SelectionResult | None] = [None] * len(tasks)
        for t, task in enumerate(tasks):
            if n_keeps[t] < task.n_star:
                results[t] = SelectionResult(
                    [], 0.0, 0.0, feasible=False,
                    note=f"only {int(n_keeps[t])} clients pass thresholds, "
                         f"need {task.n_star}")
        for j, t in enumerate(run):
            picks, ts, tc = batch[j]
            task = tasks[t]
            res = SelectionResult(pool.client_ids[picks].tolist(), ts, tc)
            if len(res.selected) < task.n_star:
                res.feasible = False
                floor = pool.budget_floor(task.n_star, valid[t])
                res.note = (f"budget {task.budget} selects only "
                            f"{len(res.selected)} < n*={task.n_star} "
                            f"clients; Eq.(11) floor is {floor:.1f}")
            results[t] = res
        return results


@register_selection_policy
class StragglerAwareSelection(_BudgetedSelection):
    """Score/cost greedy over *timeout-discounted* scores: each client's
    overall score is scaled by ``1 - penalty * timeout_rate`` before the
    budget greedy, where ``timeout_rate`` is the shared pool's observed
    fraction of dispatches that missed their round's collect close
    (``ClientPoolState.timeout_rate()``, fed by the lifecycle's
    fault-mode bookkeeping — see docs/robustness.md). Chronic stragglers
    price themselves out of stage 1; clients with no dispatch history
    are undiscounted. On pools without timing stats (plain profile
    tuples) this degrades to exactly ``paper_greedy``. Reported
    ``total_score``/``total_cost`` use the *undiscounted* values, so
    results stay comparable across policies."""

    name = "straggler_aware"
    method = "greedy"
    penalty = 1.0       # full discount: a 100%-timeout client scores 0

    def select(self, pool, task, rng):
        if not isinstance(pool, ClientPoolState):
            return super().select(pool, task, rng)
        valid = pool.threshold_mask(task.thresholds)
        n_kept = int(valid.sum())
        if n_kept < task.n_star:
            return SelectionResult(
                [], 0.0, 0.0, feasible=False,
                note=f"only {n_kept} clients pass thresholds, "
                     f"need {task.n_star}")
        rows = np.flatnonzero(valid)
        rate = pool.timeout_rate()[rows]
        eff = pool.overall[rows] * np.maximum(
            1.0 - self.penalty * rate, 0.0)
        picks = np.asarray(select_greedy(
            eff, pool.costs[rows], task.budget,
            skip_unaffordable=True).selected, dtype=np.int64)
        sel = rows[picks]
        res = SelectionResult(
            pool.client_ids[sel].tolist(),
            float(pool.overall[sel].sum()),
            float(pool.costs[sel].sum()))
        if len(res.selected) < task.n_star:
            res.feasible = False
            floor = pool.budget_floor(task.n_star, valid)
            res.note = (f"budget {task.budget} selects only "
                        f"{len(res.selected)} < n*={task.n_star} "
                        f"clients; Eq.(11) floor is {floor:.1f}")
        return res


# ---------------------------------------------------------------------------
# Scheduling policies
# ---------------------------------------------------------------------------

@register_scheduling_policy
class PaperIIDSubsetScheduling:
    """Algorithm 1 *Generate Subsets* (the default): per-class MKPs with
    Nid-improvement and complementary knapsacks — bit-identical to the
    pre-registry ``generate_subsets`` path."""

    name = "iid_subsets"

    def schedule(self, ids, histograms, task, rng, policy_state):
        return generate_subsets(
            (ids, histograms), n=task.subset_size, delta=task.subset_delta,
            x_star=task.x_star, nid_threshold=task.nid_threshold)


@register_scheduling_policy
class RandomPartitionScheduling:
    """The paper's random baseline: shuffle the pool, slice into subsets
    of size n — bit-identical to the legacy ``scheduler=\"random\"``
    path (which it now backs)."""

    name = "random_partition"

    def schedule(self, ids, histograms, task, rng, policy_state):
        hists = {int(c): histograms[i] for i, c in enumerate(ids)}
        return random_subsets(hists, task.subset_size, rng)


@register_scheduling_policy
class FairEMAScheduling:
    """Participation-EMA-penalized scheduling (in the spirit of Shi et
    al., *Fairness-Aware Client Selection for Federated Learning*, 2023,
    and *Emulating Full Participation*, 2024).

    Across periods the policy keeps an exponential moving average of
    each client's per-period participation count in ``policy_state``
    (``fair_ema/ids`` + ``fair_ema/ema`` — checkpointed with the task).
    Each period:

    1. every pooled client gets exactly one *base* slot — subsets are
       consecutive size-``n`` slices of the pool ordered by ascending
       EMA, so chronically under-served clients train in the period's
       *earliest* rounds (they still train even when ``max_rounds`` or a
       ``stop_fn`` truncates the period);
    2. the ``delta`` headroom of every subset is filled with
       *compensation* slots handed to the least-served eligible clients
       (lowest ``EMA + extras-granted-this-period``, capped at
       ``x_star`` total appearances) — over-served clients participate
       exactly once, under-served up to ``x_star`` times, which is what
       drags the long-run participation counts together;
    3. the EMA is updated from the drawn schedule's counts, so the
       compensation pressure decays once counts equalize (and rotates:
       this period's compensated clients are next period's back of the
       queue).

    §VII guarantees hold by construction: step 1 is a partition
    (coverage), step 2 respects ``x_star`` and the ``n + delta`` size
    cap. Deterministic — the penalty order, not the rng, breaks ties —
    so checkpoint/resume reproduces schedules exactly.
    """

    name = "fair_ema"
    alpha = 0.5                       # EMA weight of the newest period

    def schedule(self, ids, histograms, task, rng, policy_state):
        ids = np.asarray(ids, dtype=np.int64)
        H = np.asarray(histograms, dtype=np.float64)
        order0 = np.argsort(ids, kind="stable")   # canonical ascending ids
        ids, H = ids[order0], H[order0]
        P = ids.size
        if P == 0:
            return ScheduleResult([], [], {}, np.zeros(0))
        n = max(1, int(task.subset_size))
        delta = max(0, int(task.subset_delta))
        x_star = max(1, int(task.x_star))
        ema = self._lookup_ema(policy_state, ids)

        order = np.argsort(ema, kind="stable")    # least-served first
        subsets_rows = [order[i: i + n] for i in range(0, P, n)]
        counts = np.ones(P, dtype=np.int64)
        if delta > 0 and x_star > 1 and len(subsets_rows) > 1:
            in_s = np.zeros(P, dtype=bool)
            for j, s in enumerate(subsets_rows):
                room = n + delta - s.size
                if room <= 0:
                    continue
                in_s[:] = False
                in_s[s] = True
                cand = np.flatnonzero(~in_s & (counts < x_star))
                if cand.size == 0:
                    continue
                # least-served first: historical EMA + compensation
                # already granted this period (counts - 1)
                penalty = ema[cand] + (counts[cand] - 1)
                take = cand[np.argsort(penalty, kind="stable")][:room]
                subsets_rows[j] = np.concatenate([s, take])
                counts[take] += 1

        policy_state["fair_ema/ids"] = ids.copy()
        policy_state["fair_ema/ema"] = \
            (1.0 - self.alpha) * ema + self.alpha * counts.astype(np.float64)
        subsets = [np.sort(ids[s]).tolist() for s in subsets_rows]
        nids = [float(nid(H[s].sum(axis=0))) for s in subsets_rows]
        count_map = {int(ids[i]): int(counts[i]) for i in range(P)}
        return ScheduleResult(subsets, nids, count_map, np.zeros(0))

    def _lookup_ema(self, policy_state, ids: np.ndarray) -> np.ndarray:
        """Previous-period EMAs for ``ids`` (0 for clients never seen —
        joiners start with maximal compensation priority). Stored ids
        are ascending (we write them that way), so a searchsorted join
        survives churn in either direction."""
        ema = np.zeros(ids.size, dtype=np.float64)
        prev_ids = policy_state.get("fair_ema/ids")
        if prev_ids is None or np.asarray(prev_ids).size == 0:
            return ema
        prev_ids = np.asarray(prev_ids, dtype=np.int64)
        prev_ema = np.asarray(policy_state["fair_ema/ema"], dtype=np.float64)
        pos = np.searchsorted(prev_ids, ids)
        pos_c = np.minimum(pos, prev_ids.size - 1)
        hit = prev_ids[pos_c] == ids
        ema[hit] = prev_ema[pos_c[hit]]
        return ema


@register_scheduling_policy
class DeadlineAwareScheduling:
    """Timing-reactive scheduling: demote chronic stragglers, tighten
    over-scheduling as observed latency approaches the collect deadline.

    The lifecycle publishes per-task timing observability columns into
    ``policy_state`` every period (docs/workloads.md): ``obs/ids`` /
    ``obs/timeouts`` / ``obs/rounds`` — the reputation tracker's timing
    arrays — plus a rolling ``obs/latency`` window of fault-mode
    simulated round latencies. This policy is the first consumer,
    reacting *mid-task* where ``straggler_aware`` only filters at
    stage 1:

    1. **Demotion.** Pooled clients are ordered by ascending observed
       timeout rate (``timeouts / (rounds + timeouts)``, 0 for clients
       with no history; ties by ascending id) and partitioned into
       consecutive size-``n`` subsets. Chronic-slow members land in the
       period's *last* subsets: under first-k/deadline collect the
       healthy-only subsets close fast, and when ``max_rounds`` or a
       ``stop_fn`` truncates the period it is the straggler subsets
       that go untrained. Every client appears exactly once, so each
       period is a partition — coverage and the ``x_star`` bound hold
       trivially and per-period participation is maximally fair
       (Jain = 1 over scheduled slots).
    2. **Deadline control.** With a ``collect_deadline`` set and
       latency observations present, the policy compares the window's
       p99 against the deadline: at >= ``pressure`` x deadline it
       multiplicatively raises ``task.overschedule_factor`` (capped at
       ``os_cap``) so rounds close at first-k before the deadline
       forces a short count; at < ``relax`` x deadline it decays the
       factor back toward the submitted value (stored in
       ``deadline_aware/base_os`` on first sight). The mutation lives
       on the task's own ``TaskRequest`` — serialized with the task, so
       checkpoint/resume keeps the adapted factor.

    Deterministic given (pool, observability columns) — the rng is
    never drawn — so checkpoint/resume replays schedules exactly.
    """

    name = "deadline_aware"
    pressure = 0.8      # p99 >= pressure * deadline -> tighten
    relax = 0.5         # p99 <  relax * deadline    -> decay toward base
    os_step = 1.25      # multiplicative tighten step
    os_cap = 3.0        # overschedule_factor ceiling

    def schedule(self, ids, histograms, task, rng, policy_state):
        ids = np.asarray(ids, dtype=np.int64)
        H = np.asarray(histograms, dtype=np.float64)
        order0 = np.argsort(ids, kind="stable")   # canonical ascending ids
        ids, H = ids[order0], H[order0]
        P = ids.size
        if P == 0:
            return ScheduleResult([], [], {}, np.zeros(0))
        n = max(1, int(task.subset_size))

        self._adapt_overschedule(task, policy_state)

        rate = self._timeout_rate(policy_state, ids)
        order = np.argsort(rate, kind="stable")   # healthy first; rate
        # ties (incl. the no-history cold start) fall back to ascending
        # id via the stable sort over already-sorted ids
        subsets_rows = [order[i: i + n] for i in range(0, P, n)]
        subsets = [np.sort(ids[s]).tolist() for s in subsets_rows]
        nids = [float(nid(H[s].sum(axis=0))) for s in subsets_rows]
        count_map = {int(c): 1 for c in ids}
        return ScheduleResult(subsets, nids, count_map, np.zeros(0))

    def _timeout_rate(self, policy_state, ids: np.ndarray) -> np.ndarray:
        """Observed timeout rate per pooled client (0 = no history)."""
        obs_ids = policy_state.get("obs/ids")
        if obs_ids is None or np.asarray(obs_ids).size == 0:
            return np.zeros(ids.size, dtype=np.float64)
        obs_ids = np.asarray(obs_ids, dtype=np.int64)
        tf = np.asarray(policy_state.get("obs/timeouts",
                                         np.zeros(obs_ids.size)),
                        dtype=np.float64)
        nr = np.asarray(policy_state.get("obs/rounds",
                                         np.zeros(obs_ids.size)),
                        dtype=np.float64)
        obs_rate = tf / np.maximum(tf + nr, 1.0)
        # tracker ids are insertion-ordered, not sorted: sort for the join
        o = np.argsort(obs_ids, kind="stable")
        obs_ids, obs_rate = obs_ids[o], obs_rate[o]
        rate = np.zeros(ids.size, dtype=np.float64)
        pos = np.searchsorted(obs_ids, ids)
        pos_c = np.minimum(pos, obs_ids.size - 1)
        hit = obs_ids[pos_c] == ids
        rate[hit] = obs_rate[pos_c[hit]]
        return rate

    def _adapt_overschedule(self, task, policy_state) -> None:
        if task.collect_deadline <= 0.0:
            return
        base = policy_state.get("deadline_aware/base_os")
        if base is None:
            base = np.array([max(1.0, float(task.overschedule_factor))])
            policy_state["deadline_aware/base_os"] = base
        lat = policy_state.get("obs/latency")
        if lat is None or np.asarray(lat).size == 0:
            return
        p99 = float(np.percentile(np.asarray(lat, dtype=np.float64), 99))
        factor = max(1.0, float(task.overschedule_factor))
        if p99 >= self.pressure * task.collect_deadline:
            task.overschedule_factor = min(self.os_cap,
                                           factor * self.os_step)
        elif p99 < self.relax * task.collect_deadline:
            task.overschedule_factor = max(float(base[0]),
                                           factor / self.os_step)
