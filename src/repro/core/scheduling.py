"""Stage 2: per-round client scheduling (paper §V-B, §VI-B, Algorithm 1).

``generate_subsets`` implements Algorithm 1 *Generate Subsets*: the pool
is partitioned into subsets — one per round of a scheduling period — by
solving a sequence of MKPs (one knapsack per class label, client
histograms as weights), with the paper's two heuristics:

- **Nid improvement**: if a subset's integrated Nid exceeds a threshold,
  previously-selected clients that still have selection budget (< x*)
  and data in the under-filled classes are added back as *compensation*
  candidates and the subset is re-selected.
- **Complementary knapsacks**: to enforce a minimum subset size (or to
  absorb a too-small tail pool), the already-chosen clients become
  *mandatory*; a second MKP is solved over the other eligible clients
  with capacities reduced by the mandatory fill (Fig. 2).

Guarantees (paper §VII, checked by tests/test_fairness.py):
  every pooled client appears in >= 1 subset; no client appears in more
  than x* subsets; subset sizes lie in [min(n-δ, pool tail), n+δ].
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .criteria import nid
from .mkp import solve_mkp, MKPResult


@dataclasses.dataclass
class ScheduleResult:
    subsets: list[list[int]]            # client ids per round
    nids: list[float]                   # integrated Nid per subset
    counts: dict[int, int]              # participation count per client id
    capacities: np.ndarray              # knapsack capacities used

    @property
    def num_rounds(self) -> int:
        return len(self.subsets)

    def max_nid(self) -> float:
        return max(self.nids) if self.nids else 0.0


def subset_nid(histograms: dict[int, np.ndarray], subset: Sequence[int]) -> float:
    """Nid of the 'integrated' dataset: Nid(sum of member histograms)."""
    if not subset:
        return 1.0
    h = np.sum([histograms[k] for k in subset], axis=0)
    return float(nid(h))


def default_capacities(histograms: dict[int, np.ndarray], n: int) -> np.ndarray:
    """Paper §VIII-C: one capacity for all knapsacks, set so that across
    the T = |S|/n expected rounds the knapsacks can accommodate the data
    of the maximum (most abundant) class in the pool."""
    total = np.sum(list(histograms.values()), axis=0)
    T = max(1, int(np.ceil(len(histograms) / max(n, 1))))
    cap = float(np.ceil(total.max() / T))
    return np.full(total.shape, cap)


def _solve_subset(pool_ids: list[int], histograms, capacities, max_size) -> list[int]:
    """One MKP (Eq. 13): value = |h_k|_1 (client data size), weights = h_k."""
    if not pool_ids:
        return []
    W = np.stack([histograms[k] for k in pool_ids])
    v = W.sum(axis=1)
    res: MKPResult = solve_mkp(v, W, capacities, max_size=max_size)
    return [pool_ids[j] for j in res.selected]


def _underfilled(histograms, subset, capacities, frac: float) -> np.ndarray:
    fill = np.sum([histograms[k] for k in subset], axis=0) if subset else \
        np.zeros_like(capacities)
    return fill < frac * capacities


def _complementary(mandatory: list[int], candidates: list[int], histograms,
                   capacities, max_extra: int) -> list[int]:
    """Complementary-knapsacks trick (Fig. 2): capacities minus the
    mandatory fill become the new knapsack capacities; select from
    ``candidates`` to fill the available space."""
    fill = np.sum([histograms[k] for k in mandatory], axis=0) if mandatory else \
        np.zeros_like(capacities)
    residual = np.maximum(capacities - fill, 0.0)
    extra = _solve_subset(candidates, histograms, residual, max_extra)
    return mandatory + extra


def generate_subsets(
    histograms: dict[int, np.ndarray],
    n: int,
    delta: int,
    x_star: int = 3,
    nid_threshold: float = 0.35,
    fill_frac: float = 0.6,
    capacities: np.ndarray | None = None,
) -> ScheduleResult:
    """Algorithm 1 *Generate Subsets*.

    Args:
      histograms: client_id -> (c,) label histogram (the client pool S).
      n, delta: desired subset size and tolerance (sizes in [n-δ, n+δ]).
      x_star: max times a client may be selected per scheduling period.
      nid_threshold: trigger for the Nid-improvement pass.
      fill_frac: a knapsack is 'under-filled' when below this fraction.
      capacities: optional explicit knapsack capacities (else §VIII-C rule).
    """
    ids = sorted(histograms.keys())
    if not ids:
        return ScheduleResult([], [], {}, np.zeros(0))
    histograms = {k: np.asarray(histograms[k], dtype=np.float64) for k in ids}
    caps = default_capacities(histograms, n) if capacities is None \
        else np.asarray(capacities, dtype=np.float64)

    counts = {k: 0 for k in ids}
    remaining = set(ids)
    subsets: list[list[int]] = []
    min_size, max_size = max(1, n - delta), n + delta

    def eligible_compensation(exclude: set[int]) -> list[int]:
        # previously-selected clients with selection budget left
        return [k for k in ids
                if k not in remaining and k not in exclude and counts[k] < x_star]

    while remaining:
        rem_list = sorted(remaining)
        if len(rem_list) >= min_size:
            subset = _solve_subset(rem_list, histograms, caps, max_size)
            if not subset:
                # no single client fits the capacities: force the smallest
                # remaining client so the algorithm always progresses.
                smallest = min(rem_list, key=lambda k: histograms[k].sum())
                subset = [smallest]
            # -- Nid improvement (compensation clients) --
            if subset_nid(histograms, subset) > nid_threshold:
                under = _underfilled(histograms, subset, caps, fill_frac)
                if np.any(under):
                    comp = [k for k in eligible_compensation(set(subset))
                            if histograms[k][under].sum() > 0]
                    if comp:
                        resel = _solve_subset(sorted(set(rem_list) | set(comp)),
                                              histograms, caps, max_size)
                        # keep the re-selection only if it covers >=1 remaining
                        # client (progress) and improves Nid
                        if (set(resel) & remaining
                                and subset_nid(histograms, resel)
                                < subset_nid(histograms, subset)):
                            subset = resel
            # -- enforce minimum size via mandatory clients + complementary --
            if len(subset) < min_size:
                pool2 = [k for k in rem_list if k not in subset]
                comp = eligible_compensation(set(subset))
                candidates = pool2 + comp
                subset = _complementary(subset, candidates, histograms, caps,
                                        max_extra=max_size - len(subset))
                # if still short, pad greedily with smallest remaining clients
                # (size constraint beats Nid, per the paper's relaxation)
                for k in sorted(pool2, key=lambda k: histograms[k].sum()):
                    if len(subset) >= min_size:
                        break
                    if k not in subset:
                        subset.append(k)
        else:
            # too few clients left: select all + complementary knapsacks
            subset = list(rem_list)
            comp = eligible_compensation(set(subset))
            if len(subset) < max_size and comp:
                subset = _complementary(subset, comp, histograms, caps,
                                        max_extra=max_size - len(subset))

        subsets.append(sorted(subset))
        for k in subset:
            counts[k] += 1
        remaining -= set(subset)

    nids = [subset_nid(histograms, s) for s in subsets]
    return ScheduleResult(subsets, nids, counts, caps)


def random_subsets(histograms: dict[int, np.ndarray], n: int,
                   rng: np.random.Generator) -> ScheduleResult:
    """Baseline: random partition into subsets of size n (paper Fig. 4
    right half / 'random selection' learning curves)."""
    ids = list(histograms.keys())
    rng.shuffle(ids)
    subsets = [sorted(ids[i:i + n]) for i in range(0, len(ids), n)]
    nids = [subset_nid({k: np.asarray(histograms[k], dtype=np.float64)
                        for k in histograms}, s) for s in subsets]
    counts = {k: 1 for k in histograms}
    return ScheduleResult(subsets, nids, counts, np.zeros(0))


def participation_weights(histograms: dict[int, np.ndarray],
                          subset: Sequence[int]) -> np.ndarray:
    """FedAvg p_k = n_k / sum n_k over the round's subset (paper §III)."""
    sizes = np.array([np.sum(histograms[k]) for k in subset], dtype=np.float64)
    return sizes / np.maximum(sizes.sum(), 1e-12)
