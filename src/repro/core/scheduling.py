"""Stage 2: per-round client scheduling (paper §V-B, §VI-B, Algorithm 1).

``generate_subsets`` implements Algorithm 1 *Generate Subsets*: the pool
is partitioned into subsets — one per round of a scheduling period — by
solving a sequence of MKPs (one knapsack per class label, client
histograms as weights), with the paper's two heuristics:

- **Nid improvement**: if a subset's integrated Nid exceeds a threshold,
  previously-selected clients that still have selection budget (< x*)
  and data in the under-filled classes are added back as *compensation*
  candidates and the subset is re-selected.
- **Complementary knapsacks**: to enforce a minimum subset size (or to
  absorb a too-small tail pool), the already-chosen clients become
  *mandatory*; a second MKP is solved over the other eligible clients
  with capacities reduced by the mandatory fill (Fig. 2).

The outer loop is inherently sequential (each round's MKP depends on the
previous rounds' picks), but *all* per-iteration work — integrated-Nid,
under-fill detection, compensation eligibility, candidate assembly —
runs as masked array ops over the pool's stacked ``(n, c)`` histogram
matrix (``ClientPoolState`` columns). The pre-refactor dict/loop
implementation is preserved as ``generate_subsets_legacy``; both produce
identical schedules (tests/test_engine.py) and both are property-checked
by tests/test_fairness.py.

Guarantees (paper §VII, checked by tests/test_fairness.py):
  every pooled client appears in >= 1 subset; no client appears in more
  than x* subsets; subset sizes lie in [min(n-δ, pool tail), n+δ].
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .criteria import nid
from .mkp import solve_mkp, MKPResult
from .pool import ClientPoolState


@dataclasses.dataclass
class ScheduleResult:
    subsets: list[list[int]]            # client ids per round
    nids: list[float]                   # integrated Nid per subset
    counts: dict[int, int]              # participation count per client id
    capacities: np.ndarray              # knapsack capacities used

    @property
    def num_rounds(self) -> int:
        return len(self.subsets)

    def max_nid(self) -> float:
        return max(self.nids) if self.nids else 0.0


def subset_nid(histograms: dict[int, np.ndarray], subset: Sequence[int]) -> float:
    """Nid of the 'integrated' dataset: Nid(sum of member histograms)."""
    if not subset:
        return 1.0
    h = np.sum([histograms[k] for k in subset], axis=0)
    return float(nid(h))


def default_capacities(histograms: dict[int, np.ndarray], n: int) -> np.ndarray:
    """Paper §VIII-C: one capacity for all knapsacks, set so that across
    the T = |S|/n expected rounds the knapsacks can accommodate the data
    of the maximum (most abundant) class in the pool."""
    total = np.sum(list(histograms.values()), axis=0)
    T = max(1, int(np.ceil(len(histograms) / max(n, 1))))
    cap = float(np.ceil(total.max() / T))
    return np.full(total.shape, cap)


def default_capacities_arrays(H: np.ndarray, n: int) -> np.ndarray:
    """Array form of :func:`default_capacities` over a stacked (P, c)
    histogram matrix."""
    total = H.sum(axis=0)
    T = max(1, int(np.ceil(H.shape[0] / max(n, 1))))
    cap = float(np.ceil(total.max() / T))
    return np.full(total.shape, cap)


# ---------------------------------------------------------------------------
# Array-native Algorithm 1 (the production path)
# ---------------------------------------------------------------------------

def _as_pool_arrays(histograms) -> tuple[np.ndarray, np.ndarray]:
    """Adapter: dict / ClientPoolState / (ids, H) -> (ids, H) arrays with
    rows in ascending-id order (the algorithm's canonical order)."""
    if isinstance(histograms, ClientPoolState):
        order = np.argsort(histograms.client_ids, kind="stable")
        return histograms.client_ids[order], histograms.histograms[order]
    if isinstance(histograms, tuple):
        ids, H = histograms
        ids = np.asarray(ids, dtype=np.int64)
        H = np.asarray(H, dtype=np.float64)
        order = np.argsort(ids, kind="stable")
        return ids[order], H[order]
    ids = np.array(sorted(histograms.keys()), dtype=np.int64)
    if ids.size == 0:
        return ids, np.zeros((0, 1))
    H = np.stack([np.asarray(histograms[int(k)], dtype=np.float64)
                  for k in ids])
    return ids, H


def _solve_rows(rows: np.ndarray, H: np.ndarray, capacities: np.ndarray,
                max_size: int, backend: str) -> np.ndarray:
    """One MKP (Eq. 13) over the candidate ``rows``: value = |h|_1,
    weights = h. Returns the chosen rows (subset of ``rows``)."""
    if rows.size == 0:
        return rows
    W = H[rows]
    v = W.sum(axis=1)
    res: MKPResult = solve_mkp(v, W, capacities, max_size=max_size,
                               backend=backend)
    return rows[np.asarray(res.selected, dtype=np.int64)] if res.selected \
        else rows[:0]


def _complementary_rows(mandatory: np.ndarray, candidates: np.ndarray,
                        H: np.ndarray, capacities: np.ndarray,
                        max_extra: int, backend: str) -> np.ndarray:
    """Complementary-knapsacks trick (Fig. 2): capacities minus the
    mandatory fill become the new capacities; fill from ``candidates``."""
    fill = H[mandatory].sum(axis=0) if mandatory.size else \
        np.zeros_like(capacities)
    residual = np.maximum(capacities - fill, 0.0)
    extra = _solve_rows(candidates, H, residual, max_extra, backend)
    return np.concatenate([mandatory, extra])


def generate_subsets(
    histograms: Mapping[int, np.ndarray] | ClientPoolState |
                tuple[np.ndarray, np.ndarray],
    n: int,
    delta: int,
    x_star: int = 3,
    nid_threshold: float = 0.35,
    fill_frac: float = 0.6,
    capacities: np.ndarray | None = None,
    backend: str = "numpy",
) -> ScheduleResult:
    """Algorithm 1 *Generate Subsets*, array-native.

    Args:
      histograms: the client pool S — a ``ClientPoolState``, an
        ``(ids, H)`` array pair, or the legacy ``client_id -> (c,)``
        dict (adapted to arrays once).
      n, delta: desired subset size and tolerance (sizes in [n-δ, n+δ]).
      x_star: max times a client may be selected per scheduling period.
      nid_threshold: trigger for the Nid-improvement pass.
      fill_frac: a knapsack is 'under-filled' when below this fraction.
      capacities: optional explicit knapsack capacities (else §VIII-C rule).
      backend: MKP backend ("numpy" greedy+LS, "jax" jit/Pallas greedy).

    Produces schedules identical to :func:`generate_subsets_legacy`
    (with the default backend); only the per-iteration bookkeeping is
    vectorized.
    """
    ids, H = _as_pool_arrays(histograms)
    P = ids.size
    if P == 0:
        return ScheduleResult([], [], {}, np.zeros(0))
    caps = default_capacities_arrays(H, n) if capacities is None \
        else np.asarray(capacities, dtype=np.float64)
    sizes = H.sum(axis=1)

    counts = np.zeros(P, dtype=np.int64)
    remaining = np.ones(P, dtype=bool)
    subsets_rows: list[np.ndarray] = []
    min_size, max_size = max(1, n - delta), n + delta

    def eligible_compensation(exclude: np.ndarray) -> np.ndarray:
        # previously-selected rows with selection budget left
        return ~remaining & ~exclude & (counts < x_star)

    while remaining.any():
        rem_rows = np.flatnonzero(remaining)        # ascending id order
        if rem_rows.size >= min_size:
            sel = _solve_rows(rem_rows, H, caps, max_size, backend)
            if sel.size == 0:
                # no single client fits the capacities: force the smallest
                # remaining client so the algorithm always progresses.
                sel = rem_rows[[int(np.argmin(sizes[rem_rows]))]]
            # -- Nid improvement (compensation clients) --
            fill = H[sel].sum(axis=0)
            sel_nid = float(nid(fill))
            if sel_nid > nid_threshold:
                under = fill < fill_frac * caps
                if under.any():
                    in_sel = np.zeros(P, dtype=bool)
                    in_sel[sel] = True
                    comp = eligible_compensation(in_sel) & \
                        (H[:, under].sum(axis=1) > 0)
                    if comp.any():
                        cand = np.flatnonzero(remaining | comp)
                        resel = _solve_rows(cand, H, caps, max_size, backend)
                        # keep the re-selection only if it covers >=1
                        # remaining client (progress) and improves Nid
                        if (remaining[resel].any()
                                and float(nid(H[resel].sum(axis=0))) < sel_nid):
                            sel = resel
            # -- enforce minimum size via mandatory clients + complementary --
            if sel.size < min_size:
                in_sel = np.zeros(P, dtype=bool)
                in_sel[sel] = True
                pool2 = rem_rows[~in_sel[rem_rows]]
                comp = np.flatnonzero(eligible_compensation(in_sel))
                candidates = np.concatenate([pool2, comp])
                sel = _complementary_rows(sel, candidates, H, caps,
                                          max_size - sel.size, backend)
                # if still short, pad greedily with smallest remaining
                # clients (size constraint beats Nid, per the paper)
                if sel.size < min_size:
                    in_sel = np.zeros(P, dtype=bool)
                    in_sel[sel] = True
                    pad = pool2[~in_sel[pool2]]
                    pad = pad[np.argsort(sizes[pad], kind="stable")]
                    need = min_size - sel.size
                    sel = np.concatenate([sel, pad[:need]])
        else:
            # too few clients left: select all + complementary knapsacks
            sel = rem_rows
            in_sel = np.zeros(P, dtype=bool)
            in_sel[sel] = True
            comp = np.flatnonzero(eligible_compensation(in_sel))
            if sel.size < max_size and comp.size:
                sel = _complementary_rows(sel, comp, H, caps,
                                          max_size - sel.size, backend)

        subsets_rows.append(np.sort(sel))
        counts[sel] += 1
        remaining[sel] = False

    nids = [float(nid(H[s].sum(axis=0))) if s.size else 1.0
            for s in subsets_rows]
    subsets = [ids[s].tolist() for s in subsets_rows]
    count_map = {int(ids[i]): int(counts[i]) for i in range(P)}
    return ScheduleResult(subsets, nids, count_map, caps)


# ---------------------------------------------------------------------------
# Legacy dict/loop implementation (reference for equivalence + fairness)
# ---------------------------------------------------------------------------

def _solve_subset(pool_ids: list[int], histograms, capacities, max_size) -> list[int]:
    """One MKP (Eq. 13): value = |h_k|_1 (client data size), weights = h_k."""
    if not pool_ids:
        return []
    W = np.stack([histograms[k] for k in pool_ids])
    v = W.sum(axis=1)
    res: MKPResult = solve_mkp(v, W, capacities, max_size=max_size)
    return [pool_ids[j] for j in res.selected]


def _underfilled(histograms, subset, capacities, frac: float) -> np.ndarray:
    fill = np.sum([histograms[k] for k in subset], axis=0) if subset else \
        np.zeros_like(capacities)
    return fill < frac * capacities


def _complementary(mandatory: list[int], candidates: list[int], histograms,
                   capacities, max_extra: int) -> list[int]:
    """Complementary-knapsacks trick (Fig. 2): capacities minus the
    mandatory fill become the new knapsack capacities; select from
    ``candidates`` to fill the available space."""
    fill = np.sum([histograms[k] for k in mandatory], axis=0) if mandatory else \
        np.zeros_like(capacities)
    residual = np.maximum(capacities - fill, 0.0)
    extra = _solve_subset(candidates, histograms, residual, max_extra)
    return mandatory + extra


def generate_subsets_legacy(
    histograms: dict[int, np.ndarray],
    n: int,
    delta: int,
    x_star: int = 3,
    nid_threshold: float = 0.35,
    fill_frac: float = 0.6,
    capacities: np.ndarray | None = None,
) -> ScheduleResult:
    """Pre-refactor Algorithm 1 over ``dict`` histograms and Python sets.

    Kept as the reference the array-native :func:`generate_subsets` is
    tested against; not a production path.
    """
    ids = sorted(histograms.keys())
    if not ids:
        return ScheduleResult([], [], {}, np.zeros(0))
    histograms = {k: np.asarray(histograms[k], dtype=np.float64) for k in ids}
    caps = default_capacities(histograms, n) if capacities is None \
        else np.asarray(capacities, dtype=np.float64)

    counts = {k: 0 for k in ids}
    remaining = set(ids)
    subsets: list[list[int]] = []
    min_size, max_size = max(1, n - delta), n + delta

    def eligible_compensation(exclude: set[int]) -> list[int]:
        # previously-selected clients with selection budget left
        return [k for k in ids
                if k not in remaining and k not in exclude and counts[k] < x_star]

    while remaining:
        rem_list = sorted(remaining)
        if len(rem_list) >= min_size:
            subset = _solve_subset(rem_list, histograms, caps, max_size)
            if not subset:
                # no single client fits the capacities: force the smallest
                # remaining client so the algorithm always progresses.
                smallest = min(rem_list, key=lambda k: histograms[k].sum())
                subset = [smallest]
            # -- Nid improvement (compensation clients) --
            if subset_nid(histograms, subset) > nid_threshold:
                under = _underfilled(histograms, subset, caps, fill_frac)
                if np.any(under):
                    comp = [k for k in eligible_compensation(set(subset))
                            if histograms[k][under].sum() > 0]
                    if comp:
                        resel = _solve_subset(sorted(set(rem_list) | set(comp)),
                                              histograms, caps, max_size)
                        # keep the re-selection only if it covers >=1 remaining
                        # client (progress) and improves Nid
                        if (set(resel) & remaining
                                and subset_nid(histograms, resel)
                                < subset_nid(histograms, subset)):
                            subset = resel
            # -- enforce minimum size via mandatory clients + complementary --
            if len(subset) < min_size:
                pool2 = [k for k in rem_list if k not in subset]
                comp = eligible_compensation(set(subset))
                candidates = pool2 + comp
                subset = _complementary(subset, candidates, histograms, caps,
                                        max_extra=max_size - len(subset))
                # if still short, pad greedily with smallest remaining clients
                # (size constraint beats Nid, per the paper's relaxation)
                for k in sorted(pool2, key=lambda k: histograms[k].sum()):
                    if len(subset) >= min_size:
                        break
                    if k not in subset:
                        subset.append(k)
        else:
            # too few clients left: select all + complementary knapsacks
            subset = list(rem_list)
            comp = eligible_compensation(set(subset))
            if len(subset) < max_size and comp:
                subset = _complementary(subset, comp, histograms, caps,
                                        max_extra=max_size - len(subset))

        subsets.append(sorted(subset))
        for k in subset:
            counts[k] += 1
        remaining -= set(subset)

    nids = [subset_nid(histograms, s) for s in subsets]
    return ScheduleResult(subsets, nids, counts, caps)


def random_subsets(histograms: dict[int, np.ndarray], n: int,
                   rng: np.random.Generator) -> ScheduleResult:
    """Baseline: random partition into subsets of size n (paper Fig. 4
    right half / 'random selection' learning curves)."""
    ids = list(histograms.keys())
    rng.shuffle(ids)
    subsets = [sorted(ids[i:i + n]) for i in range(0, len(ids), n)]
    nids = [subset_nid({k: np.asarray(histograms[k], dtype=np.float64)
                        for k in histograms}, s) for s in subsets]
    counts = {k: 1 for k in histograms}
    return ScheduleResult(subsets, nids, counts, np.zeros(0))


def participation_weights(histograms: dict[int, np.ndarray],
                          subset: Sequence[int]) -> np.ndarray:
    """FedAvg p_k = n_k / sum n_k over the round's subset (paper §III)."""
    sizes = np.array([np.sum(histograms[k]) for k in subset], dtype=np.float64)
    return sizes / np.maximum(sizes.sum(), 1e-12)
