"""Array-native client pool state (the control plane's internal form).

``ClientPoolState`` is a struct-of-arrays view of a registered client
population: criterion scores ``(n, NUM_CRITERIA)``, label histograms
``(n, c)``, costs ``(n,)``, plus the mutable service-side state
(active mask, participation counts, reputation). It replaces
``list[ClientProfile]`` / ``dict[int, np.ndarray]`` as the internal
representation across selection, scheduling and the service loop, so the
hot paths are masked array ops instead of per-client Python loops.

The dataclass API stays: ``from_profiles`` / ``to_profiles`` are the
thin adapters, so anything built on ``ClientProfile`` keeps working.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .criteria import (NUM_CRITERIA, THRESHOLDED, ClientProfile,
                       linear_cost, nid, overall_score)


@dataclasses.dataclass
class ClientPoolState:
    """Struct-of-arrays snapshot of a client pool.

    All arrays share the leading client axis ``n``; row ``i`` describes
    the client with id ``client_ids[i]``. Ids need not be contiguous but
    must be unique.
    """

    client_ids: np.ndarray        # (n,) int64 — external client ids
    scores: np.ndarray            # (n, NUM_CRITERIA) float64 in (0,1)
    histograms: np.ndarray        # (n, c) float64 label histograms
    costs: np.ndarray             # (n,) float64 per-round/task price
    active: np.ndarray = None     # (n,) bool — available for selection
    participation: np.ndarray = None  # (n,) int64 — selections this period
    reputation: np.ndarray = None     # (n,) float64 — running s_rep

    _overall: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _pos: dict | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        self.client_ids = np.asarray(self.client_ids, dtype=np.int64)
        self.scores = np.asarray(self.scores, dtype=np.float64)
        self.histograms = np.asarray(self.histograms, dtype=np.float64)
        self.costs = np.asarray(self.costs, dtype=np.float64)
        n = self.client_ids.shape[0]
        if self.scores.shape != (n, NUM_CRITERIA):
            raise ValueError(f"scores must be ({n}, {NUM_CRITERIA}), "
                             f"got {self.scores.shape}")
        if self.histograms.ndim != 2 or self.histograms.shape[0] != n:
            raise ValueError("histograms must be (n, c)")
        if self.costs.shape != (n,):
            raise ValueError("costs must be (n,)")
        if len(np.unique(self.client_ids)) != n:
            raise ValueError("client ids must be unique")
        if self.active is None:
            self.active = np.ones(n, dtype=bool)
        else:
            self.active = np.asarray(self.active, dtype=bool)
        if self.participation is None:
            self.participation = np.zeros(n, dtype=np.int64)
        else:
            self.participation = np.asarray(self.participation, dtype=np.int64)
        if self.reputation is None:
            self.reputation = np.zeros(n, dtype=np.float64)
        else:
            self.reputation = np.asarray(self.reputation, dtype=np.float64)

    # -- shape ---------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.client_ids.shape[0])

    @property
    def num_classes(self) -> int:
        return int(self.histograms.shape[1])

    def __len__(self) -> int:
        return self.n

    # -- derived quantities (vectorized) -------------------------------------
    @property
    def overall(self) -> np.ndarray:
        """(n,) Eq. (6) overall scores, computed once and cached."""
        if self._overall is None:
            self._overall = overall_score(self.scores)
        return self._overall

    def data_sizes(self) -> np.ndarray:
        return self.histograms.sum(axis=1)

    def nids(self) -> np.ndarray:
        return nid(self.histograms)

    def threshold_mask(self, thresholds: np.ndarray | None) -> np.ndarray:
        """Eq. (8d) per-client boolean mask over the thresholded criteria.

        Pure criteria filter — like the legacy ``threshold_filter`` it
        does NOT consult ``active``; availability is a scheduling-period
        concern (paper §V-B step 4). Intersect with ``self.active``
        explicitly where that semantics is wanted.
        """
        if thresholds is None:
            return np.ones(self.n, dtype=bool)
        th = np.asarray(thresholds, dtype=np.float64)[: len(THRESHOLDED)]
        return np.all(self.scores[:, list(THRESHOLDED)] >= th, axis=1)

    def budget_floor(self, n_star: int,
                     mask: np.ndarray | None = None) -> float:
        """Eq. (11): sum of the top-``n_star`` costs among ``mask``."""
        c = self.costs if mask is None else self.costs[mask]
        if c.size == 0 or n_star <= 0:
            return 0.0
        k = min(int(n_star), c.size)
        return float(np.sort(c)[-k:].sum())

    # -- id <-> position -----------------------------------------------------
    def positions(self, ids: Sequence[int] | np.ndarray) -> np.ndarray:
        """Row positions of external ``ids`` (vectorized lookup)."""
        if self._pos is None:
            self._pos = {int(c): i for i, c in enumerate(self.client_ids)}
        return np.fromiter((self._pos[int(c)] for c in ids), dtype=np.int64,
                           count=len(ids))

    def subset(self, index: np.ndarray) -> "ClientPoolState":
        """A new pool state restricted to ``index`` (bool mask or rows)."""
        idx = np.asarray(index)
        return ClientPoolState(
            client_ids=self.client_ids[idx],
            scores=self.scores[idx],
            histograms=self.histograms[idx],
            costs=self.costs[idx],
            active=self.active[idx],
            participation=self.participation[idx],
            reputation=self.reputation[idx],
        )

    # -- adapters (dataclass API compatibility) ------------------------------
    @classmethod
    def from_profiles(cls, profiles: Sequence[ClientProfile]) -> "ClientPoolState":
        profiles = list(profiles)
        if not profiles:
            return cls(np.zeros(0, np.int64), np.zeros((0, NUM_CRITERIA)),
                       np.zeros((0, 1)), np.zeros(0))
        return cls(
            client_ids=np.array([p.client_id for p in profiles], np.int64),
            scores=np.stack([p.scores for p in profiles]),
            histograms=np.stack([p.histogram for p in profiles]),
            costs=np.array([p.cost for p in profiles], np.float64),
            active=np.array([p.available for p in profiles], bool),
        )

    def to_profiles(self) -> list[ClientProfile]:
        return [
            ClientProfile(
                client_id=int(self.client_ids[i]),
                scores=self.scores[i].copy(),
                histogram=self.histograms[i].copy(),
                cost=float(self.costs[i]),
                available=bool(self.active[i]),
            )
            for i in range(self.n)
        ]

    @classmethod
    def from_histograms(cls, histograms: Mapping[int, np.ndarray]) -> "ClientPoolState":
        """Adapter for the scheduler's legacy ``dict[id, hist]`` input.

        Scores are zero placeholders; rows follow ascending client id (the
        legacy scheduler's canonical order).
        """
        ids = np.array(sorted(histograms.keys()), dtype=np.int64)
        if ids.size == 0:
            return cls(ids, np.zeros((0, NUM_CRITERIA)), np.zeros((0, 1)),
                       np.zeros(0))
        H = np.stack([np.asarray(histograms[int(k)], dtype=np.float64)
                      for k in ids])
        return cls(ids, np.zeros((ids.size, NUM_CRITERIA)), H,
                   np.zeros(ids.size))

    # -- constructors --------------------------------------------------------
    @classmethod
    def random(cls, n_clients: int, n_classes: int, rng: np.random.Generator,
               cost_a: float = 2.0, cost_b: float = 5.0,
               integer_cost: bool = True) -> "ClientPoolState":
        """Vectorized virtual-client pool (paper §VIII-A), the array-native
        counterpart of ``criteria.random_profiles`` — O(n·c) with no Python
        loop, so 100k+ client pools build in milliseconds.

        Draws differ from ``random_profiles`` (which samples per client);
        marginal distributions match: per client a uniform label-count
        k ~ U{1..c}, k distinct labels, counts ~ U{10..199}.
        """
        from .criteria import (CRITERIA, data_dist_score,  # no import cycle
                               random_histograms)
        scores = rng.uniform(0.0, 1.0, size=(n_clients, NUM_CRITERIA))
        hists = random_histograms(n_clients, n_classes, rng)
        sizes = hists.sum(axis=1)
        scores[:, CRITERIA.index("data_size")] = sizes / max(sizes.max(), 1e-12)
        scores[:, CRITERIA.index("data_dist")] = data_dist_score(hists)
        costs = linear_cost(overall_score(scores), cost_a, cost_b,
                            integer=integer_cost)
        return cls(np.arange(n_clients, dtype=np.int64), scores, hists, costs)
