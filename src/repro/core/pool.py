"""Array-native client pool state (the control plane's internal form).

``ClientPoolState`` is a struct-of-arrays view of a registered client
population: criterion scores ``(n, NUM_CRITERIA)``, label histograms
``(n, c)``, costs ``(n,)``, plus the mutable service-side state
(active mask, participation counts, reputation). It replaces
``list[ClientProfile]`` / ``dict[int, np.ndarray]`` as the internal
representation across selection, scheduling and the service loop, so the
hot paths are masked array ops instead of per-client Python loops.

The pool is *churnable* (paper §III: a shared, changing client
population serving many tasks): :meth:`register` appends clients into
capacity-doubled buffers (amortized O(1), the public arrays are views),
and :meth:`deregister` tombstones rows in place — positions stay stable
for in-flight ``TaskState`` cursors, while the ``registered`` mask
excludes departed clients from selection, ``positions`` lookups, and the
profile views. Every mutation bumps :attr:`version`, which consumers
(``FLServiceProvider.registry``, cached id maps) use for invalidation.

The dataclass API stays: ``from_profiles`` / ``to_profiles`` are the
thin adapters, so anything built on ``ClientProfile`` keeps working.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .criteria import (NUM_CRITERIA, THRESHOLDED, ClientProfile,
                       linear_cost, nid, overall_score)


@dataclasses.dataclass
class ClientPoolState:
    """Struct-of-arrays snapshot of a client pool.

    All arrays share the leading client axis ``n``; row ``i`` describes
    the client with id ``client_ids[i]``. Ids need not be contiguous but
    must be unique.
    """

    client_ids: np.ndarray        # (n,) int64 — external client ids
    scores: np.ndarray            # (n, NUM_CRITERIA) float64 in (0,1)
    histograms: np.ndarray        # (n, c) float64 label histograms
    costs: np.ndarray             # (n,) float64 per-round/task price
    active: np.ndarray = None     # (n,) bool — available for selection
    participation: np.ndarray = None  # (n,) int64 — selections this period
    reputation: np.ndarray = None     # (n,) float64 — running s_rep
    registered: np.ndarray = None     # (n,) bool — False = churned out
    reg_seq: np.ndarray = None        # (n,) int64 — registration event
    # stamp (see reg_counter): lets in-flight tasks spot rows registered
    # (or reactivated by a rejoin) after their own watermark

    _overall: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _pos: dict | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        self.client_ids = np.asarray(self.client_ids, dtype=np.int64)
        self.scores = np.asarray(self.scores, dtype=np.float64)
        self.histograms = np.asarray(self.histograms, dtype=np.float64)
        self.costs = np.asarray(self.costs, dtype=np.float64)
        n = self.client_ids.shape[0]
        if self.scores.shape != (n, NUM_CRITERIA):
            raise ValueError(f"scores must be ({n}, {NUM_CRITERIA}), "
                             f"got {self.scores.shape}")
        if self.histograms.ndim != 2 or self.histograms.shape[0] != n:
            raise ValueError("histograms must be (n, c)")
        if self.costs.shape != (n,):
            raise ValueError("costs must be (n,)")
        if len(np.unique(self.client_ids)) != n:
            raise ValueError("client ids must be unique")
        if self.active is None:
            self.active = np.ones(n, dtype=bool)
        else:
            self.active = np.asarray(self.active, dtype=bool)
        if self.participation is None:
            self.participation = np.zeros(n, dtype=np.int64)
        else:
            self.participation = np.asarray(self.participation, dtype=np.int64)
        if self.reputation is None:
            self.reputation = np.zeros(n, dtype=np.float64)
        else:
            self.reputation = np.asarray(self.reputation, dtype=np.float64)
        if self.registered is None:
            self.registered = np.ones(n, dtype=bool)
        else:
            self.registered = np.asarray(self.registered, dtype=bool)
        if self.reg_seq is None:
            self.reg_seq = np.zeros(n, dtype=np.int64)
        else:
            self.reg_seq = np.asarray(self.reg_seq, dtype=np.int64)
        self.reg_counter = int(self.reg_seq.max()) if n else 0
        self._version = 0
        self._capacity = n            # buffer rows behind the public views
        self._bufs = None             # lazily adopted on first register()
        self._pos_all = None          # id -> row incl. tombstones
        self._sizes = None            # cached data_sizes()
        self._known = None            # id universe (incl. tombstones)
        self._mutlog: list = []       # (version, rows) per churn event —
        # the dirty-region protocol consumed by DevicePoolState.sync
        self._mutlog_floor = 0        # oldest version still replayable
        self._mirror = None           # cached device mirror (lazy)
        self._pins: dict = {}         # client id -> in-flight refcount
        # (PendingChunk schedules pin their members; see pin/unpin)
        self._deferred_dereg: set = set()   # pinned ids whose deregister
        # is deferred until the last unpin
        # runtime timing stats (not serialized, not in _FIELDS): per-row
        # dispatch and collect-timeout tallies fed by the lifecycle's
        # fault-mode dispatch; selection policies read timeout_rate()
        self.timeout_counts = np.zeros(n, dtype=np.int64)
        self.dispatch_counts = np.zeros(n, dtype=np.int64)

    _FIELDS = ("client_ids", "scores", "histograms", "costs", "active",
               "participation", "reputation", "registered", "reg_seq")

    # -- shape ---------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.client_ids.shape[0])

    @property
    def n_registered(self) -> int:
        return int(self.registered.sum())

    @property
    def version(self) -> int:
        """Monotone mutation counter: bumped by :meth:`register` /
        :meth:`deregister`. Consumers caching derived views (e.g. the
        provider's profile registry) compare against it to invalidate."""
        return self._version

    @property
    def num_classes(self) -> int:
        return int(self.histograms.shape[1])

    def __len__(self) -> int:
        return self.n

    # -- derived quantities (vectorized) -------------------------------------
    @property
    def overall(self) -> np.ndarray:
        """(n,) Eq. (6) overall scores, computed once and cached."""
        if self._overall is None:
            self._overall = overall_score(self.scores)
        return self._overall

    def data_sizes(self) -> np.ndarray:
        """(n,) per-client data sizes, cached until the pool mutates
        (the round loop reads this every chunk dispatch)."""
        if self._sizes is None:
            self._sizes = self.histograms.sum(axis=1)
        return self._sizes

    def nids(self) -> np.ndarray:
        return nid(self.histograms)

    def threshold_mask(self, thresholds: np.ndarray | None) -> np.ndarray:
        """Eq. (8d) per-client boolean mask over the thresholded criteria.

        Pure criteria filter — like the legacy ``threshold_filter`` it
        does NOT consult ``active``; availability is a scheduling-period
        concern (paper §V-B step 4). Intersect with ``self.active``
        explicitly where that semantics is wanted. Clients deregistered
        by churn (``registered == False``) no longer exist to the
        service, so they ARE excluded here.
        """
        if thresholds is None:
            return self.registered.copy()
        th = np.asarray(thresholds, dtype=np.float64)[: len(THRESHOLDED)]
        return np.all(self.scores[:, list(THRESHOLDED)] >= th, axis=1) \
            & self.registered

    def budget_floor(self, n_star: int,
                     mask: np.ndarray | None = None) -> float:
        """Eq. (11): sum of the top-``n_star`` costs among ``mask``."""
        c = self.costs[self.registered] if mask is None else self.costs[mask]
        if c.size == 0 or n_star <= 0:
            return 0.0
        k = min(int(n_star), c.size)
        return float(np.sort(c)[-k:].sum())

    # -- id <-> position -----------------------------------------------------
    def _pos_map(self) -> dict:
        if self._pos is None:
            self._pos = {int(c): i for i, c in enumerate(self.client_ids)
                         if self.registered[i]}
        return self._pos

    def positions(self, ids: Sequence[int] | np.ndarray,
                  include_deregistered: bool = False) -> np.ndarray:
        """Row positions of external ``ids`` (vectorized lookup).

        Raises ``KeyError`` for any id that is not currently registered
        — either never seen, or removed by churn (``deregister``). The
        pre-churn behavior of silently mapping a stale id would let a
        churned-out client index garbage rows downstream.

        ``include_deregistered=True`` also resolves tombstoned rows —
        the mid-period case: a schedule drawn while a client was live
        keeps training against its (still resident) row until the next
        period checkpoint drops it.
        """
        pos = self._pos_map()
        if include_deregistered and len(pos) < self.n:
            if self._pos_all is None:
                self._pos_all = {int(c): i
                                 for i, c in enumerate(self.client_ids)}
            pos = self._pos_all
        try:
            return np.fromiter((pos[int(c)] for c in ids),
                               dtype=np.int64, count=len(ids))
        except KeyError as e:
            raise KeyError(
                f"client id {e.args[0]} is not registered in the pool "
                f"(unknown, or removed by deregister)") from None

    def is_registered(self, ids: Sequence[int] | np.ndarray) -> np.ndarray:
        """(len(ids),) bool: which external ids are currently registered
        (amortized via the cached id->row map)."""
        pos = self._pos_map()
        return np.array([int(c) in pos for c in ids], dtype=bool)

    # -- churn (register / deregister) ---------------------------------------
    _MUTLOG_MAX = 65536               # churn events retained for replay

    def _bump_version(self) -> None:
        self._version += 1

    def _log_mutation(self, rows: np.ndarray) -> None:
        """Record the rows touched by the mutation that produced the
        current ``version`` (the dirty-region log). Device mirrors
        replay entries newer than their synced version instead of
        re-staging whole buffers; once the log overflows, the floor
        rises and laggards fall back to a full restage."""
        self._mutlog.append((self._version, np.asarray(rows, np.int64)))
        if len(self._mutlog) > self._MUTLOG_MAX:
            drop = len(self._mutlog) - self._MUTLOG_MAX
            self._mutlog_floor = self._mutlog[drop - 1][0]
            del self._mutlog[:drop]

    def dirty_rows_since(self, version: int) -> np.ndarray | None:
        """Unique rows mutated after ``version`` (ascending), or
        ``None`` when the log no longer reaches back that far (the
        caller must re-stage from scratch). ``version`` equal to the
        current :attr:`version` returns an empty array."""
        if version < self._mutlog_floor:
            return None
        rows = [r for v, r in self._mutlog if v > version]
        if not rows:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate(rows))

    def device_mirror(self, shard_cap: int | None = None,
                      include_histograms: bool = False):
        """The pool's cached :class:`~repro.core.device_pool.
        DevicePoolState` (sharded jnp arrays), synced to the current
        version via the dirty-region log — thousands of churn events
        per sweep update row slices in place instead of re-staging the
        buffers. Rebuilt only when the requested geometry changes."""
        from .device_pool import DevicePoolState   # no import cycle
        m = self._mirror
        if (m is None
                or (shard_cap is not None and m.shard_cap != shard_cap)
                or (include_histograms and m.histograms is None)):
            m = DevicePoolState.from_host(
                self, shard_cap=shard_cap,
                include_histograms=include_histograms)
            self._mirror = m
        else:
            m.sync(self)
        return m

    def _ensure_capacity(self, extra: int) -> None:
        """Grow the backing buffers (doubling) so ``extra`` more rows fit;
        the public arrays stay views into them."""
        if self._bufs is None:
            self._bufs = {f: getattr(self, f) for f in self._FIELDS}
            self._capacity = self.n
        need = self.n + extra
        if need <= self._capacity:
            return
        cap = max(need, 2 * self._capacity, 4)
        n = self.n
        for f in self._FIELDS:
            a = getattr(self, f)
            buf = np.zeros((cap,) + a.shape[1:], dtype=a.dtype)
            buf[:n] = a
            self._bufs[f] = buf
        self._capacity = cap

    def register(self, profiles: "ClientProfile | Sequence[ClientProfile]"
                 ) -> np.ndarray:
        """Append newly-joined clients (dataclass adapter over
        :meth:`register_arrays`). Returns the new row positions."""
        if isinstance(profiles, ClientProfile):
            profiles = [profiles]
        add = ClientPoolState.from_profiles(profiles)
        return self.register_arrays(add.client_ids, add.scores,
                                    add.histograms, add.costs, add.active)

    def register_arrays(self, client_ids, scores, histograms, costs,
                        active=None) -> np.ndarray:
        """Masked append of ``k`` clients with amortized capacity doubling.

        The public arrays become views of larger buffers, so steady-state
        registration is O(k); cached views (``positions`` map, overall
        scores, provider registries via :attr:`version`) are invalidated.
        A previously deregistered id may rejoin: its tombstoned row is
        reactivated in place with the new profile (positions stay
        stable). Cached id->row maps are updated incrementally (rows
        never move), so churn events stay O(k); the derived-score caches
        and the ``version`` counter are refreshed. Returns the row
        positions of the registered clients, in input order.
        """
        ids = np.asarray(client_ids, dtype=np.int64).reshape(-1)
        k = ids.size
        if k == 0:
            return np.zeros(0, dtype=np.int64)
        scores = np.asarray(scores, dtype=np.float64).reshape(k, -1)
        if scores.shape[1] != NUM_CRITERIA:
            raise ValueError(f"scores must be ({k}, {NUM_CRITERIA})")
        H = np.asarray(histograms, dtype=np.float64)
        if H.ndim != 2 or H.shape[0] != k:
            raise ValueError("histograms must be (k, c)")
        if self.n == 0 and H.shape[1] != self.num_classes:
            self.histograms = np.zeros((0, H.shape[1]))  # adopt c on empty
            if self._bufs is not None:
                self._bufs["histograms"] = self.histograms
        if H.shape[1] != self.num_classes:
            raise ValueError(f"histograms must have {self.num_classes} "
                             f"classes, got {H.shape[1]}")
        costs = np.asarray(costs, dtype=np.float64).reshape(k)
        act = np.ones(k, dtype=bool) if active is None \
            else np.asarray(active, dtype=bool).reshape(k)
        if self._known is None:      # built once; updated incrementally
            self._known = set(int(c) for c in self.client_ids)
        live = self._pos_map()
        dup = sorted({int(c) for c in ids if int(c) in live})
        if dup or len(set(ids.tolist())) != k:
            vals = ids.tolist()
            batch_dup = {v for v in vals if vals.count(v) > 1}
            raise ValueError(f"client ids already registered or duplicated "
                             f"in batch: {sorted(set(dup) | batch_dup)[:5]}")
        # split rejoining tombstones (row reactivated in place, position
        # stable) from genuinely new ids (appended)
        self.reg_counter += 1
        rejoin = np.array([int(c) in self._known for c in ids])
        out = np.empty(k, dtype=np.int64)
        if rejoin.any():
            if self._pos_all is None:
                self._pos_all = {int(c): i
                                 for i, c in enumerate(self.client_ids)}
            rows = np.array([self._pos_all[int(c)] for c in ids[rejoin]],
                            dtype=np.int64)
            self.scores[rows] = scores[rejoin]
            self.histograms[rows] = H[rejoin]
            self.costs[rows] = costs[rejoin]
            self.active[rows] = act[rejoin]
            self.participation[rows] = 0
            self.reputation[rows] = 0.0
            self.registered[rows] = True
            self.reg_seq[rows] = self.reg_counter
            out[rejoin] = rows
        fresh = ~rejoin
        kf = int(fresh.sum())
        if kf:
            self._known.update(int(c) for c in ids[fresh])
            self._ensure_capacity(kf)
            n0, n1 = self.n, self.n + kf
            b = self._bufs
            b["client_ids"][n0:n1] = ids[fresh]
            b["scores"][n0:n1] = scores[fresh]
            b["histograms"][n0:n1] = H[fresh]
            b["costs"][n0:n1] = costs[fresh]
            b["active"][n0:n1] = act[fresh]
            b["participation"][n0:n1] = 0
            b["reputation"][n0:n1] = 0.0
            b["registered"][n0:n1] = True
            b["reg_seq"][n0:n1] = self.reg_counter
            for f in self._FIELDS:
                setattr(self, f, b[f][:n1])
            out[fresh] = np.arange(n0, n1, dtype=np.int64)
        # incremental cache maintenance: rows never move, so the id->row
        # maps just gain the (re)registered entries; score/size caches
        # are stale (new rows / overwritten profiles) and rebuild lazily
        for c, r in zip(ids, out):
            if self._pos is not None:
                self._pos[int(c)] = int(r)
            if self._pos_all is not None:
                self._pos_all[int(c)] = int(r)
        # timing stats follow the row universe: grow for fresh rows,
        # reset for reactivated ones (a rejoin is a new device); a rejoin
        # also cancels any deregister deferred while the old row was
        # pinned — the client is wanted again
        if self.timeout_counts.shape[0] < self.n:
            grow = self.n - self.timeout_counts.shape[0]
            pad = np.zeros(grow, dtype=np.int64)
            self.timeout_counts = np.concatenate([self.timeout_counts, pad])
            self.dispatch_counts = np.concatenate(
                [self.dispatch_counts, pad.copy()])
        if rejoin.any():
            self.timeout_counts[out[rejoin]] = 0
            self.dispatch_counts[out[rejoin]] = 0
        for c in ids:
            self._deferred_dereg.discard(int(c))
        self._overall = None
        self._sizes = None
        self._bump_version()
        self._log_mutation(out)
        return out

    def deregister(self, ids: Sequence[int] | np.ndarray) -> None:
        """Churn-out: tombstone clients in place. Rows keep their
        positions and data, so a task mid-period keeps training its
        already-drawn schedule (``positions(...,
        include_deregistered=True)``) until the next period checkpoint
        drops the client; the ids disappear from plain ``positions``,
        ``threshold_mask`` and the profile views immediately. Raises
        ``KeyError`` for ids not registered.

        Ids referenced by an in-flight ``PendingChunk`` schedule
        (:meth:`pin`) are **deferred**, not tombstoned: the removal is
        applied automatically when the last pin is released (the chunk
        is collected or evicted), so a dispatched schedule never trains
        against a row that silently churned out underneath it."""
        ids = [int(c) for c in np.asarray(ids, dtype=np.int64).reshape(-1)]
        deferred = [c for c in ids if self._pins.get(c)]
        now = [c for c in ids if not self._pins.get(c)]
        self._deferred_dereg.update(deferred)
        if not now:
            return
        rows = self.positions(now)
        self.registered[rows] = False
        self.active[rows] = False
        if self._pos is not None:       # incremental: rows never move
            for c in now:
                self._pos.pop(int(c), None)
        self._bump_version()
        self._log_mutation(rows)

    # -- in-flight pins + timing stats (robustness plane) --------------------
    def pin(self, ids) -> None:
        """Mark ``ids`` as referenced by an in-flight dispatched chunk.
        Pins are refcounted (overlapping tenants may share clients);
        while pinned, :meth:`deregister` defers instead of tombstoning."""
        for c in ids:
            c = int(c)
            self._pins[c] = self._pins.get(c, 0) + 1

    def unpin(self, ids) -> None:
        """Release one pin per id; at refcount zero, any deregister
        deferred while the client was pinned is applied."""
        release = []
        for c in ids:
            c = int(c)
            left = self._pins.get(c, 0) - 1
            if left > 0:
                self._pins[c] = left
            else:
                self._pins.pop(c, None)
                if c in self._deferred_dereg:
                    self._deferred_dereg.discard(c)
                    release.append(c)
        if release:
            self.deregister(release)

    def is_pinned(self, client_id: int) -> bool:
        return self._pins.get(int(client_id), 0) > 0

    def note_timing(self, dispatched_rows: np.ndarray,
                    timeout_rows: np.ndarray) -> None:
        """Tally one dispatch per row in ``dispatched_rows`` and one
        collect-timeout per row in ``timeout_rows`` (fault-mode
        lifecycle bookkeeping; see :meth:`timeout_rate`)."""
        np.add.at(self.dispatch_counts,
                  np.asarray(dispatched_rows, dtype=np.int64), 1)
        np.add.at(self.timeout_counts,
                  np.asarray(timeout_rows, dtype=np.int64), 1)

    def timeout_rate(self) -> np.ndarray:
        """(n,) float — fraction of each client's dispatches that missed
        the round close (0 for never-dispatched clients). Selection
        policies (``straggler_aware``) use this to discount chronic
        stragglers' scores."""
        return self.timeout_counts / np.maximum(self.dispatch_counts, 1)

    def subset(self, index: np.ndarray) -> "ClientPoolState":
        """A new pool state restricted to ``index`` (bool mask or rows)."""
        idx = np.asarray(index)
        return ClientPoolState(
            client_ids=self.client_ids[idx],
            scores=self.scores[idx],
            histograms=self.histograms[idx],
            costs=self.costs[idx],
            active=self.active[idx],
            participation=self.participation[idx],
            reputation=self.reputation[idx],
            registered=self.registered[idx],
            reg_seq=self.reg_seq[idx],
        )

    # -- adapters (dataclass API compatibility) ------------------------------
    @classmethod
    def from_profiles(cls, profiles: Sequence[ClientProfile]) -> "ClientPoolState":
        profiles = list(profiles)
        if not profiles:
            return cls(np.zeros(0, np.int64), np.zeros((0, NUM_CRITERIA)),
                       np.zeros((0, 1)), np.zeros(0))
        return cls(
            client_ids=np.array([p.client_id for p in profiles], np.int64),
            scores=np.stack([p.scores for p in profiles]),
            histograms=np.stack([p.histogram for p in profiles]),
            costs=np.array([p.cost for p in profiles], np.float64),
            active=np.array([p.available for p in profiles], bool),
        )

    def to_profiles(self) -> list[ClientProfile]:
        """Dataclass view of the *registered* clients (churned-out rows
        are tombstones, not clients — they are skipped)."""
        return [
            ClientProfile(
                client_id=int(self.client_ids[i]),
                scores=self.scores[i].copy(),
                histogram=self.histograms[i].copy(),
                cost=float(self.costs[i]),
                available=bool(self.active[i]),
            )
            for i in range(self.n) if self.registered[i]
        ]

    @classmethod
    def from_histograms(cls, histograms: Mapping[int, np.ndarray]) -> "ClientPoolState":
        """Adapter for the scheduler's legacy ``dict[id, hist]`` input.

        Scores are zero placeholders; rows follow ascending client id (the
        legacy scheduler's canonical order).
        """
        ids = np.array(sorted(histograms.keys()), dtype=np.int64)
        if ids.size == 0:
            return cls(ids, np.zeros((0, NUM_CRITERIA)), np.zeros((0, 1)),
                       np.zeros(0))
        H = np.stack([np.asarray(histograms[int(k)], dtype=np.float64)
                      for k in ids])
        return cls(ids, np.zeros((ids.size, NUM_CRITERIA)), H,
                   np.zeros(ids.size))

    # -- constructors --------------------------------------------------------
    @classmethod
    def random(cls, n_clients: int, n_classes: int, rng: np.random.Generator,
               cost_a: float = 2.0, cost_b: float = 5.0,
               integer_cost: bool = True) -> "ClientPoolState":
        """Vectorized virtual-client pool (paper §VIII-A), the array-native
        counterpart of ``criteria.random_profiles`` — O(n·c) with no Python
        loop, so 100k+ client pools build in milliseconds.

        Draws differ from ``random_profiles`` (which samples per client);
        marginal distributions match: per client a uniform label-count
        k ~ U{1..c}, k distinct labels, counts ~ U{10..199}.
        """
        from .criteria import (CRITERIA, data_dist_score,  # no import cycle
                               random_histograms)
        scores = rng.uniform(0.0, 1.0, size=(n_clients, NUM_CRITERIA))
        hists = random_histograms(n_clients, n_classes, rng)
        sizes = hists.sum(axis=1)
        scores[:, CRITERIA.index("data_size")] = sizes / max(sizes.max(), 1e-12)
        scores[:, CRITERIA.index("data_dist")] = data_dist_score(hists)
        costs = linear_cost(overall_score(scores), cost_a, cost_b,
                            integer=integer_cost)
        return cls(np.arange(n_clients, dtype=np.int64), scores, hists, costs)
