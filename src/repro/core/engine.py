"""Batched selection / scheduling engine over ``ClientPoolState`` arrays.

This module is the array-native hot path behind the control plane:

- ``greedy_knapsack``        — Stage-1 greedy (Eq. 12) as argsort +
  cumulative-sum prefix instead of a per-client Python loop. Bit-exact
  against ``selection.select_greedy_legacy`` (the remaining-budget
  sequence is reproduced with ``np.subtract.accumulate``, so even float
  rounding matches the sequential loop).
- ``greedy_knapsack_batch``  — the same greedy jit+vmapped over many
  concurrent ``TaskRequest`` budgets/threshold masks (multi-tenant
  serving: one argsort per task, one fused scan, no Python per client).
- ``mkp_pseudo_utility``     — the Toyoda scarcity-weighted scoring of
  *all* MKP candidates at once (shared with ``mkp.solve_mkp_greedy`` so
  the two paths cannot drift).
- ``solve_mkp_greedy_jax``   — the MKP greedy loop as a
  ``lax.while_loop`` whose per-iteration ``(n_items, n_knapsacks)``
  utility update runs through ``kernels.ops.mkp_utility`` (Pallas on
  TPU, jnp reference on CPU, interpret mode for tests).

Data flow: callers hold a ``ClientPoolState``; every function here takes
plain arrays (columns of that state) and returns arrays/masks, so it is
jit/vmap friendly and never materializes ``ClientProfile`` objects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Stage 1: vectorized greedy knapsack
# ---------------------------------------------------------------------------

def greedy_order(scores: np.ndarray, costs: np.ndarray) -> np.ndarray:
    """Non-increasing score/cost ratio order (stable, like the legacy)."""
    ratio = np.asarray(scores, np.float64) / np.maximum(
        np.asarray(costs, np.float64), _EPS)
    return np.argsort(-ratio, kind="stable")


def greedy_knapsack(scores: np.ndarray, costs: np.ndarray, budget: float,
                    skip_unaffordable: bool = False
                    ) -> tuple[np.ndarray, float, float]:
    """Vectorized greedy (§VI-A). Returns ``(chosen, total_score,
    total_cost)`` with ``chosen`` positions in pick order — identical to
    the legacy Python loop on any input.

    Paper-faithful mode (``skip_unaffordable=False``): the scan stops at
    the first client whose cost exceeds the remaining budget, i.e. the
    selection is the longest affordable prefix of the ratio order. The
    remaining-budget sequence ``b - c0 - c1 - ...`` is evaluated with
    left-fold rounding (``np.subtract.accumulate``) so float behavior
    matches the sequential loop exactly.

    The skip variant keeps scanning for cheaper clients; that is an
    inherently sequential recurrence, run here over the presorted cost
    array with a suffix-min early exit.
    """
    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    order = greedy_order(scores, costs)
    oc = costs[order]
    n = oc.size
    if n == 0:
        return order[:0], 0.0, 0.0
    if not skip_unaffordable:
        # remaining[t] = budget - c0 - ... - c_{t-1}, folded left to right
        rem = np.subtract.accumulate(
            np.concatenate(([float(budget)], oc)))[:-1]
        unaff = oc > rem
        k = int(np.argmax(unaff)) if unaff.any() else n
        chosen = order[:k]
        return chosen, float(scores[chosen].sum()), float(costs[chosen].sum())
    # skip mode: sequential over the sorted order, but bail out as soon as
    # nothing further down can fit (suffix minimum of cost).
    sufmin = np.minimum.accumulate(oc[::-1])[::-1]
    remaining = float(budget)
    taken = np.zeros(n, dtype=bool)
    for t in range(n):
        if sufmin[t] > remaining:
            break
        c = oc[t]
        if c <= remaining:
            taken[t] = True
            remaining -= c
    chosen = order[taken]
    return chosen, float(scores[chosen].sum()), float(costs[chosen].sum())


@functools.partial(jax.jit, static_argnames=("skip_unaffordable",))
def _greedy_batch_jax(scores, costs, budgets, valid, skip_unaffordable):
    """(T,) budgets x (T, n) validity -> (T, n) selection masks + totals."""

    def one(budget, vmask):
        ratio = jnp.where(vmask, scores / jnp.maximum(costs, _EPS), -jnp.inf)
        order = jnp.argsort(-ratio, stable=True)
        # invalid clients sort last; infinite cost makes them hard stops
        oc = jnp.where(vmask[order], costs[order], jnp.inf)

        def step(carry, c):
            remaining, stopped = carry
            fits = (c <= remaining) & jnp.logical_not(stopped)
            if not skip_unaffordable:
                stopped = stopped | (c > remaining)
            remaining = remaining - jnp.where(fits, c, 0.0)
            return (remaining, stopped), fits

        init = (jnp.asarray(budget, scores.dtype), jnp.asarray(False))
        _, taken = jax.lax.scan(step, init, oc)
        return jnp.zeros_like(vmask).at[order].set(taken)

    masks = jax.vmap(one)(budgets, valid)
    return masks, masks @ scores, masks @ costs


def greedy_knapsack_batch(scores: np.ndarray, costs: np.ndarray,
                          budgets: np.ndarray,
                          valid: np.ndarray | None = None,
                          skip_unaffordable: bool = False,
                          backend: str = "auto"):
    """Batched Stage-1 greedy for multi-tenant serving.

    Every concurrent task shares the client pool, hence the score/cost
    ratio *order*: the batch reduces to ONE argsort plus a ``(T, n)``
    masked cumulative sum — per-task work is O(n), not O(n log n), and
    fully vectorized over tasks. ``backend="jax"`` instead runs the
    jit+vmap scan (`_greedy_batch_jax`), the path that makes sense on
    TPU; ``"auto"`` picks jax on TPU and numpy elsewhere.

    Args:
      scores, costs: (n,) shared client pool columns.
      budgets: (T,) one budget per concurrent task.
      valid: optional (T, n) per-task eligibility (threshold masks).

    Returns ``(masks, total_scores, total_costs)`` with shapes
    ``(T, n), (T,), (T,)`` as numpy arrays. With the numpy backend,
    selections are bit-exact against running the single-task greedy per
    task over its valid clients; the jax backend computes in float32
    (ratio ties / rounding may differ at the margin).
    """
    if backend == "auto":
        backend = "jax" if jax.default_backend() == "tpu" else "numpy"
    if backend == "jax":
        scores = jnp.asarray(scores)
        costs = jnp.asarray(costs)
        budgets = jnp.atleast_1d(jnp.asarray(budgets))
        if valid is None:
            valid = jnp.ones((budgets.shape[0], scores.shape[0]), dtype=bool)
        else:
            valid = jnp.asarray(valid, dtype=bool)
        masks, ts, tc = _greedy_batch_jax(scores, costs, budgets, valid,
                                          bool(skip_unaffordable))
        return np.asarray(masks), np.asarray(ts), np.asarray(tc)

    scores = np.asarray(scores, dtype=np.float64)
    costs = np.asarray(costs, dtype=np.float64)
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    T, n = budgets.shape[0], scores.shape[0]
    if valid is None:
        valid = np.ones((T, n), dtype=bool)
    else:
        valid = np.asarray(valid, dtype=bool)
    if skip_unaffordable:
        # sequential recurrence per task; no shared-prefix shortcut
        masks = np.zeros((T, n), dtype=bool)
        for t in range(T):
            cols = np.flatnonzero(valid[t])
            chosen, _, _ = greedy_knapsack(scores[cols], costs[cols],
                                           budgets[t], skip_unaffordable=True)
            masks[t, cols[chosen]] = True
        return masks, masks @ scores, masks @ costs
    order = greedy_order(scores, costs)
    oc = costs[order]                                  # (n,)
    ov = valid[:, order]                               # (T, n)
    # Reproduce the single-task greedy's left-fold remaining-budget
    # sequence per row (budget - c0 - c1 - ..., rounded at every step;
    # invalid clients subtract exactly 0.0), so selections are bit-exact
    # against greedy_knapsack even when partial sums round differently
    # than a cumsum-vs-budget comparison would.
    rem = np.subtract.accumulate(
        np.concatenate([budgets[:, None], np.where(ov, oc, 0.0)], axis=1),
        axis=1)[:, :-1]                                # (T, n) before each pick
    viol = ov & (oc > rem)
    first = np.where(viol.any(axis=1), viol.argmax(axis=1), n)
    take = ov & (np.arange(n) < first[:, None])
    masks = np.zeros((T, n), dtype=bool)
    masks[:, order] = take
    return masks, masks @ scores, masks @ costs


# ---------------------------------------------------------------------------
# Stage 1 at fleet scale: hierarchical two-level greedy
# ---------------------------------------------------------------------------

def _flat_pool_greedy(pool, budget: float, thresholds
                      ) -> tuple[np.ndarray, float, float, int]:
    """Host flat path over a ``ClientPoolState``: threshold mask ->
    greedy over kept rows -> global row indices in pick order."""
    mask = pool.threshold_mask(thresholds)
    rows_kept = np.flatnonzero(mask)
    chosen, ts, tc = greedy_knapsack(pool.overall[rows_kept],
                                     pool.costs[rows_kept], budget)
    return rows_kept[chosen], ts, tc, int(rows_kept.size)


def hierarchical_greedy_knapsack(pool, budget: float,
                                 thresholds: np.ndarray | None = None,
                                 *, mirror=None, shard_cap: int | None = None,
                                 interpret: bool | None = None,
                                 stats: dict | None = None
                                 ) -> tuple[np.ndarray, float, float, int]:
    """Two-level Stage-1 greedy over the device pool mirror (fleet
    scale: 1M–10M clients; see ``docs/scaling.md``).

    Level 1 (device, f32): eligibility mask + score/cost ratios over the
    ``(S, C)`` sharded mirror, then a per-shard top-``F`` frontier via
    the ``segmented_topk`` kernel — O(n) streaming work, no full-pool
    argsort. Level 2 (host, f64): the exact paper greedy over the
    ``<= S*F`` surviving candidates, re-ranked with the host pool's f64
    scores/costs and the flat path's stable tie-break (ratio ties break
    toward the lower global row). The frontier escalates (``F *= 2``)
    whenever a clipped shard could still contribute — i.e. the budget
    scan consumed a clipped shard's entire frontier, or never hit a
    stop — so on termination the result provably matches the flat
    greedy on the f32-frontier candidate set (membership itself is
    decided in f32; see docs for the near-tie caveat).

    Degenerate budgets that would select a large fraction of the pool
    (frontier ~ pool) fall back to the flat host path.

    Returns ``(rows, total_score, total_cost, n_valid)`` with ``rows``
    global pool rows in pick order. ``stats``, if given, is filled with
    path/frontier/escalation counters.
    """
    if mirror is None:
        mirror = pool.device_mirror(shard_cap=shard_cap)
    else:
        mirror.sync(pool)
    valid = mirror.valid_mask(thresholds)
    counts, cost_sum = mirror.shard_stats(valid)
    n_valid = int(counts.sum())
    if stats is None:
        stats = {}
    stats.update(path="frontier", frontier=0, escalations=0,
                 candidates=0, shards=mirror.num_shards)
    if n_valid == 0:
        return np.zeros(0, np.int64), 0.0, 0.0, 0
    S = mirror.num_shards
    max_count = int(counts.max())
    budget = float(budget)
    # Frontier sizing: expected picks if the budget were spent at the
    # mean valid cost, spread over shards, with 4x headroom for skew.
    k_est = budget / max(cost_sum / n_valid, _EPS)
    if k_est >= 0.5 * n_valid:
        stats["path"] = "flat-fallback"
        rows, ts, tc, n_kept = _flat_pool_greedy(pool, budget, thresholds)
        return rows, ts, tc, n_kept
    F = int(min(max_count, max(32, 1 << int(np.ceil(
        np.log2(4.0 * k_est / S + 8.0))))))
    while True:
        stats["frontier"] = F
        vals, rows = mirror.frontier(mirror.masked_ratio(valid), F,
                                     interpret=interpret)
        cand = rows[np.isfinite(vals)]
        stats["candidates"] = int(cand.size)
        # Host-precision merge: exact greedy over the candidate set.
        # overall_score on the gathered rows only — identical per-row
        # values to pool.overall, without forcing the pool-wide O(n)
        # cache rebuild after every churn event.
        from .criteria import overall_score
        sc = overall_score(pool.scores[cand])
        cs = pool.costs[cand]
        ratio = sc / np.maximum(cs, _EPS)
        pos = np.lexsort((cand, -ratio))      # ratio desc, row asc on ties
        cand_s, oc = cand[pos], cs[pos]
        rem = np.subtract.accumulate(
            np.concatenate(([budget], oc)))[:-1]
        unaff = oc > rem
        stopped = bool(unaff.any())
        k = int(np.argmax(unaff)) if stopped else oc.size
        # Escalate iff a clipped shard could still change the answer:
        # its whole frontier fed the consumed prefix (selection + the
        # stopping client), or the scan never stopped at all.
        clipped = counts > F
        if clipped.any() and F < max_count:
            prefix = cand_s[: k + 1] if stopped else cand_s
            contrib = np.bincount(prefix // mirror.shard_cap, minlength=S)
            suspect = clipped & (contrib >= F) if stopped else clipped
            if suspect.any():
                F = min(2 * F, max_count)
                stats["escalations"] += 1
                continue
        chosen = cand_s[:k]
        return (chosen, float(sc[pos][:k].sum()), float(oc[:k].sum()),
                n_valid)


def hierarchical_greedy_knapsack_batch(pool, budgets: np.ndarray,
                                       thresholds_list,
                                       *, mirror=None,
                                       shard_cap: int | None = None,
                                       interpret: bool | None = None):
    """Batched :func:`hierarchical_greedy_knapsack` for multi-tenant
    sweeps: one mirror sync serves every task; each task then runs its
    own frontier + host merge (per-task thresholds make the device mask
    task-specific, so there is no shared argsort to amortize — the
    shared work is the mirror itself).

    ``thresholds_list``: per-task thresholds (or ``None``), length T.
    Returns a list of ``(rows, total_score, total_cost, n_valid)``.
    """
    if mirror is None:
        mirror = pool.device_mirror(shard_cap=shard_cap)
    else:
        mirror.sync(pool)
    budgets = np.atleast_1d(np.asarray(budgets, dtype=np.float64))
    return [hierarchical_greedy_knapsack(pool, float(b), th, mirror=mirror,
                                         interpret=interpret)
            for b, th in zip(budgets, thresholds_list)]


# ---------------------------------------------------------------------------
# Stage 2: vectorized Toyoda pseudo-utility (MKP inner loop)
# ---------------------------------------------------------------------------

def mkp_pseudo_utility(values: np.ndarray, weights: np.ndarray,
                       residual: np.ndarray, selectable: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Scarcity-weighted utility of *all* candidates at once.

    ``util_j = v_j / (w_j · scarcity)`` with ``scarcity = 1/residual``;
    items that don't fit (or aren't selectable) score ``-inf``. This is
    the single source of truth for the greedy MKP scoring — both
    ``mkp.solve_mkp_greedy`` (numpy) and the jax/Pallas path call the
    same formula.
    """
    scarcity = 1.0 / np.maximum(residual, _EPS)
    penalty = weights @ scarcity
    util = values / np.maximum(penalty, _EPS)
    fits = selectable & np.all(weights <= residual + _EPS, axis=1)
    return np.where(fits, util, -np.inf), fits


def mkp_pseudo_utility_jax(values, weights, residual, selectable,
                           interpret: bool | None = None):
    """Accelerator path of :func:`mkp_pseudo_utility` (Pallas on TPU,
    jnp reference otherwise; ``interpret=True`` forces the kernel in
    interpreter mode for CPU testing)."""
    from ..kernels import ops
    return ops.mkp_utility(values, weights, residual, selectable,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_size", "interpret"))
def _mkp_greedy_jax(values, weights, capacities, max_size, interpret):
    from ..kernels import ops
    n, m = weights.shape

    def cond(state):
        _, _, count, cont = state
        return cont & (count < max_size)

    def body(state):
        used, in_sel, count, _ = state
        residual = capacities - used
        util = ops.mkp_utility(values, weights, residual,
                               jnp.logical_not(in_sel), interpret=interpret)
        j = jnp.argmax(util)
        ok = jnp.isfinite(util[j])
        in_sel = in_sel.at[j].set(in_sel[j] | ok)
        used = used + jnp.where(ok, weights[j], 0.0)
        return used, in_sel, count + ok.astype(jnp.int32), ok

    init = (jnp.zeros(m, values.dtype), jnp.zeros(n, dtype=bool),
            jnp.asarray(0, jnp.int32), jnp.asarray(True))
    used, in_sel, _, _ = jax.lax.while_loop(cond, body, init)
    return in_sel, used


def solve_mkp_greedy_jax(values, weights, capacities,
                         max_size: int | None = None,
                         interpret: bool | None = None
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Toyoda greedy as a jit'd ``while_loop``; the per-iteration utility
    update is the Pallas kernel (TPU) / jnp reference (CPU).

    Returns ``(selection_mask (n,), used (m,))``. Matches the greedy
    phase of ``mkp.solve_mkp_greedy`` (``local_search=False``) up to
    float32 utility ties.
    """
    values = jnp.asarray(values)
    weights = jnp.asarray(weights)
    capacities = jnp.asarray(capacities)
    ms = int(values.shape[0] if max_size is None else max_size)
    in_sel, used = _mkp_greedy_jax(values, weights, capacities, ms,
                                   interpret)
    return np.asarray(in_sel), np.asarray(used)
