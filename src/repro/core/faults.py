"""Deterministic fault injection for FL round execution (robustness
plane).

Real FL fleets are dominated by device heterogeneity: stragglers,
mid-round crashes, transient outages, permanent departures (the client
-selection surveys in PAPERS.md enumerate exactly these axes). The
service plane models them through one seeded :class:`FaultPlan` — a
*pure function* from ``(plan.seed, client_id, round)`` to latencies and
failure events, built on counter-based splitmix64 hashing rather than
stateful RNGs, so:

- every scenario replays bit-identically (tests, checkpoint/resume,
  benchmark baselines share one plan);
- outcomes for a client/round never depend on evaluation order, how
  rounds are chunked, or which other clients are scheduled;
- the lifecycle can evaluate a round's arrivals *at dispatch time*
  (``round_outcome``) and mask non-arriving clients on device before
  any training runs — simulation-honest straggler mitigation with no
  wall-clock sleeps anywhere.

The plan is attached to a trainer (``DeviceFLSim(...,
fault_plan=plan)`` or any object with a ``fault_plan`` attribute); the
lifecycle reads it with ``getattr``. A plan with every rate at zero is
*inactive* (:attr:`FaultPlan.active` is False) and the lifecycle takes
the unmodified no-fault code path — bit-identical to a trainer with no
plan at all (asserted in tests/test_faults.py and
benchmarks/bench_faults.py).

Latencies are unitless simulated time: ``base_latency`` is a healthy
client's round time, ``collect_deadline`` / ``retry_backoff`` on
:class:`~repro.core.lifecycle.TaskRequest` are expressed in the same
units, and the per-round ``metrics["round_latency"]`` the lifecycle
emits is the simulated close time of the round.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(z: np.ndarray) -> np.ndarray:
    """Finalizer of the splitmix64 generator, vectorized over uint64."""
    z = (z + _GOLDEN) & _MASK64
    z = ((z ^ (z >> np.uint64(30))) * _MIX1) & _MASK64
    z = ((z ^ (z >> np.uint64(27))) * _MIX2) & _MASK64
    return z ^ (z >> np.uint64(31))


def _u01(seed: int, stream: int, ids, extra=0) -> np.ndarray:
    """I.i.d.-looking uniforms in [0, 1) keyed by ``(seed, stream,
    client_id, extra)`` — counter-based, so any tuple can be evaluated
    independently and out of order."""
    ids = np.atleast_1d(np.asarray(ids)).astype(np.uint64)
    extra = np.asarray(extra).astype(np.uint64)
    with np.errstate(over="ignore"):
        h = _splitmix64(np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                        ^ ((np.uint64(stream) * _GOLDEN) & _MASK64))
        h = _splitmix64(ids ^ h)
        h = _splitmix64(h ^ ((extra * _MIX1) & _MASK64))
    return (h >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


@dataclasses.dataclass(frozen=True)
class RoundOutcome:
    """Arrival evaluation of one round under a :class:`FaultPlan`."""

    arrival: np.ndarray     # (K,) bool — reported by the close time
    latency: np.ndarray     # (K,) float — per-client report time (inf =
    # never: crashed, in outage, or permanently dead this round)
    close_time: float       # simulated time the round closed: min of the
    # deadline and the target_k-th arrival (first-k-collect)
    n_arrived: int
    quorum_met: bool        # n_arrived >= quorum_k


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, deterministic per-client fault model.

    All rates default to zero — the all-zero plan is :attr:`active` ==
    False and injects nothing. Fields:

    - ``straggler_frac`` — fraction of clients that are *chronic*
      stragglers (a fixed per-client trait drawn once from the seed);
      their round latency is multiplied by ``straggler_slowdown``.
    - ``base_latency`` / ``latency_jitter`` — a healthy client's round
      time is ``base_latency * (1 + jitter*U[-1,1))`` per (client,
      round).
    - ``crash_prob`` — per-(client, round) probability of a transient
      mid-round crash (the update is lost; the client is back next
      round).
    - ``permanent_frac`` — converts a fraction of the crash rate into
      *permanent* death: each client permanently departs at a geometric
      round with per-round rate ``crash_prob * permanent_frac``.
    - ``outage_prob`` / ``outage_len`` — flaky-rejoin churn: in each
      window of ``outage_len`` rounds a client is offline with
      probability ``outage_prob`` (and rejoins in the next window).
    """

    seed: int = 0
    straggler_frac: float = 0.0
    straggler_slowdown: float = 4.0
    base_latency: float = 1.0
    latency_jitter: float = 0.25
    crash_prob: float = 0.0
    permanent_frac: float = 0.0
    outage_prob: float = 0.0
    outage_len: int = 5

    @property
    def active(self) -> bool:
        """Whether this plan can change any outcome. Inactive plans are
        treated by the lifecycle exactly like no plan at all (the
        bit-identity contract)."""
        return (self.straggler_frac > 0.0 or self.crash_prob > 0.0
                or self.outage_prob > 0.0)

    # -- per-client / per-round draws ---------------------------------------
    def is_straggler(self, ids) -> np.ndarray:
        """(K,) bool — the fixed chronic-straggler trait."""
        return _u01(self.seed, 1, ids) < self.straggler_frac

    def latency(self, ids, round_index: int) -> np.ndarray:
        """(K,) float — simulated report latency, ignoring crashes."""
        u = _u01(self.seed, 2, ids, extra=int(round_index))
        jit = 1.0 + self.latency_jitter * (2.0 * u - 1.0)
        slow = np.where(self.is_straggler(ids),
                        self.straggler_slowdown, 1.0)
        return self.base_latency * slow * jit

    def death_round(self, ids) -> np.ndarray:
        """(K,) float — the round at which each client permanently
        departs (inf = never). Geometric with per-round rate
        ``crash_prob * permanent_frac``, drawn in O(1) per client."""
        ids = np.atleast_1d(np.asarray(ids))
        p = self.crash_prob * self.permanent_frac
        if p <= 0.0:
            return np.full(ids.shape[0], np.inf)
        u = _u01(self.seed, 3, ids)
        return np.floor(np.log1p(-u) / np.log1p(-min(p, 1.0 - 1e-12)))

    def crashed(self, ids, round_index: int) -> np.ndarray:
        """(K,) bool — transient mid-round crash this round."""
        ids = np.atleast_1d(np.asarray(ids))
        if self.crash_prob <= 0.0:
            return np.zeros(ids.shape[0], dtype=bool)
        return _u01(self.seed, 4, ids, extra=int(round_index)) \
            < self.crash_prob

    def in_outage(self, ids, round_index: int) -> np.ndarray:
        """(K,) bool — offline for this round's outage window."""
        ids = np.atleast_1d(np.asarray(ids))
        if self.outage_prob <= 0.0:
            return np.zeros(ids.shape[0], dtype=bool)
        win = int(round_index) // max(1, int(self.outage_len))
        return _u01(self.seed, 5, ids, extra=win) < self.outage_prob

    def alive(self, ids, round_index: int) -> np.ndarray:
        """(K,) bool — will this client report this round at all."""
        ids = np.atleast_1d(np.asarray(ids))
        return ((round_index < self.death_round(ids))
                & ~self.in_outage(ids, round_index)
                & ~self.crashed(ids, round_index))

    # -- round evaluation ----------------------------------------------------
    def round_outcome(self, ids, round_index: int, deadline: float,
                      target_k: int, quorum_k: int) -> RoundOutcome:
        """Evaluate one round's arrivals (first-k-collect semantics).

        The round closes at ``min(deadline, latency of the target_k-th
        arrival)``; with no deadline (``deadline <= 0``) it closes at
        the ``target_k``-th arrival, or at the last alive arrival when
        fewer than ``target_k`` clients ever report — the simulation
        never hangs. ``arrival`` marks clients whose latency is within
        the close; ``quorum_met`` is ``n_arrived >= quorum_k``.
        """
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        lat = np.where(self.alive(ids, round_index),
                       self.latency(ids, round_index), np.inf)
        dl = float(deadline) if deadline is not None and deadline > 0 \
            else np.inf
        finite = np.isfinite(lat)
        nf = int(finite.sum())
        k = min(max(int(target_k), 1), lat.size)
        if nf == 0:
            close = dl if np.isfinite(dl) else 0.0
        elif nf >= k:
            close = min(dl, float(np.partition(lat, k - 1)[k - 1]))
        else:
            close = min(dl, float(lat[finite].max()))
        arrival = lat <= close
        n = int(arrival.sum())
        return RoundOutcome(arrival=arrival, latency=lat,
                            close_time=float(close), n_arrived=n,
                            quorum_met=n >= int(quorum_k))
