"""Core library: the paper's client selection + scheduling contribution."""
from .criteria import (CRITERIA, NUM_CRITERIA, ClientProfile, build_profiles,
                       cosine_similarity, data_dist_score, linear_cost, nid,
                       nid_hellinger, nid_kl, nid_l2, overall_score,
                       random_profiles, resource_scores)
from .fairness import (bounded_participation, coverage, fairness_report,
                       jain_index, over_selection_fraction)
from .mkp import MKPResult, solve_mkp, solve_mkp_bnb, solve_mkp_greedy
from .reputation import ReputationRecord, ReputationTracker, model_quality_batch
from .scheduling import (ScheduleResult, default_capacities, generate_subsets,
                         participation_weights, random_subsets, subset_nid)
from .selection import (SelectionResult, budget_floor, select_dp,
                        select_greedy, select_initial_pool, select_random,
                        threshold_filter)
from .service import FLServiceProvider, RoundLog, ServiceRunResult, TaskRequest

__all__ = [
    "CRITERIA", "NUM_CRITERIA", "ClientProfile", "build_profiles",
    "cosine_similarity", "data_dist_score", "linear_cost", "nid",
    "nid_hellinger", "nid_kl", "nid_l2", "overall_score", "random_profiles",
    "resource_scores", "bounded_participation", "coverage", "fairness_report",
    "jain_index", "over_selection_fraction", "MKPResult", "solve_mkp",
    "solve_mkp_bnb", "solve_mkp_greedy", "ReputationRecord",
    "ReputationTracker", "model_quality_batch", "ScheduleResult",
    "default_capacities", "generate_subsets", "participation_weights",
    "random_subsets", "subset_nid", "SelectionResult", "budget_floor",
    "select_dp", "select_greedy", "select_initial_pool", "select_random",
    "threshold_filter", "FLServiceProvider", "RoundLog", "ServiceRunResult",
    "TaskRequest",
]
