"""Core library: the paper's client selection + scheduling contribution.

Data flow (post array-native refactor):

- ``ClientPoolState`` (pool.py) is the internal representation — a
  struct-of-arrays (scores ``(n, 11)``, histograms ``(n, c)``, costs,
  active mask, participation counts) shared by every stage.
- ``engine`` holds the batched hot paths: vectorized greedy knapsack
  (numpy, bit-exact vs. the legacy loop), a jit+vmap multi-task greedy,
  and the Toyoda MKP scoring (numpy / jax / Pallas kernel).
- ``device_pool`` is the fleet-scale selection plane: a sharded
  device-resident mirror of the pool (``DevicePoolState``) kept
  coherent by a dirty-region sync protocol, feeding the hierarchical
  two-level greedy (per-shard ``segmented_topk`` frontiers + exact
  host merge) that ``selection``/``policy`` route to above
  ``HIERARCHICAL_MIN_N`` clients (see docs/scaling.md).
- ``selection`` / ``scheduling`` / ``service`` consume pool-state
  columns; the dataclass APIs (``ClientProfile`` lists, ``dict``
  histograms) keep working through thin adapters
  (``ClientPoolState.from_profiles`` / ``from_histograms``).
- ``policy`` is the pluggable strategy seam: ``SelectionPolicy`` /
  ``SchedulingPolicy`` protocols plus a by-name registry; every
  ``TaskRequest`` picks its pair (defaults reproduce the paper's
  greedy + Algorithm 1 bit-for-bit), and alternatives
  (random / score_prop selection, fair_ema scheduling) ride the same
  service unchanged.
- ``lifecycle`` is the service orchestration layer: an explicit
  ``TaskState`` machine (``submit`` / ``step`` / ``drain``, with the
  TRAINING transition split into async ``dispatch`` / ``collect``) with
  checkpoint/resume (``save_state``/``load_state``), client churn, and
  a multi-tenant ``ServiceScheduler`` overlapping many tasks' device
  dispatches over one shared pool. ``FLServiceProvider.run_task`` is a
  deprecated shim over it.
- ``placement`` spreads tenants across a device mesh
  (docs/placement.md): a ``PlacementPolicy`` registry (``bin_pack``
  by estimated per-round cost, ``round_robin``) behind
  ``ServiceScheduler(n_devices=..., placement=...)``, which keeps one
  in-flight window per device and migrates boundary-parked tenants on
  load imbalance over the checkpoint path.
- ``workload`` / ``driver`` / ``telemetry`` are the online harness
  (docs/workloads.md): seeded counter-based arrival / availability /
  device-speed traces, a virtual-clock ``OnlineDriver`` replaying them
  against a live ``ServiceScheduler``, and SLA telemetry (p50/p99
  latency, queue wait, completion time, DEGRADED rate, Jain fairness).
- The pre-refactor loop implementations survive as
  ``select_greedy_legacy``, ``generate_subsets_legacy`` and
  ``FLServiceProvider.run_task_legacy`` — reference paths for
  equivalence tests and benchmarks, not production.

Use the dataclass API for small pools and readability; hand a
``ClientPoolState`` to ``select_initial_pool`` / ``generate_subsets`` /
``FLServiceProvider`` for large-n or multi-task serving.
"""
from .criteria import (CRITERIA, NUM_CRITERIA, ClientProfile, build_profiles,
                       cosine_similarity, data_dist_score, linear_cost, nid,
                       nid_hellinger, nid_kl, nid_l2, overall_score,
                       random_histograms, random_profiles, resource_scores)
from .fairness import (bounded_participation, coverage, fairness_report,
                       jain_index, over_selection_fraction)
from .faults import FaultPlan, RoundOutcome
from .lifecycle import (AsyncTrainer, InFlightError, PendingChunk,
                        RejectedTask, RoundEvent, ServiceScheduler,
                        ServiceState, TaskPhase, TaskState, Trainer,
                        apply_pool_selection, as_run_result, collect,
                        dispatch, drain, load_state, resolve_trainer,
                        save_state, single_round_adapter, step, submit)
from .mkp import MKPResult, solve_mkp, solve_mkp_bnb, solve_mkp_greedy
from .placement import (PlacementPolicy, available_placement_policies,
                        placement_policy, register_placement_policy,
                        resolve_placement_policy)
from .policy import (SchedulingPolicy, SelectionPolicy,
                     available_scheduling_policies,
                     available_selection_policies,
                     register_scheduling_policy, register_selection_policy,
                     resolve_scheduling_policy, resolve_selection_policy,
                     scheduling_policy, selection_policy)
from .device_pool import DevicePoolState
from .pool import ClientPoolState
from .reputation import ReputationRecord, ReputationTracker, model_quality_batch
from .scheduling import (ScheduleResult, default_capacities,
                         default_capacities_arrays, generate_subsets,
                         generate_subsets_legacy, participation_weights,
                         random_subsets, subset_nid)
from .selection import (SelectionResult, budget_floor, select_dp,
                        select_greedy, select_greedy_legacy,
                        select_initial_pool, select_random,
                        select_score_prop, select_score_prop_batch,
                        threshold_filter)
from .service import FLServiceProvider, RoundLog, ServiceRunResult, TaskRequest
from .workload import (ArrivalTrace, DeviceSpeedProfile, DiurnalAvailability,
                       HeterogeneousFaultPlan, WorkloadTrace, make_workload)
from .driver import OnlineDriver
from .telemetry import TelemetryEvent, TelemetryLog

__all__ = [
    "CRITERIA", "NUM_CRITERIA", "ClientPoolState", "ClientProfile",
    "build_profiles", "cosine_similarity", "data_dist_score", "linear_cost",
    "nid", "nid_hellinger", "nid_kl", "nid_l2", "overall_score",
    "random_histograms", "random_profiles", "resource_scores",
    "bounded_participation", "coverage", "fairness_report", "jain_index",
    "over_selection_fraction", "MKPResult", "solve_mkp", "solve_mkp_bnb",
    "solve_mkp_greedy", "ReputationRecord", "ReputationTracker",
    "model_quality_batch", "ScheduleResult", "default_capacities",
    "default_capacities_arrays", "generate_subsets", "generate_subsets_legacy",
    "participation_weights", "random_subsets", "subset_nid",
    "SelectionResult", "budget_floor", "select_dp", "select_greedy",
    "select_greedy_legacy", "select_initial_pool", "select_random",
    "select_score_prop", "select_score_prop_batch", "threshold_filter",
    "FLServiceProvider", "RoundLog", "ServiceRunResult", "TaskRequest",
    # fleet-scale selection plane (sharded device mirror)
    "DevicePoolState",
    # placement registry (multi-device tenant fabric, docs/placement.md)
    "PlacementPolicy", "available_placement_policies", "placement_policy",
    "register_placement_policy", "resolve_placement_policy",
    # policy registry (pluggable selection/scheduling strategies)
    "SchedulingPolicy", "SelectionPolicy", "available_scheduling_policies",
    "available_selection_policies", "register_scheduling_policy",
    "register_selection_policy", "resolve_scheduling_policy",
    "resolve_selection_policy", "scheduling_policy", "selection_policy",
    # lifecycle (resumable service API)
    "AsyncTrainer", "InFlightError", "PendingChunk", "RejectedTask",
    "RoundEvent", "ServiceScheduler", "ServiceState", "TaskPhase",
    "TaskState", "Trainer", "apply_pool_selection", "as_run_result",
    "collect", "dispatch", "drain", "load_state", "resolve_trainer",
    "save_state", "single_round_adapter", "step", "submit",
    # fault injection (robustness plane, docs/robustness.md)
    "FaultPlan", "RoundOutcome",
    # online workload harness (docs/workloads.md)
    "ArrivalTrace", "DeviceSpeedProfile", "DiurnalAvailability",
    "HeterogeneousFaultPlan", "OnlineDriver", "TelemetryEvent",
    "TelemetryLog", "WorkloadTrace", "make_workload",
]
