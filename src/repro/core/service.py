"""FL service provider orchestration (paper §III Fig. 1).

Ties the two stages together the way the deployed service would run
them: task intake -> stage-1 pool selection -> repeated scheduling
periods (stage-2 subset generation + reputation-driven pool updates)
until the training driver reports convergence or the round budget is
exhausted.

The actual model training is injected as a callback so the same
orchestration drives the paper's CNN experiments, the LM federated runs
and unit tests with stub trainers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from .criteria import ClientProfile
from .reputation import ReputationTracker
from .scheduling import (ScheduleResult, generate_subsets,
                         participation_weights, random_subsets)
from .selection import SelectionResult, select_initial_pool


@dataclasses.dataclass
class TaskRequest:
    """An FL task as submitted by a task requester."""
    budget: float
    n_star: int = 1                       # minimum pool size (Eq. 8c)
    thresholds: np.ndarray | None = None  # per-criterion minimums (Eq. 8d)
    subset_size: int = 10                 # n
    subset_delta: int = 3                 # δ
    x_star: int = 3                       # max selections per period
    max_periods: int = 20
    rep_threshold: float = 0.5
    suspension_periods: int = 1
    scheduler: str = "mkp"                # "mkp" (ours) | "random" (baseline)
    nid_threshold: float = 0.35
    seed: int = 0


@dataclasses.dataclass
class RoundLog:
    period: int
    round_index: int
    subset: list[int]
    weights: np.ndarray
    nid: float
    metrics: dict


@dataclasses.dataclass
class ServiceRunResult:
    pool: SelectionResult
    rounds: list[RoundLog]
    schedules: list[ScheduleResult]
    reputation: dict[int, float]

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)


# A trainer callback runs one FL round for the given subset and returns
# (per-client returned flags, per-client q_t values, metrics dict).
TrainerFn = Callable[[int, Sequence[int], np.ndarray], tuple[np.ndarray, np.ndarray, dict]]


class FLServiceProvider:
    """Client registry + the two-stage selection/scheduling pipeline."""

    def __init__(self, profiles: Sequence[ClientProfile]):
        self.registry: dict[int, ClientProfile] = {p.client_id: p for p in profiles}

    # -- Stage 1 -------------------------------------------------------------
    def select_pool(self, task: TaskRequest, method: str = "greedy",
                    rng: np.random.Generator | None = None) -> SelectionResult:
        return select_initial_pool(
            list(self.registry.values()), budget=task.budget, n_star=task.n_star,
            thresholds=task.thresholds, method=method, rng=rng)

    # -- Stage 2 (one period) --------------------------------------------------
    def schedule_period(self, pool_ids: Sequence[int], task: TaskRequest,
                        rng: np.random.Generator) -> ScheduleResult:
        hists = {k: self.registry[k].histogram for k in pool_ids}
        if task.scheduler == "random":
            return random_subsets(hists, task.subset_size, rng)
        return generate_subsets(hists, n=task.subset_size, delta=task.subset_delta,
                                x_star=task.x_star, nid_threshold=task.nid_threshold)

    # -- Full service loop -----------------------------------------------------
    def run_task(self, task: TaskRequest, trainer: TrainerFn,
                 availability_fn: Callable[[int, int], bool] | None = None,
                 stop_fn: Callable[[dict], bool] | None = None,
                 method: str = "greedy") -> ServiceRunResult:
        """Run stage 1 then scheduling periods until stop/max_periods.

        availability_fn(client_id, period) -> bool models clients going
        offline (paper: conflicting schedules / battery / network).
        """
        rng = np.random.default_rng(task.seed)
        pool_sel = self.select_pool(task, method=method, rng=rng)
        if not pool_sel.feasible:
            return ServiceRunResult(pool_sel, [], [], {})
        pool = set(pool_sel.selected)
        tracker = ReputationTracker(pool_sel.selected,
                                    suspension_periods=task.suspension_periods,
                                    rep_threshold=task.rep_threshold)
        rounds: list[RoundLog] = []
        schedules: list[ScheduleResult] = []
        global_round = 0
        for period in range(task.max_periods):
            if not pool:
                break
            sched = self.schedule_period(sorted(pool), task, rng)
            schedules.append(sched)
            hists = {k: self.registry[k].histogram for k in pool}
            stop = False
            for t, subset in enumerate(sched.subsets):
                w = participation_weights(hists, subset)
                returned, q_vals, metrics = trainer(global_round, subset, w)
                for i, cid in enumerate(subset):
                    tracker.record_round(cid, bool(returned[i]),
                                         q_value=float(q_vals[i]))
                rounds.append(RoundLog(period, global_round, list(subset), w,
                                       sched.nids[t], metrics))
                global_round += 1
                if stop_fn is not None and stop_fn(metrics):
                    stop = True
                    break
            avail = {cid: (availability_fn(cid, period + 1)
                           if availability_fn else True)
                     for cid in tracker.records}
            pool = tracker.update_pool(pool, avail) & set(pool_sel.selected)
            if stop:
                break
        return ServiceRunResult(pool_sel, rounds, schedules, tracker.scores())
