"""FL service provider orchestration (paper §III Fig. 1).

The provider owns the shared, churnable client registry
(``ClientPoolState`` struct-of-arrays; the ``ClientProfile`` dict
remains as a compatibility view) and the two-stage pipeline: stage-1
pool selection (single-task ``select_pool`` or the batched multi-tenant
``select_pools_batch``) and stage-2 per-period scheduling
(``schedule_period``).

Task orchestration itself lives in :mod:`repro.core.lifecycle`: a task
is an explicit :class:`~repro.core.lifecycle.TaskState` advanced by
``submit`` / ``step`` / ``drain`` (resumable, multi-tenant via
``ServiceScheduler``). The blocking :meth:`FLServiceProvider.run_task`
survives as a deprecated shim over ``submit`` + ``drain`` that
reproduces the pre-redesign results bit-for-bit;
:meth:`run_task_legacy` preserves the original loop as the equivalence
reference (tests/test_lifecycle.py), not a production path.

Model training is injected as a :class:`~repro.core.lifecycle.Trainer`
(``run_rounds``) — or a legacy per-round callback, wrapped via
``single_round_adapter`` — so the same orchestration drives the paper's
CNN experiments, the LM federated runs and unit tests with stub
trainers.
"""
from __future__ import annotations

import warnings
from typing import Callable, Sequence

import numpy as np

from . import engine, lifecycle
from .criteria import ClientProfile
from .lifecycle import RoundLog, ServiceRunResult, TaskRequest
from .pool import ClientPoolState
from .reputation import ReputationTracker
from .scheduling import ScheduleResult, generate_subsets, random_subsets
from .selection import SelectionResult, select_initial_pool

# Legacy alias: a per-round trainer callback
# (round, subset, weights) -> (returned flags, q values, metrics).
TrainerFn = Callable[[int, Sequence[int], np.ndarray], tuple]


class FLServiceProvider:
    """Client registry + the two-stage selection/scheduling pipeline."""

    def __init__(self, profiles: Sequence[ClientProfile] | ClientPoolState):
        if isinstance(profiles, ClientPoolState):
            self._pool_state = profiles
        else:
            self._pool_state = ClientPoolState.from_profiles(profiles)
        self._registry: dict[int, ClientProfile] | None = None
        self._registry_version: int | None = None

    @property
    def pool_state(self) -> ClientPoolState:
        return self._pool_state

    @pool_state.setter
    def pool_state(self, pool: ClientPoolState) -> None:
        """Replacing the pool drops every cached view derived from it."""
        self._pool_state = pool
        self._registry = None
        self._registry_version = None

    @property
    def registry(self) -> dict[int, ClientProfile]:
        """Dataclass compatibility view of the pool (built lazily so a
        100k-client ``ClientPoolState`` provider never materializes
        profiles unless asked). A read-only snapshot, rebuilt whenever
        the pool is replaced or mutated (churn — the pool's ``version``
        counter is the staleness signal): mutate ``pool_state``, not
        these profiles, to affect selection."""
        version = self._pool_state.version
        if self._registry is None or self._registry_version != version:
            self._registry = {
                p.client_id: p for p in self._pool_state.to_profiles()}
            self._registry_version = version
        return self._registry

    # -- Stage 1 -------------------------------------------------------------
    def select_pool(self, task: TaskRequest, method: str = "greedy",
                    rng: np.random.Generator | None = None) -> SelectionResult:
        return select_initial_pool(
            self.pool_state, budget=task.budget, n_star=task.n_star,
            thresholds=task.thresholds, method=method, rng=rng)

    def select_pools_batch(self, tasks: Sequence[TaskRequest]
                           ) -> list[SelectionResult]:
        """Stage 1 for many concurrent tasks in one batched sweep.

        Per-task threshold masks are computed vectorized over the shared
        pool, then a single jit+vmap greedy (engine.greedy_knapsack_batch)
        solves every task's knapsack at once — the multi-tenant serving
        path (``ServiceScheduler`` intake). Per-task feasibility (n*,
        Eq. 11) is applied afterwards. Selected ids come back in pool
        order (same set, totals and feasibility as per-task
        ``select_pool``, which returns greedy pick order).
        """
        if not tasks:
            return []
        pool = self.pool_state
        budgets = np.array([t.budget for t in tasks], dtype=np.float64)
        valid = np.stack([pool.threshold_mask(t.thresholds) for t in tasks])
        masks, _, _ = engine.greedy_knapsack_batch(
            pool.overall, pool.costs, budgets, valid)
        results: list[SelectionResult] = []
        for t, task in enumerate(tasks):
            n_kept = int(valid[t].sum())
            if n_kept < task.n_star:
                results.append(SelectionResult(
                    [], 0.0, 0.0, feasible=False,
                    note=f"only {n_kept} clients pass thresholds, "
                         f"need {task.n_star}"))
                continue
            sel = masks[t]
            res = SelectionResult(
                pool.client_ids[sel].tolist(),
                float(pool.overall[sel].sum()),
                float(pool.costs[sel].sum()))
            if len(res.selected) < task.n_star:
                res.feasible = False
                floor = pool.budget_floor(task.n_star, valid[t])
                res.note = (f"budget {task.budget} selects only "
                            f"{len(res.selected)} < n*={task.n_star} "
                            f"clients; Eq.(11) floor is {floor:.1f}")
            results.append(res)
        return results

    # -- Stage 2 (one period) --------------------------------------------------
    def schedule_period(self, pool_ids: Sequence[int], task: TaskRequest,
                        rng: np.random.Generator) -> ScheduleResult:
        """Algorithm 1 over the task's current pool. Raises ``KeyError``
        if any id is not registered (e.g. churned out mid-task)."""
        rows = self.pool_state.positions(sorted(pool_ids))
        if task.scheduler == "random":
            hists = {int(self.pool_state.client_ids[r]):
                     self.pool_state.histograms[r] for r in rows}
            return random_subsets(hists, task.subset_size, rng)
        # array-native: hand the scheduler (ids, H) columns directly
        subpool = (self.pool_state.client_ids[rows],
                   self.pool_state.histograms[rows])
        return generate_subsets(subpool, n=task.subset_size,
                                delta=task.subset_delta, x_star=task.x_star,
                                nid_threshold=task.nid_threshold)

    # -- Full service loop (deprecated shim over the lifecycle) ----------------
    def run_task(self, task: TaskRequest, trainer,
                 availability_fn: Callable[[int, int], bool] | None = None,
                 stop_fn: Callable[[dict], bool] | None = None,
                 method: str = "greedy") -> ServiceRunResult:
        """Deprecated: blocking convenience wrapper over the stepped
        lifecycle (``lifecycle.submit`` + ``lifecycle.drain``).

        Produces results bit-for-bit identical to the pre-redesign
        blocking loop (kept as :meth:`run_task_legacy`; equivalence is
        tested). New code should drive the lifecycle directly — it adds
        checkpoint/resume (``TaskState.to_arrays``), multi-tenant
        serving (``ServiceScheduler``) and churn, which this blocking
        call structurally cannot express.
        """
        warnings.warn(
            "FLServiceProvider.run_task is deprecated; use "
            "repro.core.lifecycle (submit/step/drain, or ServiceScheduler "
            "for multi-tenant serving) instead",
            DeprecationWarning, stacklevel=2)
        state = lifecycle.submit(self, task, method=method)
        state, _ = lifecycle.drain(self, state, trainer,
                                   availability_fn=availability_fn,
                                   stop_fn=stop_fn)
        return lifecycle.as_run_result(state)

    def run_task_legacy(self, task: TaskRequest, trainer,
                        availability_fn: Callable[[int, int], bool] | None = None,
                        stop_fn: Callable[[dict], bool] | None = None,
                        method: str = "greedy") -> ServiceRunResult:
        """The pre-redesign blocking loop, verbatim — the reference the
        ``submit``/``step``/``drain`` lifecycle is equivalence-tested
        against (tests/test_lifecycle.py). Not a production path.

        availability_fn(client_id, period) -> bool models clients going
        offline (paper: conflicting schedules / battery / network).

        With ``task.round_chunk > 1`` and a chunk-capable trainer
        (``run_rounds``), consecutive rounds of a period are dispatched
        in chunks of up to ``round_chunk``; the host checkpoint between
        chunks runs stop_fn and the reputation bookkeeping. Chunks never
        straddle a period boundary (the pool update must see every round
        of the period). If stop_fn fires mid-chunk, logging stops at
        that round but the model has already advanced to the chunk end —
        known round budgets should use ``task.max_rounds``, which caps
        the chunk so the model never trains past it.
        """
        rng = np.random.default_rng(task.seed)
        pool_sel = self.select_pool(task, method=method, rng=rng)
        if not pool_sel.feasible:
            return ServiceRunResult(pool_sel, [], [], {})
        pool = set(pool_sel.selected)
        tracker = ReputationTracker(pool_sel.selected,
                                    suspension_periods=task.suspension_periods,
                                    rep_threshold=task.rep_threshold)
        data_sizes = self.pool_state.data_sizes()
        chunk_size = max(1, int(task.round_chunk)) \
            if hasattr(trainer, "run_rounds") else 1
        rounds: list[RoundLog] = []
        schedules: list[ScheduleResult] = []
        global_round = 0
        for period in range(task.max_periods):
            if not pool:
                break
            if task.max_rounds is not None and global_round >= task.max_rounds:
                break
            sched = self.schedule_period(sorted(pool), task, rng)
            schedules.append(sched)
            stop = False
            t = 0
            while t < len(sched.subsets) and not stop:
                limit = chunk_size
                if task.max_rounds is not None:
                    remaining = task.max_rounds - global_round
                    if remaining <= 0:
                        stop = True
                        break
                    limit = min(limit, remaining)
                chunk = sched.subsets[t:t + limit]
                ws = []
                for subset in chunk:
                    sizes = data_sizes[self.pool_state.positions(subset)]
                    ws.append(sizes / np.maximum(sizes.sum(), 1e-12))
                if chunk_size > 1:
                    results = trainer.run_rounds(global_round, chunk, ws)
                else:
                    results = [trainer(global_round, chunk[0], ws[0])]
                for j, (returned, q_vals, metrics) in enumerate(results):
                    subset = chunk[j]
                    for i, cid in enumerate(subset):
                        tracker.record_round(cid, bool(returned[i]),
                                             q_value=float(q_vals[i]))
                    rounds.append(RoundLog(period, global_round, list(subset),
                                           ws[j], sched.nids[t + j], metrics))
                    global_round += 1
                    if stop_fn is not None and stop_fn(metrics):
                        stop = True
                        break
                t += len(chunk)
            avail = {cid: (availability_fn(cid, period + 1)
                           if availability_fn else True)
                     for cid in tracker.records}
            pool = tracker.update_pool(pool, avail) & set(pool_sel.selected)
            if stop:
                break
        return ServiceRunResult(pool_sel, rounds, schedules, tracker.scores())
