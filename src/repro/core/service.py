"""FL service provider orchestration (paper §III Fig. 1).

The provider owns the shared, churnable client registry
(``ClientPoolState`` struct-of-arrays; the ``ClientProfile`` dict
remains as a compatibility view) and the two-stage pipeline: stage-1
pool selection (single-task ``select_pool`` or the batched multi-tenant
``select_pools_batch``) and stage-2 per-period scheduling
(``schedule_period``). Both stages dispatch through the pluggable
policy registry (:mod:`repro.core.policy`): every ``TaskRequest``
names its ``selection_policy`` / ``scheduling_policy`` pair, so tasks
running different strategies coexist on one provider.

Task orchestration itself lives in :mod:`repro.core.lifecycle`: a task
is an explicit :class:`~repro.core.lifecycle.TaskState` advanced by
``submit`` / ``step`` / ``drain`` (resumable, multi-tenant via
``ServiceScheduler``). The blocking :meth:`FLServiceProvider.run_task`
survives as a deprecated shim over ``submit`` + ``drain`` that
reproduces the pre-redesign results bit-for-bit;
:meth:`run_task_legacy` preserves the original loop as the equivalence
reference (tests/test_lifecycle.py), not a production path.

Model training is injected as a :class:`~repro.core.lifecycle.Trainer`
(``run_rounds``) — or a legacy per-round callback, wrapped via
``single_round_adapter`` — so the same orchestration drives the paper's
CNN experiments, the LM federated runs and unit tests with stub
trainers.

Robustness (ISSUE-7, docs/robustness.md): a trainer carrying an active
:class:`~repro.core.faults.FaultPlan` switches the lifecycle's
dispatch/collect split into fault mode — over-scheduled subsets,
first-k/deadline round closes, quorum retries with exponential backoff
and a terminal DEGRADED phase — while the provider's shared pool picks
up in-flight pins (deferred deregister) and per-client timing stats
that the ``straggler_aware`` selection policy consumes. With no plan
(or an inactive one) every path below is bit-identical to pre-fault
behavior; ``run_task_legacy`` remains the frozen equivalence reference.
"""
from __future__ import annotations

import warnings
from typing import Callable, Sequence

import numpy as np

from . import lifecycle
from .criteria import ClientProfile
from .lifecycle import RoundLog, ServiceRunResult, TaskRequest
from .policy import resolve_scheduling_policy, resolve_selection_policy
from .pool import ClientPoolState
from .reputation import ReputationTracker
from .scheduling import ScheduleResult
from .selection import SelectionResult

# Legacy alias: a per-round trainer callback
# (round, subset, weights) -> (returned flags, q values, metrics).
TrainerFn = Callable[[int, Sequence[int], np.ndarray], tuple]


class FLServiceProvider:
    """Client registry + the two-stage selection/scheduling pipeline."""

    def __init__(self, profiles: Sequence[ClientProfile] | ClientPoolState):
        if isinstance(profiles, ClientPoolState):
            self._pool_state = profiles
        else:
            self._pool_state = ClientPoolState.from_profiles(profiles)
        self._registry: dict[int, ClientProfile] | None = None
        self._registry_version: int | None = None

    @property
    def pool_state(self) -> ClientPoolState:
        return self._pool_state

    @pool_state.setter
    def pool_state(self, pool: ClientPoolState) -> None:
        """Replacing the pool drops every cached view derived from it."""
        self._pool_state = pool
        self._registry = None
        self._registry_version = None

    @property
    def registry(self) -> dict[int, ClientProfile]:
        """Dataclass compatibility view of the pool (built lazily so a
        100k-client ``ClientPoolState`` provider never materializes
        profiles unless asked). A read-only snapshot, rebuilt whenever
        the pool is replaced or mutated (churn — the pool's ``version``
        counter is the staleness signal): mutate ``pool_state``, not
        these profiles, to affect selection."""
        version = self._pool_state.version
        if self._registry is None or self._registry_version != version:
            self._registry = {
                p.client_id: p for p in self._pool_state.to_profiles()}
            self._registry_version = version
        return self._registry

    # -- Stage 1 -------------------------------------------------------------
    def select_pool(self, task: TaskRequest, method: str | None = None,
                    rng: np.random.Generator | None = None) -> SelectionResult:
        """Stage 1 through the task's registered selection policy
        (``task.selection_policy``, default ``paper_greedy``). An
        explicitly passed legacy ``method`` ("greedy" | "dp" |
        "random") always wins over the field."""
        policy = resolve_selection_policy(task, method)
        return policy.select(self.pool_state, task, rng)

    def select_pools_batch(self, tasks: Sequence[TaskRequest],
                           rngs: Sequence[np.random.Generator] | None = None,
                           ) -> list[SelectionResult]:
        """Stage 1 for many concurrent tasks in one batched sweep.

        Tasks are grouped by their resolved selection policy and each
        group is served by the policy's ``select_batch`` — for the
        default ``paper_greedy`` that is one vectorized threshold sweep
        plus a single jit+vmap greedy (engine.greedy_knapsack_batch)
        solving every task's knapsack at once — the multi-tenant
        serving path (``ServiceScheduler`` intake). Per-task
        feasibility (n*, Eq. 11) is applied by the policies. For
        ``paper_greedy``, selected ids come back in pool order (same
        set, totals and feasibility as per-task ``select_pool``, which
        returns greedy pick order).

        ``rngs`` supplies each task's generator (stochastic policies
        consume it exactly as a per-task ``select_pool`` would — the
        scheduler intake passes the tenants' own state rngs so batched
        and serial intake stay bit-identical); defaults to fresh
        ``default_rng(task.seed)`` per task, matching a fresh
        ``lifecycle.submit``.
        """
        if not tasks:
            return []
        if rngs is None:
            rngs = [np.random.default_rng(t.seed) for t in tasks]
        groups: dict[str, list[int]] = {}
        for i, t in enumerate(tasks):
            groups.setdefault(resolve_selection_policy(t).name, []).append(i)
        results: list[SelectionResult | None] = [None] * len(tasks)
        for name, idxs in groups.items():
            out = resolve_selection_policy(tasks[idxs[0]]).select_batch(
                self.pool_state, [tasks[i] for i in idxs],
                [rngs[i] for i in idxs])
            for i, res in zip(idxs, out):
                results[i] = res
        return results

    # -- Stage 2 (one period) --------------------------------------------------
    def schedule_period(self, pool_ids: Sequence[int], task: TaskRequest,
                        rng: np.random.Generator,
                        policy_state: dict | None = None) -> ScheduleResult:
        """One period's schedule through the task's registered
        scheduling policy (``task.scheduling_policy``; the legacy
        ``scheduler=\"random\"`` field maps to ``random_partition``).
        Raises ``KeyError`` if any id is not registered (e.g. churned
        out mid-task). ``policy_state`` is the task's policy cursor
        dict (``TaskState.policy_state``) — stateful policies read and
        mutate it; omitting it gives a stateless one-shot call."""
        rows = self.pool_state.positions(sorted(pool_ids))
        policy = resolve_scheduling_policy(task)
        return policy.schedule(
            self.pool_state.client_ids[rows], self.pool_state.histograms[rows],
            task, rng, {} if policy_state is None else policy_state)

    # -- Full service loop (deprecated shim over the lifecycle) ----------------
    def run_task(self, task: TaskRequest, trainer,
                 availability_fn: Callable[[int, int], bool] | None = None,
                 stop_fn: Callable[[dict], bool] | None = None,
                 method: str | None = None) -> ServiceRunResult:
        """Deprecated: blocking convenience wrapper over the stepped
        lifecycle (``lifecycle.submit`` + ``lifecycle.drain``).

        Produces results bit-for-bit identical to the pre-redesign
        blocking loop (kept as :meth:`run_task_legacy`; equivalence is
        tested). New code should drive the lifecycle directly — it adds
        checkpoint/resume (``TaskState.to_arrays``), multi-tenant
        serving (``ServiceScheduler``) and churn, which this blocking
        call structurally cannot express.
        """
        warnings.warn(
            "FLServiceProvider.run_task is deprecated; use "
            "repro.core.lifecycle (submit/step/drain, or ServiceScheduler "
            "for multi-tenant serving) instead",
            DeprecationWarning, stacklevel=2)
        state = lifecycle.submit(self, task, method=method)
        state, _ = lifecycle.drain(self, state, trainer,
                                   availability_fn=availability_fn,
                                   stop_fn=stop_fn)
        return lifecycle.as_run_result(state)

    def run_task_legacy(self, task: TaskRequest, trainer,
                        availability_fn: Callable[[int, int], bool] | None = None,
                        stop_fn: Callable[[dict], bool] | None = None,
                        method: str | None = None) -> ServiceRunResult:
        """The pre-redesign blocking loop, verbatim — the reference the
        ``submit``/``step``/``drain`` lifecycle is equivalence-tested
        against (tests/test_lifecycle.py). Not a production path.

        availability_fn(client_id, period) -> bool models clients going
        offline (paper: conflicting schedules / battery / network).

        With ``task.round_chunk > 1`` and a chunk-capable trainer
        (``run_rounds``), consecutive rounds of a period are dispatched
        in chunks of up to ``round_chunk``; the host checkpoint between
        chunks runs stop_fn and the reputation bookkeeping. Chunks never
        straddle a period boundary (the pool update must see every round
        of the period). If stop_fn fires mid-chunk, logging stops at
        that round but the model has already advanced to the chunk end —
        known round budgets should use ``task.max_rounds``, which caps
        the chunk so the model never trains past it.
        """
        rng = np.random.default_rng(task.seed)
        pool_sel = self.select_pool(task, method=method, rng=rng)
        if not pool_sel.feasible:
            return ServiceRunResult(pool_sel, [], [], {})
        pool = set(pool_sel.selected)
        policy_state: dict = {}        # stateful scheduling-policy cursors
        tracker = ReputationTracker(pool_sel.selected,
                                    suspension_periods=task.suspension_periods,
                                    rep_threshold=task.rep_threshold)
        data_sizes = self.pool_state.data_sizes()
        chunk_size = max(1, int(task.round_chunk)) \
            if hasattr(trainer, "run_rounds") else 1
        rounds: list[RoundLog] = []
        schedules: list[ScheduleResult] = []
        global_round = 0
        for period in range(task.max_periods):
            if not pool:
                break
            if task.max_rounds is not None and global_round >= task.max_rounds:
                break
            sched = self.schedule_period(sorted(pool), task, rng,
                                         policy_state=policy_state)
            schedules.append(sched)
            stop = False
            t = 0
            while t < len(sched.subsets) and not stop:
                limit = chunk_size
                if task.max_rounds is not None:
                    remaining = task.max_rounds - global_round
                    if remaining <= 0:
                        stop = True
                        break
                    limit = min(limit, remaining)
                chunk = sched.subsets[t:t + limit]
                ws = []
                for subset in chunk:
                    sizes = data_sizes[self.pool_state.positions(subset)]
                    ws.append(sizes / np.maximum(sizes.sum(), 1e-12))
                if chunk_size > 1:
                    results = trainer.run_rounds(global_round, chunk, ws)
                else:
                    results = [trainer(global_round, chunk[0], ws[0])]
                for j, (returned, q_vals, metrics) in enumerate(results):
                    subset = chunk[j]
                    for i, cid in enumerate(subset):
                        tracker.record_round(cid, bool(returned[i]),
                                             q_value=float(q_vals[i]))
                    rounds.append(RoundLog(period, global_round, list(subset),
                                           ws[j], sched.nids[t + j], metrics))
                    global_round += 1
                    if stop_fn is not None and stop_fn(metrics):
                        stop = True
                        break
                t += len(chunk)
            avail = {cid: (availability_fn(cid, period + 1)
                           if availability_fn else True)
                     for cid in tracker.records}
            pool = tracker.update_pool(pool, avail) & set(pool_sel.selected)
            if stop:
                break
        return ServiceRunResult(pool_sel, rounds, schedules, tracker.scores())
