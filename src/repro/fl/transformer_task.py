"""Federated transformer fine-tuning with LoRA adapter deltas.

The FL path trained only the MNIST CNN; this module opens the LM
workload the ROADMAP calls for: clients fine-tune a small transformer
from the config zoo (``configs.smollm_360m`` reduced) on class-
conditional bigram streams (``data.synthetic.make_lm_data``), but the
*server state that crosses the wire is only a LoRA adapter tree* — the
frozen backbone stays on every device and client deltas are adapter
deltas, which is what makes the compressed update plane
(fl.compression, ``TaskRequest.compression``) representative: payloads
are small to begin with and top-k/int8 codecs act on exactly what a
production cross-device system would ship.

LoRA here is the functional formulation: an adapter for target leaf W
(stacked over layers, shape ``(L, din, ...)``) is a pair
``a (L, din, r)``, ``b (L, r, dout)`` and the effective weight is
``W + (alpha/r)·a@b`` reshaped back — ``b`` starts at zero so the
merged model equals the backbone at round 0. Targets are leaves whose
*first* trailing dim is the input dim (wq/wv/w_up by default), so one
einsum covers attention and MLP uniformly.

:class:`TransformerFLSim` subclasses the device data-plane trainer
(fl.simulation.DeviceFLSim): same segmentation DP, async
dispatch/collect split, arrival masks and export/import checkpoint
seam — only the model plumbing (adapter params, LM gather, merged
next-token eval) differs. :func:`make_transformer_fl` builds the whole
bundle (trainer + pool + partitions) for tests and benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smollm_360m
from repro.data.synthetic import LMData, make_lm_data
from repro.fl import device_data
from repro.fl.partition import partition_labels
from repro.fl.round import make_fl_rounds_scan
from repro.fl.simulation import DeviceFLSim, SimConfig, pool_from_partition
from repro.models import transformer
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class LoraConfig:
    """Adapter shape: rank-r factors on ``targets`` (paths into one
    stacked layer dict, ``<block>/<leaf>``). Every default target has
    its input dim first (wq/wv: (d, heads, hd); w_up: (d, d_ff)), the
    layout :func:`merge_adapters` assumes."""
    rank: int = 4
    alpha: float = 8.0
    targets: tuple = ("attn/wq", "attn/wv", "mlp/w_up")


def _get_leaf(layers, path: str):
    node = layers
    for part in path.split("/"):
        node = node[part]
    return node


def init_adapters(layers, lora: LoraConfig, key):
    """Adapter tree for stacked layer params: ``{path: {"a", "b"}}``.

    ``a`` ~ N(0, 0.02), ``b`` = 0 (standard LoRA init: the merged model
    starts exactly at the backbone). f32 regardless of backbone dtype —
    adapters are the optimizer-visible state.
    """
    out = {}
    for i, path in enumerate(lora.targets):
        leaf = _get_leaf(layers, path)
        L, din = leaf.shape[0], leaf.shape[1]
        dout = int(np.prod(leaf.shape[2:]))
        ka = jax.random.fold_in(key, i)
        out[path] = {
            "a": 0.02 * jax.random.normal(ka, (L, din, lora.rank),
                                          jnp.float32),
            "b": jnp.zeros((L, lora.rank, dout), jnp.float32),
        }
    return out


def merge_adapters(params, adapters, lora: LoraConfig):
    """Backbone params with each target leaf replaced by
    ``W + (alpha/rank)·a@b`` (reshaped, cast back to W.dtype). Pure
    function of (params, adapters), so it vmaps/grads through — client
    training differentiates the merged forward wrt the adapters only.
    """
    scale = lora.alpha / lora.rank
    layers = {k: (dict(v) if isinstance(v, dict) else v)
              for k, v in params["layers"].items()}
    for path, ab in adapters.items():
        block, leaf_name = path.split("/")
        base = layers[block][leaf_name]
        delta = jnp.einsum("lir,lro->lio", ab["a"], ab["b"]) * scale
        layers[block][leaf_name] = (base + delta.reshape(base.shape)
                                    .astype(base.dtype))
    return {**params, "layers": layers}


def reduced_lm_config(vocab_size: int = 64,
                      num_layers: int = 2) -> ModelConfig:
    """The federated LM backbone: SmolLM-360M's architecture reduced to
    CPU-smoke size (2 heads x 64 head dim, f32)."""
    return smollm_360m.config().reduced(num_layers=num_layers,
                                        d_model=128, vocab=vocab_size)


class TransformerFLSim(DeviceFLSim):
    """Device-resident federated LoRA fine-tuning trainer.

    ``self.params`` is the *adapter* tree (the server state: what client
    deltas perturb, what FedAdam/FedYogi steps, what format-4
    checkpoints carry); the frozen backbone is closed over by the loss.
    Everything else — chunk segmentation, async dispatch/collect,
    fault-mode arrival masks, export/import — is inherited from
    :class:`~repro.fl.simulation.DeviceFLSim`.
    """

    def __init__(self, model_cfg: ModelConfig, data: LMData, parts,
                 test: LMData, sim: SimConfig = SimConfig(),
                 lora: LoraConfig = LoraConfig(),
                 pad_subset_to: int | None = None, fault_plan=None,
                 compression: str | None = None,
                 server_opt: str | None = None):
        from repro import optim
        self.cfg = model_cfg
        self.lora = lora
        self.pad_subset_to = pad_subset_to
        self.fault_plan = fault_plan
        self.base_key = jax.random.PRNGKey(sim.seed)
        kb, ka = jax.random.split(jax.random.PRNGKey(sim.seed))
        self.base_params = transformer.init_params(model_cfg, kb)
        self.params = init_adapters(self.base_params["layers"], lora, ka)
        self._server_opt = None if server_opt is None \
            else optim.make(server_opt, sim.server_lr)
        self.opt_state = None if self._server_opt is None \
            else self._server_opt.init(self.params)
        self.data = device_data.DeviceLMDataset.stage(data, parts)

        base = self.base_params

        def loss(adapters, batch):
            merged = merge_adapters(base, adapters, lora)
            return transformer.loss_fn(model_cfg, merged, batch)

        self.chunk_fn = make_fl_rounds_scan(
            loss, local_lr=sim.local_lr, local_steps=sim.local_steps,
            batch_size=sim.batch_size, server_lr=sim.server_lr,
            dropout_rate=sim.dropout_rate, compression=compression,
            server_opt=self._server_opt,
            gather_fn=device_data.gather_lm_batches)

        # deterministic eval: next-token accuracy of the merged model
        # over the full held-out set (no sampling rng — resume-exact)
        self.sim = sim
        self.history = []
        self._test_seqs = jnp.asarray(test.tokens)

        def eval_fn(adapters, seqs):
            merged = merge_adapters(base, adapters, lora)
            logits, _ = transformer.forward(model_cfg, merged, seqs[:, :-1])
            return (logits.argmax(-1) == seqs[:, 1:]).mean()

        self._eval_fn = jax.jit(eval_fn)

    def _enqueue_eval(self, params, n: int = 1024):
        """Next-token accuracy on the full cached test set
        (unmaterialized device scalar; deterministic, no rng draw)."""
        return self._eval_fn(params, self._test_seqs)

    def evaluate(self, n: int = 1024) -> float:
        return float(self._enqueue_eval(self.params))


def make_transformer_fl(n_clients: int = 20, n_train: int = 400,
                        n_test: int = 120, seq_len: int = 16,
                        vocab_size: int = 64, noniid: str = "type2",
                        num_layers: int = 2, seed: int = 0,
                        sim: SimConfig | None = None,
                        lora: LoraConfig = LoraConfig(),
                        pad_subset_to: int | None = None,
                        compression: str | None = None,
                        server_opt: str | None = None,
                        fault_plan=None) -> dict:
    """Build the full federated LM bundle: reduced SmolLM backbone,
    bigram LM data split train/test, a paper-style non-iid partition
    with its client pool (latent bigram classes are the scheduler's
    labels), and a ready :class:`TransformerFLSim`.

    Returns ``{"trainer", "pool", "parts", "cfg", "data", "test"}`` —
    enough to drive ``core.lifecycle`` directly (tests, benchmarks).
    """
    if sim is None:
        sim = SimConfig(batch_size=4, local_steps=2, local_lr=5.0,
                        server_lr=1.0, dropout_rate=0.0, eval_every=10_000,
                        seed=seed)
    cfg = reduced_lm_config(vocab_size, num_layers)
    full = make_lm_data(n_train + n_test, seq_len, vocab_size, seed=seed)
    data = LMData(full.tokens[:n_train], full.labels[:n_train],
                  full.num_classes, vocab_size)
    test = LMData(full.tokens[n_train:], full.labels[n_train:],
                  full.num_classes, vocab_size)
    parts = partition_labels(data.labels, n_clients, noniid,
                             data.num_classes, seed=seed)
    pool = pool_from_partition(data.labels, parts, data.num_classes,
                               seed=seed)
    trainer = TransformerFLSim(cfg, data, parts, test, sim, lora,
                               pad_subset_to=pad_subset_to,
                               fault_plan=fault_plan,
                               compression=compression,
                               server_opt=server_opt)
    return {"trainer": trainer, "pool": pool, "parts": parts, "cfg": cfg,
            "data": data, "test": test}
