"""End-to-end FL simulation: glues core.service (selection/scheduling)
to real JAX training (fl.round) over partitioned synthetic data —
the machinery behind the paper's Figs. 5/6 experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientPoolState, ClientProfile, FLServiceProvider,
                        TaskRequest)
from repro.core.criteria import NUM_CRITERIA, data_dist_score, overall_score, linear_cost
from repro.data.synthetic import ClassificationData
from repro.fl.partition import client_histograms
from repro.fl.round import make_fl_round
from repro.models import cnn


@dataclasses.dataclass
class SimConfig:
    batch_size: int = 16
    local_steps: int = 2
    local_lr: float = 0.1
    server_lr: float = 1.0
    dropout_rate: float = 0.05        # paper: 5% of clients drop per period
    eval_every: int = 5
    seed: int = 0


def pool_from_partition(labels, parts, num_classes,
                        seed: int = 0) -> ClientPoolState:
    """Array-native client pool whose data criteria come from the real
    partition and whose resource criteria are random (paper §VIII-A)."""
    rng = np.random.default_rng(seed)
    hists = client_histograms(labels, parts, num_classes)
    n = len(parts)
    scores = rng.uniform(0.3, 1.0, size=(n, NUM_CRITERIA))
    H = np.stack([hists[i] for i in range(n)])
    sizes = H.sum(axis=1)
    scores[:, 7] = sizes / max(sizes.max(), 1)
    scores[:, 8] = data_dist_score(H)
    costs = linear_cost(overall_score(scores), 2.0, 5.0, integer=True)
    return ClientPoolState(np.arange(n, dtype=np.int64), scores, H, costs)


def profiles_from_partition(labels, parts, num_classes,
                            seed: int = 0) -> list[ClientProfile]:
    """Dataclass adapter over :func:`pool_from_partition` (same draws)."""
    return pool_from_partition(labels, parts, num_classes, seed).to_profiles()


class FLClassificationSim:
    """Federated CNN training over a partitioned synthetic dataset."""

    def __init__(self, model_cfg: cnn.CNNConfig, data: ClassificationData,
                 parts: list[np.ndarray], test: ClassificationData,
                 sim: SimConfig = SimConfig()):
        self.cfg = model_cfg
        self.data = data
        self.parts = parts
        self.test = test
        self.sim = sim
        self.rng = np.random.default_rng(sim.seed)
        self.params = cnn.init_params(model_cfg, jax.random.PRNGKey(sim.seed))
        self.round_fn = make_fl_round(
            lambda p, b: cnn.loss_fn(model_cfg, p, b),
            local_lr=sim.local_lr, local_steps=sim.local_steps,
            server_lr=sim.server_lr)
        self._eval_fn = jax.jit(
            lambda p, images, labels: (cnn.forward(model_cfg, p, images)
                                       .argmax(-1) == labels).mean())
        self.history: list[dict] = []
        self.dropped_this_round: set[int] = set()

    # -- batching -----------------------------------------------------------
    def _client_batches(self, subset):
        E, b = self.sim.local_steps, self.sim.batch_size
        imgs, labs = [], []
        for cid in subset:
            idx = self.parts[cid]
            take = self.rng.choice(idx, size=E * b, replace=len(idx) < E * b)
            imgs.append(self.data.images[take].reshape(E, b, *self.data.images.shape[1:]))
            labs.append(self.data.labels[take].reshape(E, b))
        return {"images": jnp.asarray(np.stack(imgs)),
                "labels": jnp.asarray(np.stack(labs))}

    def evaluate(self, n: int = 1024) -> float:
        idx = self.rng.choice(len(self.test.labels), size=min(n, len(self.test.labels)),
                              replace=False)
        return float(self._eval_fn(self.params,
                                   jnp.asarray(self.test.images[idx]),
                                   jnp.asarray(self.test.labels[idx])))

    # -- TrainerFn for core.service.FLServiceProvider -----------------------
    def trainer(self, rnd: int, subset, weights) -> tuple:
        K = len(subset)
        drop = self.rng.uniform(size=K) < self.sim.dropout_rate
        if drop.all():
            drop[self.rng.integers(K)] = False
        batches = self._client_batches(subset)
        mask = jnp.asarray((~drop).astype(np.float32))
        self.params, info = self.round_fn(self.params, batches,
                                          jnp.asarray(weights), mask)
        metrics = {"round": rnd, "loss": float(info["mean_loss"])}
        if rnd % self.sim.eval_every == 0:
            metrics["accuracy"] = self.evaluate()
        self.history.append(metrics)
        q = np.asarray(info["q_values"])
        return (~drop), q, metrics


def run_fl_experiment(kind: str, noniid: str, n_clients: int = 100,
                      rounds: int = 30, scheduler: str = "mkp",
                      n_train: int = 6000, n_test: int = 1500,
                      subset_size: int = 10, sim: SimConfig = SimConfig(),
                      seed: int = 0) -> dict:
    """One learning-curve run (paper Figs. 5/6): returns history + config."""
    from repro.data.synthetic import make_classification_data
    from repro.fl.partition import partition_labels

    # one generation pass -> shared class prototypes; split train/test
    full = make_classification_data(kind, n_train + n_test, seed=seed)
    data = full.subset(np.arange(n_train))
    test = full.subset(np.arange(n_train, n_train + n_test))
    parts = partition_labels(data.labels, n_clients, noniid,
                             data.num_classes, seed=seed)
    pool = pool_from_partition(data.labels, parts, data.num_classes,
                               seed=seed)
    provider = FLServiceProvider(pool)
    model_cfg = cnn.MNIST_CNN if kind == "mnist" else cnn.CIFAR_CNN
    simul = FLClassificationSim(model_cfg, data, parts, test, sim)

    task = TaskRequest(budget=1e9, n_star=n_clients, subset_size=subset_size,
                       subset_delta=3, x_star=3, max_periods=10_000,
                       scheduler=scheduler, seed=seed)
    result = provider.run_task(
        task, simul.trainer,
        stop_fn=lambda m: m["round"] + 1 >= rounds)
    return {"history": simul.history, "service": result,
            "final_accuracy": simul.evaluate(), "scheduler": scheduler,
            "noniid": noniid, "kind": kind}
