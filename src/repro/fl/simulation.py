"""End-to-end FL simulation: glues the core service lifecycle
(selection/scheduling, ``core.lifecycle``) to real JAX training
(fl.round) over partitioned synthetic data — the machinery behind the
paper's Figs. 5/6 experiments.

Two trainers implement the explicit ``core.lifecycle.Trainer`` protocol
(``run_rounds`` — no more ``hasattr`` duck typing):

- :class:`FLClassificationSim` — the legacy host-loop data plane: every
  round assembles client batches on the host (numpy fancy-indexing per
  client) and ships them to the device, one dispatch per round
  (``run_rounds`` loops internally, so chunked schedules work but gain
  nothing). Kept as the equivalence/benchmark baseline; a plain *sync*
  ``Trainer``, exercising the lifecycle's eager dispatch fallback.
- :class:`DeviceFLSim` — the device-resident data plane: the partitioned
  dataset is staged on device once (fl.device_data.DeviceDataset) and
  ``run_rounds`` drives S rounds per dispatch through the chunked
  ``lax.scan`` driver (fl.round.make_fl_rounds_scan) with on-device
  batch gather, dropout masks, and the fused aggregation+quality pass.
  Driven with ``TaskRequest.round_chunk > 1`` rounds per dispatch. An
  ``AsyncTrainer``: ``dispatch_rounds`` enqueues the chunk and returns
  unmaterialized device arrays, ``collect`` blocks — the overlapped
  ``ServiceScheduler`` keeps many tasks' chunks in flight at once.

Both trainers draw batch positions and dropout from the same
slot-keyed PRNG stream (fl.device_data.sample_positions), so with equal
seeds they see identical schedules, masks, and batches — the
device-vs-legacy equivalence tests rely on this.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientPoolState, ClientProfile, FLServiceProvider,
                        TaskRequest, lifecycle)
from repro.core.criteria import NUM_CRITERIA, data_dist_score, overall_score, linear_cost
from repro.data.synthetic import ClassificationData
from repro.fl import device_data
from repro.fl.partition import client_histograms
from repro.fl.round import make_fl_round, make_fl_rounds_scan
from repro.models import cnn


@dataclasses.dataclass
class SimConfig:
    batch_size: int = 16
    local_steps: int = 2
    local_lr: float = 0.1
    server_lr: float = 1.0
    dropout_rate: float = 0.05        # paper: 5% of clients drop per period
    eval_every: int = 5
    seed: int = 0


def pool_from_partition(labels, parts, num_classes,
                        seed: int = 0) -> ClientPoolState:
    """Array-native client pool whose data criteria come from the real
    partition and whose resource criteria are random (paper §VIII-A)."""
    rng = np.random.default_rng(seed)
    hists = client_histograms(labels, parts, num_classes)
    n = len(parts)
    scores = rng.uniform(0.3, 1.0, size=(n, NUM_CRITERIA))
    H = np.stack([hists[i] for i in range(n)])
    sizes = H.sum(axis=1)
    scores[:, 7] = sizes / max(sizes.max(), 1)
    scores[:, 8] = data_dist_score(H)
    costs = linear_cost(overall_score(scores), 2.0, 5.0, integer=True)
    return ClientPoolState(np.arange(n, dtype=np.int64), scores, H, costs)


def profiles_from_partition(labels, parts, num_classes,
                            seed: int = 0) -> list[ClientProfile]:
    """Dataclass adapter over :func:`pool_from_partition` (same draws)."""
    return pool_from_partition(labels, parts, num_classes, seed).to_profiles()


class _EvalCache:
    """Shared eval/history machinery for both trainers: the test set is
    cached on device once (evaluate() only ships sampled indices), and
    per-round metrics/history bookkeeping lives in one place so the two
    data planes cannot drift apart."""

    def _init_eval(self, model_cfg: cnn.CNNConfig, test: ClassificationData,
                   sim: SimConfig, impl: str = "reference"):
        self.sim = sim
        self._eval_fn = jax.jit(
            lambda p, images, labels: (cnn.forward(model_cfg, p, images,
                                                   impl=impl)
                                       .argmax(-1) == labels).mean())
        self._test_images = jnp.asarray(test.images)
        self._test_labels = jnp.asarray(test.labels)
        self._eval_rng = np.random.default_rng(sim.seed)
        self.history: list[dict] = []

    def _enqueue_eval(self, params, n: int = 1024):
        """Enqueue an accuracy evaluation of ``params`` on the cached
        device test set; returns the *unmaterialized* device scalar (the
        caller decides when to block). Consumes one draw from the eval
        rng stream, so enqueue order must match record order."""
        m = len(self._test_labels)
        idx = jnp.asarray(self._eval_rng.choice(m, size=min(n, m),
                                                replace=False))
        return self._eval_fn(params,
                             jnp.take(self._test_images, idx, axis=0),
                             jnp.take(self._test_labels, idx, axis=0))

    def evaluate(self, n: int = 1024) -> float:
        """Accuracy of the current params on a sampled test subset
        (blocking)."""
        return float(self._enqueue_eval(self.params, n))

    def _record(self, rnd: int, loss, accuracy=None) -> dict:
        """Append round ``rnd`` to ``history``. Eval rounds take their
        accuracy from ``accuracy`` when the caller already enqueued the
        evaluation (the async collect path), else evaluate now."""
        metrics = {"round": rnd, "loss": float(loss)}
        if rnd % self.sim.eval_every == 0:
            metrics["accuracy"] = (self.evaluate() if accuracy is None
                                   else float(accuracy))
        self.history.append(metrics)
        return metrics


class FLClassificationSim(_EvalCache):
    """Federated CNN training over a partitioned synthetic dataset —
    the legacy host-loop data plane (per-round host batch assembly +
    host→device transfer; one jit dispatch per round).

    Implements the ``core.lifecycle.Trainer`` protocol: ``run_rounds``
    processes a chunk sequentially (one dispatch per round, so chunking
    changes nothing but the grouping of trainer calls)."""

    # lifecycle fault mode may pass per-round arrival masks (first-k
    # collect; see core.faults / docs/robustness.md)
    accepts_arrivals = True

    def __init__(self, model_cfg: cnn.CNNConfig, data: ClassificationData,
                 parts: list[np.ndarray], test: ClassificationData,
                 sim: SimConfig = SimConfig(), fault_plan=None):
        self.cfg = model_cfg
        self.data = data
        self.parts = parts
        self.test = test
        self.fault_plan = fault_plan
        self.base_key = jax.random.PRNGKey(sim.seed)
        self.params = cnn.init_params(model_cfg, jax.random.PRNGKey(sim.seed))
        self.round_fn = make_fl_round(
            lambda p, b: cnn.loss_fn(model_cfg, p, b),
            local_lr=sim.local_lr, local_steps=sim.local_steps,
            server_lr=sim.server_lr)
        self._init_eval(model_cfg, test, sim)
        self.dropped_this_round: set[int] = set()

    # -- batching -----------------------------------------------------------
    def _round_draws(self, rnd: int, K: int):
        """Shared slot-keyed PRNG draws for round ``rnd`` (host copy)."""
        mask_u, pos_u = device_data.sample_positions(
            self.base_key, rnd, K, self.sim.local_steps, self.sim.batch_size)
        return np.asarray(mask_u), np.asarray(pos_u)

    def _client_batches(self, subset, pos_u):
        E, b = self.sim.local_steps, self.sim.batch_size
        imgs, labs = [], []
        for i, cid in enumerate(subset):
            idx = self.parts[cid]
            pos = np.minimum((pos_u[i] * len(idx)).astype(np.int64),
                             len(idx) - 1)
            take = idx[pos.reshape(-1)]
            imgs.append(self.data.images[take].reshape(E, b, *self.data.images.shape[1:]))
            labs.append(self.data.labels[take].reshape(E, b))
        return {"images": jnp.asarray(np.stack(imgs)),
                "labels": jnp.asarray(np.stack(labs))}

    # -- core.lifecycle.Trainer protocol -------------------------------------
    def __call__(self, rnd: int, subset, weights, arrival=None) -> tuple:
        K = len(subset)
        mask_u, pos_u = self._round_draws(rnd, K)
        arr = None if arrival is None \
            else jnp.asarray(np.asarray(arrival, dtype=np.float32))
        mask_np = np.asarray(device_data.dropout_mask(
            jnp.asarray(mask_u), jnp.ones(K), self.sim.dropout_rate,
            arrival=arr))
        batches = self._client_batches(subset, pos_u)
        mask = jnp.asarray(mask_np)
        self.params, info = self.round_fn(self.params, batches,
                                          jnp.asarray(weights), mask)
        metrics = self._record(rnd, info["mean_loss"])
        q = np.asarray(info["q_values"])
        return mask_np > 0, q, metrics

    def run_rounds(self, start_round: int, subsets: Sequence[Sequence[int]],
                   weights: Sequence[np.ndarray],
                   arrivals: Sequence[np.ndarray] | None = None
                   ) -> list[tuple]:
        """Sequential host loop over the chunk (one dispatch per round)."""
        return [self(start_round + j, subset, np.asarray(w),
                     arrival=None if arrivals is None else arrivals[j])
                for j, (subset, w) in enumerate(zip(subsets, weights))]

    @property
    def trainer(self):
        """The object itself (callable per-round AND a Trainer), kept
        for source compatibility with the pre-protocol API."""
        return self


class DeviceFLSim(_EvalCache):
    """Device-resident trainer: staged dataset + chunked scan driver.

    Implements the ``core.lifecycle.AsyncTrainer`` protocol — the
    chunked ``run_rounds`` (driven with ``task.round_chunk > 1``) splits
    into ``dispatch_rounds`` (enqueue only, returns unmaterialized
    device arrays) and ``collect`` (block + bookkeeping), which lets the
    ``ServiceScheduler`` overlap this task's device work with other
    tasks' — plus the legacy per-round callable form (``__call__``).

    Subsets sized n±δ share one static client axis K per dispatch
    (padding is semantics-free thanks to slot-keyed randomness), and a
    chunk may be split into several dispatches: a small DP picks the
    segmentation minimizing padded-slot waste plus a fixed per-dispatch
    cost, so e.g. a [5,5,5,11]-sized chunk trains as [5,5,5]+[11]
    rather than all-padded-to-11. ``pad_subset_to`` caps K.

    Eval rounds (``rnd % eval_every == 0``) force a split so the
    dispatch ends exactly at the eval round — accuracy is always
    measured with that round's params, matching the host-loop trainer.
    """

    # estimated fixed cost of one extra dispatch, in units of one
    # padded client-slot-round of training compute (sets how eagerly
    # the segmentation DP splits a chunk to avoid padding waste)
    DISPATCH_COST = 4.0

    # lifecycle fault mode may pass per-round arrival masks, threaded
    # into the scan as an extra schedule key (only fault-mode dispatches
    # carry it, so the no-fault jit trace is untouched)
    accepts_arrivals = True

    # class-level defaults so subclasses with their own __init__
    # (TransformerFLSim) stay on the unsharded plane: no mesh, client
    # axis padded to multiples of 2
    _mesh = None
    _k_quantum = 2

    def __init__(self, model_cfg: cnn.CNNConfig, data: ClassificationData,
                 parts: list[np.ndarray], test: ClassificationData,
                 sim: SimConfig = SimConfig(), impl: str = "auto",
                 pad_subset_to: int | None = None,
                 fused_quality: bool = True, fault_plan=None,
                 compression: str | None = None,
                 server_opt: str | None = None, mesh=None):
        from repro import optim
        self.cfg = model_cfg
        self.pad_subset_to = pad_subset_to
        self.fault_plan = fault_plan
        self.base_key = jax.random.PRNGKey(sim.seed)
        self.params = cnn.init_params(model_cfg, jax.random.PRNGKey(sim.seed))
        self.data = device_data.DeviceDataset.stage(data, parts)
        # compressed update plane (docs/compression.md): `compression`
        # is the TaskRequest spec string; `server_opt` names a
        # repro.optim server optimizer (fedadam/fedyogi) applied to the
        # pseudo-gradient with lr = sim.server_lr. Both default off and
        # the default trace is bit-identical to the uncompressed plane.
        self._server_opt = None if server_opt is None \
            else optim.make(server_opt, sim.server_lr)
        self.opt_state = None if self._server_opt is None \
            else self._server_opt.init(self.params)
        # `mesh` (a jax.sharding.Mesh, e.g. launch.mesh.make_host_mesh())
        # swaps in the client-sharded scan: the round's client axis
        # splits over the mesh's data axes, one psum'd aggregate per
        # round (docs/placement.md). Out of the sharded variant's
        # scope: compression, server optimizers, simulated dropout.
        self._mesh = mesh
        self._k_quantum = 2
        if mesh is not None:
            from repro.fl.round import make_fl_rounds_scan_sharded
            from repro.sharding import specs as sharding_specs
            if compression is not None or server_opt is not None:
                raise ValueError("mesh-sharded DeviceFLSim supports the "
                                 "uncompressed plain-SGD plane only")
            if sim.dropout_rate:
                raise ValueError("mesh-sharded DeviceFLSim does not "
                                 "simulate client dropout (the all-"
                                 "dropped fallback is global across K); "
                                 "set sim.dropout_rate = 0.0")
            n = sharding_specs.mesh_axis_size(mesh,
                                              sharding_specs.data_axes(mesh))
            self._k_quantum = max(2, int(n))
            self.chunk_fn = make_fl_rounds_scan_sharded(
                lambda p, b: cnn.loss_fn(model_cfg, p, b, impl=impl),
                local_lr=sim.local_lr, local_steps=sim.local_steps,
                batch_size=sim.batch_size, server_lr=sim.server_lr,
                mesh=mesh)
        else:
            self.chunk_fn = make_fl_rounds_scan(
                lambda p, b: cnn.loss_fn(model_cfg, p, b, impl=impl),
                local_lr=sim.local_lr, local_steps=sim.local_steps,
                batch_size=sim.batch_size, server_lr=sim.server_lr,
                dropout_rate=sim.dropout_rate, fused_quality=fused_quality,
                compression=compression, server_opt=self._server_opt)
        self._init_eval(model_cfg, test, sim, impl=impl)

    def _k_pad(self, k: int) -> int:
        """Padded client axis for a segment whose largest subset has k
        clients: next multiple of 2 (fewer distinct compile shapes),
        capped at pad_subset_to but never below k — then, in
        mesh-sharded mode, rounded up to a multiple of the data-axis
        size (each shard takes K/n client slots)."""
        pad = -(-k // 2) * 2
        if self.pad_subset_to is not None:
            pad = min(pad, self.pad_subset_to)
        pad = max(pad, k)
        if self._k_quantum > 2:
            pad = -(-pad // self._k_quantum) * self._k_quantum
        return pad

    def place_on(self, device_index: int) -> None:
        """``ServiceScheduler`` placement hook (docs/placement.md): move
        the server state, staged dataset and eval cache to
        ``jax.devices()[device_index]``. Committed inputs make every
        later ``chunk_fn`` dispatch execute on that device, so tenants
        placed on different devices compute concurrently. No-op in
        mesh-sharded mode — the sharded scan already spans devices."""
        if self._mesh is not None:
            return
        dev = jax.devices()[device_index]
        self.params = jax.device_put(self.params, dev)
        if self.opt_state is not None:
            self.opt_state = jax.device_put(self.opt_state, dev)
        self.data = jax.device_put(self.data, dev)
        self.base_key = jax.device_put(self.base_key, dev)
        self._test_images = jax.device_put(self._test_images, dev)
        self._test_labels = jax.device_put(self._test_labels, dev)

    def _segment(self, sizes: list[int]) -> list[int]:
        """Optimal consecutive segmentation of one chunk (DP): minimize
        Σ over segments of [DISPATCH_COST + Σ_t (K_seg − k_t)] where
        K_seg pads the segment's max size. Returns segment lengths."""
        n = len(sizes)
        best = [0.0] + [float("inf")] * n       # best[i]: cost of sizes[:i]
        cut = [0] * (n + 1)
        for i in range(1, n + 1):
            kmax = 0
            waste = 0.0
            for j in range(i - 1, -1, -1):      # segment sizes[j:i]
                if sizes[j] > kmax:              # pad grew: recompute
                    kmax = sizes[j]
                    kp = self._k_pad(kmax)
                    waste = float(sum(kp - s for s in sizes[j:i]))
                else:
                    waste += self._k_pad(kmax) - sizes[j]
                cost = best[j] + self.DISPATCH_COST + waste
                if cost < best[i]:
                    best[i] = cost
                    cut[i] = j
        lengths: list[int] = []
        i = n
        while i > 0:
            lengths.append(i - cut[i])
            i = cut[i]
        return lengths[::-1]

    # -- async trainer protocol (core.lifecycle.AsyncTrainer) ----------------
    def dispatch_rounds(self, start_round: int,
                        subsets: Sequence[Sequence[int]],
                        weights: Sequence[np.ndarray],
                        arrivals: Sequence[np.ndarray] | None = None
                        ) -> list[tuple]:
        """Enqueue ``len(subsets)`` consecutive rounds WITHOUT blocking
        on the device: every segment's ``chunk_fn`` call (and, for
        segments ending at an eval round, its accuracy evaluation) is
        dispatched back-to-back, and the returned handle holds only
        unmaterialized device arrays. Chunks are split after every eval
        round (so accuracies use that round's params) and per the
        padding-vs-dispatch-cost DP (``_segment``), exactly like the
        blocking path — ``run_rounds`` is ``collect`` of this."""
        handles = []
        seg_start = 0
        for e in range(len(subsets)):
            if (start_round + e) % self.sim.eval_every == 0 \
                    or e == len(subsets) - 1:
                block = subsets[seg_start:e + 1]
                r = start_round + seg_start
                for length in self._segment([len(s) for s in block]):
                    handles.append(self._enqueue_segment(
                        r, subsets[seg_start:seg_start + length],
                        weights[seg_start:seg_start + length],
                        None if arrivals is None
                        else arrivals[seg_start:seg_start + length]))
                    r += length
                    seg_start += length
        return handles

    def collect(self, handles: list[tuple]) -> list[tuple]:
        """Materialize a ``dispatch_rounds`` handle: block on each
        segment's device arrays in dispatch order and emit the per-round
        ``(returned, q_values, metrics)`` tuples + history records."""
        out = []
        for start_round, subsets, info, eval_acc in handles:
            masks = np.asarray(info["masks"])
            qs = np.asarray(info["q_values"])
            losses = np.asarray(info["mean_loss"])
            wire = np.asarray(info["bytes"]) if "bytes" in info else None
            for t, subset in enumerate(subsets):
                k = len(subset)
                # only a segment's final round can be an eval round (the
                # split above guarantees it), so eval_acc is unambiguous
                metrics = self._record(start_round + t, losses[t],
                                       accuracy=eval_acc)
                if wire is not None:
                    metrics["bytes"] = float(wire[t])
                out.append((masks[t, :k] > 0, qs[t, :k], metrics))
        return out

    def run_rounds(self, start_round: int, subsets: Sequence[Sequence[int]],
                   weights: Sequence[np.ndarray],
                   arrivals: Sequence[np.ndarray] | None = None
                   ) -> list[tuple]:
        """Blocking chunk execution: enqueue everything, then collect."""
        return self.collect(self.dispatch_rounds(start_round, subsets,
                                                 weights, arrivals))

    def _enqueue_segment(self, start_round: int,
                         subsets: Sequence[Sequence[int]],
                         weights: Sequence[np.ndarray],
                         arrivals: Sequence[np.ndarray] | None = None
                         ) -> tuple:
        """One device dispatch for ``len(subsets)`` consecutive rounds;
        returns ``(start_round, subsets, info, eval_acc)`` with ``info``
        (and ``eval_acc``, when the segment ends at an eval round) still
        on device. The eval is enqueued *here*, against this segment's
        output params, because the next segment's dispatch donates that
        buffer (``chunk_fn`` has ``donate_argnums=(0,)``)."""
        S = len(subsets)
        K = self._k_pad(max(len(s) for s in subsets))
        rows = np.zeros((S, K), dtype=np.int32)
        w = np.zeros((S, K), dtype=np.float32)
        active = np.zeros((S, K), dtype=np.float32)
        arr = None if arrivals is None \
            else np.zeros((S, K), dtype=np.float32)
        for t, (subset, wt) in enumerate(zip(subsets, weights)):
            k = len(subset)
            rows[t, :k] = np.asarray(subset, dtype=np.int32)
            w[t, :k] = np.asarray(wt, dtype=np.float32)
            active[t, :k] = 1.0
            if arr is not None:
                arr[t, :k] = np.asarray(arrivals[t], dtype=np.float32)
        schedule = {"rows": jnp.asarray(rows), "weights": jnp.asarray(w),
                    "active": jnp.asarray(active),
                    "round_ids": jnp.asarray(
                        start_round + np.arange(S, dtype=np.int32))}
        if arr is not None:
            # extra pytree key => separate jit trace; the no-fault trace
            # (and its results) are untouched
            schedule["arrival"] = jnp.asarray(arr)
        if self._server_opt is None:
            self.params, info = self.chunk_fn(self.params, self.data,
                                              schedule, self.base_key)
        else:
            (self.params, self.opt_state), info = self.chunk_fn(
                (self.params, self.opt_state), self.data, schedule,
                self.base_key)
        eval_acc = None
        if (start_round + S - 1) % self.sim.eval_every == 0:
            eval_acc = self._enqueue_eval(self.params)
        return start_round, list(subsets), info, eval_acc

    # -- server-state checkpointing (lifecycle format 4) ---------------------
    def export_state(self) -> dict:
        """Flat ``{path: numpy}`` snapshot of the server state (model
        params + optimizer moments when a server optimizer is active);
        rides ``TaskState.trainer_state`` in format-4 checkpoints
        (``lifecycle.save_state(..., trainer=...)``)."""
        from repro import checkpoint
        out = checkpoint.tree_to_arrays(self.params, "params")
        if self.opt_state is not None:
            out.update(checkpoint.tree_to_arrays(self.opt_state, "opt"))
        return out

    def import_state(self, arrays: dict) -> None:
        """Inverse of :meth:`export_state` (lifecycle resume path)."""
        from repro import checkpoint
        self.params = checkpoint.tree_from_arrays(self.params, arrays,
                                                  "params")
        if self.opt_state is not None:
            self.opt_state = checkpoint.tree_from_arrays(self.opt_state,
                                                         arrays, "opt")

    # -- per-round TrainerFn protocol (round_chunk == 1) ---------------------
    def __call__(self, rnd: int, subset, weights) -> tuple:
        return self.run_rounds(rnd, [subset], [np.asarray(weights)])[0]

    @property
    def trainer(self):
        """The object itself: a chunk-capable ``core.lifecycle.Trainer``
        (and still callable per-round for legacy call sites)."""
        return self


def run_fl_experiment(kind: str, noniid: str, n_clients: int = 100,
                      rounds: int = 30, scheduler: str = "mkp",
                      n_train: int = 6000, n_test: int = 1500,
                      subset_size: int = 10, subset_delta: int = 3,
                      sim: SimConfig = SimConfig(),
                      seed: int = 0, data_plane: str = "host",
                      round_chunk: int = 8,
                      budget: float = 1e9, n_star: int | None = None,
                      selection_policy: str | None = None,
                      scheduling_policy: str | None = None,
                      fault_plan=None, overschedule_factor: float = 1.0,
                      quorum_frac: float = 0.0,
                      collect_deadline: float = 0.0,
                      compression: str | None = None,
                      server_opt: str | None = None) -> dict:
    """One learning-curve run (paper Figs. 5/6): returns history + config.

    ``data_plane="host"`` uses the legacy per-round host-loop trainer;
    ``"device"`` stages the dataset on device and runs ``round_chunk``
    rounds per dispatch through the chunked scan driver.

    ``selection_policy`` / ``scheduling_policy`` pick registered
    ``core.policy`` strategies (with ``budget`` binding, different
    selection policies admit different pools — the policy-comparison
    study in ``benchmarks/bench_policies.py``); unset (``None``), the
    legacy ``scheduler`` alias decides (``"random"`` ->
    ``random_partition``) — an explicit name wins over the alias.
    ``n_star`` defaults to ``n_clients`` when the budget is
    unconstrained (the paper's full-pool setup) and to 1 otherwise.

    ``fault_plan`` (a :class:`repro.core.faults.FaultPlan`) injects
    deterministic stragglers/crashes/outages; ``overschedule_factor`` /
    ``quorum_frac`` / ``collect_deadline`` are the matching
    ``TaskRequest`` mitigation knobs (docs/robustness.md). All default
    off — the no-fault path is bit-identical to before.
    """
    from repro.data.synthetic import make_classification_data
    from repro.fl.partition import partition_labels

    # one generation pass -> shared class prototypes; split train/test
    full = make_classification_data(kind, n_train + n_test, seed=seed)
    data = full.subset(np.arange(n_train))
    test = full.subset(np.arange(n_train, n_train + n_test))
    parts = partition_labels(data.labels, n_clients, noniid,
                             data.num_classes, seed=seed)
    pool = pool_from_partition(data.labels, parts, data.num_classes,
                               seed=seed)
    provider = FLServiceProvider(pool)
    model_cfg = cnn.MNIST_CNN if kind == "mnist" else cnn.CIFAR_CNN
    if data_plane == "device":
        simul = DeviceFLSim(model_cfg, data, parts, test, sim,
                            pad_subset_to=subset_size + subset_delta,
                            fault_plan=fault_plan, compression=compression,
                            server_opt=server_opt)
    elif data_plane == "host":
        if compression or server_opt:
            raise ValueError("compression/server_opt need the device "
                             "data plane (data_plane='device')")
        simul = FLClassificationSim(model_cfg, data, parts, test, sim,
                                    fault_plan=fault_plan)
        round_chunk = 1
    else:
        raise ValueError(f"unknown data_plane {data_plane!r}")

    if n_star is None:
        n_star = n_clients if budget >= 1e9 else 1
    task = TaskRequest(budget=budget, n_star=n_star, subset_size=subset_size,
                       subset_delta=subset_delta, x_star=3, max_periods=10_000,
                       scheduler=scheduler, seed=seed,
                       round_chunk=round_chunk, max_rounds=rounds,
                       selection_policy=selection_policy,
                       scheduling_policy=scheduling_policy,
                       overschedule_factor=overschedule_factor,
                       quorum_frac=quorum_frac,
                       collect_deadline=collect_deadline,
                       compression=compression)
    state = lifecycle.submit(provider, task)
    state, _ = lifecycle.drain(provider, state, simul.trainer,
                               stop_fn=lambda m: m["round"] + 1 >= rounds)
    result = lifecycle.as_run_result(state)
    return {"history": simul.history, "service": result, "state": state,
            "final_accuracy": simul.evaluate(), "scheduler": scheduler,
            "noniid": noniid, "kind": kind}
