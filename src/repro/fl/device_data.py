"""Device-resident FL data plane: staged dataset + on-device batch gather.

The PR-1 trainer assembled every round's client batches on the host
(numpy fancy-indexing per client) and shipped them device-ward anew each
round. This module stages the partitioned dataset on device ONCE and
draws batches with a jit'd gather, so a whole scheduling period can run
with zero per-round host transfers (fl.round.make_fl_rounds_scan):

- :func:`repro.fl.partition.dense_index_pools` turns the ragged
  per-client index lists into a dense ``(n_clients, cap)`` pool matrix;
- :class:`DeviceDataset` holds images/labels/pools/sizes as device
  arrays (a NamedTuple, so it is a pytree and jit-traceable);
- :func:`sample_positions` derives per-round, per-slot randomness by
  key folding. Randomness is *slot-keyed* (one fold per client slot),
  so the draw for slot k is independent of how far the subset is padded
  — the host-loop trainer (K = true subset size) and the padded device
  scan (K = n+delta) see the same stream, which is what makes the
  device-vs-legacy equivalence tests exact;
- :func:`gather_batches` maps sampled positions to samples with two
  chained ``jnp.take`` gathers (pool row -> sample index -> image);
- :func:`dropout_mask` draws the paper's per-round client dropout
  (behavior b_t = 0) on device, guaranteeing at least one surviving
  client per round (slot 0 always holds a real client).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.partition import dense_index_pools


class DeviceDataset(NamedTuple):
    """Partitioned dataset staged on device once (tentpole step 1)."""
    images: jax.Array        # (N, H, W, C)
    labels: jax.Array        # (N,)
    pools: jax.Array         # (n_clients, cap) int32 sample-index pools
    sizes: jax.Array         # (n_clients,) int32 true pool sizes

    @classmethod
    def stage(cls, data, parts, cap: int | None = None) -> "DeviceDataset":
        """One-time host->device staging of a partitioned dataset."""
        pools, sizes = dense_index_pools(parts, cap=cap)
        return cls(jnp.asarray(data.images), jnp.asarray(data.labels),
                   jnp.asarray(pools), jnp.asarray(sizes))

    @property
    def n_clients(self) -> int:
        return self.pools.shape[0]


def slot_key(base_key, round_index, slot):
    """Key for (round, client-slot): fold round then slot."""
    return jax.random.fold_in(jax.random.fold_in(base_key, round_index), slot)


def sample_positions(base_key, round_index, n_slots: int, local_steps: int,
                     batch_size: int, slot_offset=0):
    """Per-slot uniforms for one round: ``(mask_u (K,), pos_u (K, E, b))``.

    ``mask_u`` drives the dropout draw, ``pos_u`` the batch-position
    draw. Values for slot k depend only on (base_key, round, k), never
    on ``n_slots`` — padding the subset does not perturb the stream.

    ``slot_offset`` shifts the slot ids: a client-sharded round scan
    (``fl.round.make_fl_rounds_scan_sharded``) passes each shard's
    global base slot so every shard draws the *global* slot's stream —
    keeping draws identical to the unsharded plane.
    """
    def one(slot):
        ku, kb = jax.random.split(slot_key(base_key, round_index, slot))
        return (jax.random.uniform(ku, ()),
                jax.random.uniform(kb, (local_steps, batch_size)))
    return jax.vmap(one)(jnp.arange(n_slots) + slot_offset)


def positions_to_indices(pools, sizes, rows, pos_u):
    """Map uniform draws to sample indices: ``(K, E, b)`` int32.

    pos = floor(u * size_k) in [0, size_k) — sampling with replacement
    from the client's true pool; dense-pool padding never selected.
    """
    sz = jnp.take(sizes, rows, axis=0).astype(jnp.float32)   # (K,)
    pos = jnp.floor(pos_u * sz[:, None, None]).astype(jnp.int32)
    pos = jnp.minimum(pos, (sz[:, None, None] - 1).astype(jnp.int32))
    pos = jnp.maximum(pos, 0)                                # empty-pool guard
    rowpools = jnp.take(pools, rows, axis=0)                 # (K, cap)
    flat = jnp.take_along_axis(rowpools, pos.reshape(pos.shape[0], -1), axis=1)
    return flat.reshape(pos.shape)


def gather_batches(data: DeviceDataset, rows, pos_u):
    """On-device batch assembly: ``{"images": (K,E,b,H,W,C), "labels": (K,E,b)}``."""
    idx = positions_to_indices(data.pools, data.sizes, rows, pos_u)
    flat = idx.reshape(-1)
    K, E, b = idx.shape
    imgs = jnp.take(data.images, flat, axis=0).reshape(
        K, E, b, *data.images.shape[1:])
    labs = jnp.take(data.labels, flat, axis=0).reshape(K, E, b)
    return {"images": imgs, "labels": labs}


class DeviceLMDataset(NamedTuple):
    """Token-sequence twin of :class:`DeviceDataset` for the federated
    LM plane (fl.transformer_task): ``seqs`` holds packed next-token
    sequences of length S+1 (input = ``[:, :-1]``, target = ``[:, 1:]``)
    as produced by ``data.synthetic.make_lm_data``. Pool/size semantics
    are identical, so :func:`sample_positions` /
    :func:`positions_to_indices` are shared with the image plane and
    ``fl.round`` only sees the ``.sizes`` attribute either way."""
    seqs: jax.Array          # (N, S+1) int32 packed token sequences
    labels: jax.Array        # (N,) latent class (partitioning only)
    pools: jax.Array         # (n_clients, cap) int32 sample-index pools
    sizes: jax.Array         # (n_clients,) int32 true pool sizes

    @classmethod
    def stage(cls, data, parts, cap: int | None = None) -> "DeviceLMDataset":
        """Stage ``data.synthetic.LMData`` (``.tokens``/``.labels``)."""
        pools, sizes = dense_index_pools(parts, cap=cap)
        return cls(jnp.asarray(data.tokens), jnp.asarray(data.labels),
                   jnp.asarray(pools), jnp.asarray(sizes))

    @property
    def n_clients(self) -> int:
        return self.pools.shape[0]


def gather_lm_batches(data: DeviceLMDataset, rows, pos_u):
    """LM batch assembly hook for ``make_fl_rounds_scan(gather_fn=...)``:
    ``{"tokens": (K,E,b,S), "targets": (K,E,b,S)}`` int32 (the
    models.transformer.loss_fn batch contract, next-token shifted)."""
    idx = positions_to_indices(data.pools, data.sizes, rows, pos_u)
    flat = idx.reshape(-1)
    K, E, b = idx.shape
    seqs = jnp.take(data.seqs, flat, axis=0).reshape(
        K, E, b, data.seqs.shape[1])
    return {"tokens": seqs[..., :-1], "targets": seqs[..., 1:]}


def dropout_mask(mask_u, active, dropout_rate: float, arrival=None):
    """Per-round client dropout mask (K,) f32.

    A client drops when its uniform < dropout_rate. ``active`` (K,) f32
    marks real (non-padding) slots. If every active client would drop,
    slot 0 is kept (schedules place real clients first) — mirroring the
    legacy trainer's "never lose the whole round" rule.

    ``arrival`` (K,) f32, when given, additionally masks clients that
    had not reported by the round's collect close (lifecycle fault-mode
    first-k semantics, docs/robustness.md): a non-arrived client can
    neither contribute nor be the fallback, so the fallback becomes the
    first *arrived* active slot. With ``arrival=None`` the computation
    is exactly the pre-fault one.
    """
    act = active > 0
    if arrival is None:
        fallback = (jnp.arange(mask_u.shape[0]) == 0) & act
    else:
        act = act & (arrival > 0)
        # first arrived active slot (argmax of the bool mask); when no
        # client arrived at all, `& act` still zeroes the fallback and
        # the round contributes nothing — the host side only dispatches
        # quorum-met rounds, so that case never reaches the aggregate
        fallback = (jnp.arange(mask_u.shape[0]) == jnp.argmax(act)) & act
    keep = (mask_u >= dropout_rate) & act
    keep = jnp.where(keep.any(), keep, fallback)
    return keep.astype(jnp.float32)
