"""Compressed client-update codecs for the device round plane.

The round scan (fl.round) ships each client's flattened delta — a row
of the stacked ``(K, P)`` update matrix — to the server. This module
defines what actually crosses the wire when ``TaskRequest.compression``
is set, and how the server aggregates directly from those payloads:

==============  ====================================================
spec string     wire format (per client)
==============  ====================================================
``none``        raw row: P values in the delta dtype (no codec; the
                round scan's trace is bit-identical to the
                uncompressed plane — asserted in tests)
``int8``        per-chunk symmetric int8: P int8 values +
                ceil(P/chunk) f32 scales (kernels.ops.quantize_i8)
``topk:F``      magnitude top-k, k = ceil(F·P): k f32 values +
                k int32 indices (kernels.ops.topk_sparsify)
``topk:F+int8`` top-k then int8 over the packed values: k int8 +
                ceil(k/chunk) f32 scales + k int32 indices
==============  ====================================================

Options append ``@chunk=N`` to override the 256-lane quant chunk, e.g.
``"int8@chunk=512"`` or ``"topk:0.05+int8@chunk=128"``.

Aggregation (:func:`aggregate_compressed`) is the server's view: int8
payloads go through the fused ``fedavg_agg_quality_i8`` kernel
(dequantize-in-kernel, no (K, P) f32 materialization); top-k payloads
are densified by scatter and reuse ``fedavg_agg_quality`` — exact with
respect to the decoded updates either way, so the paper's per-client
quality cosines q_k are computed on what the server actually received.

:func:`bytes_per_client` is the accounting column threaded into round
metrics ("bytes" = arrived clients × per-client payload).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.kernels import ops as kops

_KINDS = ("none", "int8", "topk", "topk_int8")


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Parsed ``TaskRequest.compression`` string."""
    kind: str = "none"            # one of _KINDS
    topk_frac: float = 0.0        # fraction of P kept (topk kinds)
    chunk: int = 256              # quantization chunk width (int8 kinds)

    @property
    def active(self) -> bool:
        return self.kind != "none"

    def k_for(self, p: int) -> int:
        """Number of kept entries per row for a P-wide flat delta."""
        return max(1, min(p, int(math.ceil(self.topk_frac * p))))

    def describe(self) -> str:
        if self.kind == "none":
            return "none"
        base = self.kind if self.kind != "topk_int8" else \
            f"topk:{self.topk_frac:g}+int8"
        if self.kind == "topk":
            base = f"topk:{self.topk_frac:g}"
        if "int8" in self.kind and self.chunk != 256:
            base += f"@chunk={self.chunk}"
        return base

    @classmethod
    def parse(cls, spec) -> "CompressionSpec":
        """Accepts None, a CompressionSpec, or a spec string."""
        if spec is None:
            return cls()
        if isinstance(spec, cls):
            return spec
        if not isinstance(spec, str):
            raise TypeError(f"compression spec must be str or "
                            f"CompressionSpec, got {type(spec).__name__}")
        text = spec.strip().lower()
        if text in ("", "none"):
            return cls()
        chunk = 256
        if "@" in text:
            text, _, opt = text.partition("@")
            key, _, val = opt.partition("=")
            if key != "chunk":
                raise ValueError(f"unknown compression option {opt!r}")
            chunk = int(val)
            if chunk <= 0:
                raise ValueError("chunk must be positive")
        if text == "int8":
            return cls(kind="int8", chunk=chunk)
        if text.startswith("topk:"):
            body = text[len("topk:"):]
            quant = body.endswith("+int8")
            if quant:
                body = body[: -len("+int8")]
            frac = float(body)
            if not 0.0 < frac <= 1.0:
                raise ValueError(f"topk fraction must be in (0, 1], "
                                 f"got {frac}")
            return cls(kind="topk_int8" if quant else "topk",
                       topk_frac=frac, chunk=chunk)
        raise ValueError(f"unknown compression spec {spec!r}")


def bytes_per_client(spec: CompressionSpec, p: int,
                     raw_itemsize: int = 4) -> int:
    """Wire bytes one client uploads for a P-entry flat delta."""
    if not spec.active:
        return p * raw_itemsize
    if spec.kind == "int8":
        return p + 4 * _n_chunks(p, spec.chunk)
    k = spec.k_for(p)
    if spec.kind == "topk":
        return 4 * k + 4 * k                       # f32 values + i32 indices
    # topk_int8: int8 values + chunk scales + i32 indices
    return k + 4 * _n_chunks(k, spec.chunk) + 4 * k


def _n_chunks(p: int, chunk: int) -> int:
    return -(-p // chunk)


# ---------------------------------------------------------------------------
# Codec round-trip (what the server decodes from the wire)
# ---------------------------------------------------------------------------

def compress(flat, spec: CompressionSpec, *, interpret=None):
    """flat: (K, P) stacked client deltas -> payload dict.

    Keys by kind — int8: {"values" i8, "scales" f32}; topk:
    {"values" f32, "indices" i32}; topk_int8: {"values" i8,
    "scales" f32, "indices" i32}.
    """
    if not spec.active:
        return {"values": flat}
    if spec.kind == "int8":
        v, s = kops.quantize_i8(flat, chunk=spec.chunk, interpret=interpret)
        return {"values": v, "scales": s}
    k = spec.k_for(flat.shape[1])
    vals, idx = kops.topk_sparsify(flat, k, interpret=interpret)
    if spec.kind == "topk":
        return {"values": vals, "indices": idx}
    qv, qs = kops.quantize_i8(vals, chunk=spec.chunk, interpret=interpret)
    return {"values": qv, "scales": qs, "indices": idx}


def decompress(payload, spec: CompressionSpec, p: int, *, interpret=None):
    """Payload dict -> the server's (K, P) f32 view of the deltas."""
    if not spec.active:
        return payload["values"]
    if spec.kind == "int8":
        return kops.dequantize_i8(payload["values"], payload["scales"],
                                  chunk=spec.chunk, interpret=interpret)
    vals = payload["values"]
    if spec.kind == "topk_int8":
        vals = kops.dequantize_i8(vals, payload["scales"],
                                  chunk=spec.chunk, interpret=interpret)
    return _densify(vals, payload["indices"], p)


def _densify(vals, idx, p: int):
    """Scatter (K, k) sparse values back to a dense (K, p) f32 matrix.

    Top-k indices are distinct within a row, so a plain ``.set`` scatter
    is exact.
    """
    K = vals.shape[0]
    rows = jnp.arange(K, dtype=jnp.int32)[:, None]
    dense = jnp.zeros((K, p), jnp.float32)
    return dense.at[rows, idx].set(vals.astype(jnp.float32))


def roundtrip(flat, spec: CompressionSpec, *, interpret=None):
    """compress → decompress: the lossy (K, P) f32 view in one call."""
    payload = compress(flat, spec, interpret=interpret)
    return decompress(payload, spec, flat.shape[1], interpret=interpret)


# ---------------------------------------------------------------------------
# Server-side aggregation directly from compressed payloads
# ---------------------------------------------------------------------------

def aggregate_compressed(flat, weights, spec: CompressionSpec, *,
                         interpret=None):
    """Weighted aggregate + quality Gram terms from compressed payloads.

    flat: (K, P) raw stacked deltas (what clients computed), weights:
    (K,) normalized p_k. The deltas are encoded per ``spec`` and the
    server aggregates what it decodes: int8 payloads stream through the
    fused ``fedavg_agg_quality_i8`` kernel; sparse payloads are
    densified and reuse ``fedavg_agg_quality``. Returns
    ``(agg (P,) f32, dots (K,), sq (K,), asq ())``.
    """
    payload = compress(flat, spec, interpret=interpret)
    if spec.kind == "int8":
        return kops.fedavg_agg_quality_i8(
            payload["values"], payload["scales"], weights,
            chunk=spec.chunk, interpret=interpret)
    decoded = decompress(payload, spec, flat.shape[1], interpret=interpret)
    return kops.fedavg_agg_quality(decoded, weights, interpret=interpret)
