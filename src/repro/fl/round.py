"""Federated rounds in JAX — three scales (DESIGN.md §4):

- ``make_fl_round``: true FedAvg semantics at simulation scale — every
  scheduled client gets its own parameter copy (vmap over the client
  axis), runs E local SGD steps, and the server aggregates weighted
  deltas (Pallas ``fedavg_agg`` on TPU) and applies the server LR
  (paper §III: w_{t+1} = w_t − η Δ_t). One dispatch per round; batches
  arrive from the caller (host- or device-assembled).

- ``make_fl_rounds_scan``: the device-resident round data plane — S
  rounds per dispatch via ``lax.scan`` over precomputed schedule arrays
  (padded subsets/weights from stage 2), with on-device batch gather
  (fl.device_data), on-device dropout masks, the fused aggregation +
  quality kernel (kernels.fedavg_agg_quality: one pass over the stacked
  deltas yields Δ_t and every q_t cosine), and ``donate_argnums`` on
  the params so the server state never round-trips the host. A host
  checkpoint between chunks (core.lifecycle with round_chunk>1) handles
  stop_fn/eval/reputation. ``chunk_fn`` is also the unit of *overlap*
  in the multi-tenant service: a jit'd call returns unmaterialized
  device arrays immediately (JAX async dispatch), so
  ``DeviceFLSim.dispatch_rounds`` can enqueue one task's chunk while
  another task's still computes — never force a result (``np.asarray``
  / ``float`` / ``block_until_ready``) inside this module; callers
  decide when to block (``collect``).

- ``make_fedsgd_step``: datacenter-scale one-local-step equivalent —
  per-client weights fold into the loss so a single data-parallel
  backward implements the paper's weighted aggregation exactly; this is
  the ``train_step`` that the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import device_data
from repro.kernels import ops as kops
from repro.optim import apply_updates, sgd


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_weighted_sum(trees_stacked, weights, use_kernel: bool = False):
    """Σ_k w_k · leaf[k] for every leaf with leading client axis K.

    Uses ``lax.dot_general`` with ``preferred_element_type=float32`` so
    accumulation happens in f32 *without* first materializing an f32
    copy of the stacked (K, P) tree (which doubled peak memory on bf16
    deltas); weights are cast to the leaf dtype instead.
    """
    if use_kernel:
        return kops.fedavg_agg_tree(trees_stacked, weights)

    def agg_leaf(leaf):
        K = leaf.shape[0]
        flat = leaf.reshape(K, -1)
        acc = jax.lax.dot_general(
            weights.astype(leaf.dtype), flat, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc.reshape(leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(agg_leaf, trees_stacked)


def flatten_stacked(trees_stacked):
    """Stacked pytree (leaves (K, ...)) -> ((K, P) array, unflatten).

    The fused aggregation+quality kernel wants one contiguous (K, P)
    matrix; ``unflatten`` restores a (P,) vector to the original tree
    structure/shapes/dtypes.
    """
    leaves, treedef = jax.tree_util.tree_flatten(trees_stacked)
    K = leaves[0].shape[0]
    ctype = jnp.result_type(*leaves)
    flats = [leaf.reshape(K, -1).astype(ctype) for leaf in leaves]
    sizes = [f.shape[1] for f in flats]
    splits = [int(s) for s in np.cumsum(sizes)[:-1]]
    shapes = [leaf.shape[1:] for leaf in leaves]
    dtypes = [leaf.dtype for leaf in leaves]

    def unflatten(vec):
        parts = jnp.split(vec, splits)
        out = [p.reshape(s).astype(d)
               for p, s, d in zip(parts, shapes, dtypes)]
        return jax.tree_util.tree_unflatten(treedef, out)

    return jnp.concatenate(flats, axis=1), unflatten


def _make_client_update(loss_fn: Callable, local_lr: float):
    """E local SGD steps for one client; returns (delta, mean_loss)."""
    opt = sgd(local_lr)

    def client_update(params, batches):
        state = opt.init(params)

        def step(carry, batch):
            p, s = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            upd, s = opt.update(grads, s, p)
            return (apply_updates(p, upd), s), loss

        (new_params, _), losses = jax.lax.scan(step, (params, state), batches)
        return tree_sub(params, new_params), losses.mean()

    return client_update


def _aggregate_and_quality(deltas, w, use_agg_kernel: bool,
                           fused_quality: bool):
    """Weighted aggregate Δ_t + per-client q_t = cos(Δ_t^(k), Δ_t).

    ``fused_quality`` routes through the single-pass aggregation +
    quality kernel (kernels.fedavg_agg_quality / its jnp oracle off-TPU);
    otherwise the legacy two-pass path: tree_weighted_sum then a vmapped
    cosine with the aggregate norm hoisted out of the K loop.
    """
    if fused_quality:
        flat, unflatten = flatten_stacked(deltas)
        agg_flat, dots, sq, asq = kops.fedavg_agg_quality(flat, w)
        q = dots / jnp.maximum(jnp.sqrt(sq) * jnp.sqrt(asq), 1e-12)
        return unflatten(agg_flat), q

    agg = tree_weighted_sum(deltas, w, use_agg_kernel)
    return agg, _quality_cosines(deltas, agg)


def _tree_dot(a, b):
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _quality_cosines(deltas, agg):
    """Per-client q_t = cos(Δ_t^(k), Δ_t) against a given aggregate —
    the two-pass quality path, with the aggregate norm hoisted out of
    the K loop. Factored out so the sharded scan can reuse it with a
    psum'd (globally replicated) aggregate over local client shards."""
    nb = jnp.sqrt(_tree_dot(agg, agg))  # hoisted: identical for every k

    def cos_one(k):
        dk = jax.tree_util.tree_map(lambda leaf: leaf[k], deltas)
        num = _tree_dot(dk, agg)
        na = jnp.sqrt(_tree_dot(dk, dk))
        return num / jnp.maximum(na * nb, 1e-12)

    K = jax.tree_util.tree_leaves(deltas)[0].shape[0]
    return jax.vmap(cos_one)(jnp.arange(K))


def make_fl_round(loss_fn: Callable, local_lr: float = 0.05,
                  local_steps: int = 1, server_lr: float = 1.0,
                  use_agg_kernel: bool = False,
                  fused_quality: bool = False):
    """Build a jit'd FedAvg round.

    loss_fn(params, batch) -> (loss, metrics). Client batches arrive
    stacked: every leaf (K, local_steps, ...). Returns
    round_fn(params, client_batches, weights, mask) -> (params, info)
    where ``mask`` (K,) zeroes out dropped clients (behavior b_t = 0) and
    info carries per-client deltas' cosine-to-global q_t (paper §IV-C).
    ``fused_quality`` computes Δ_t and all q_t in one pass over the
    stacked deltas (the device data plane's default).
    """
    client_update = _make_client_update(loss_fn, local_lr)

    @jax.jit
    def round_fn(params, client_batches, weights, mask):
        deltas, losses = jax.vmap(client_update, in_axes=(None, 0))(
            params, client_batches)
        w = weights * mask
        w = w / jnp.maximum(w.sum(), 1e-9)
        agg, q = _aggregate_and_quality(deltas, w, use_agg_kernel,
                                        fused_quality)
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p - server_lr * d).astype(p.dtype), params, agg)
        info = {"client_losses": losses, "q_values": q * mask,
                "mean_loss": jnp.sum(losses * w)}
        return new_params, info

    return round_fn


def make_fl_rounds_scan(loss_fn: Callable, local_lr: float = 0.05,
                        local_steps: int = 1, batch_size: int = 16,
                        server_lr: float = 1.0, dropout_rate: float = 0.0,
                        fused_quality: bool = True,
                        use_agg_kernel: bool = False,
                        compression=None, server_opt=None,
                        gather_fn: Callable | None = None):
    """Chunked multi-round driver: S rounds in ONE device dispatch.

    Returns ``chunk_fn(params, data, schedule, base_key)`` (jit'd, params
    donated) where

    - ``data`` is a :class:`repro.fl.device_data.DeviceDataset` (staged
      once; never re-transferred),
    - ``schedule`` is a dict of stacked per-round arrays from stage 2:
      ``rows (S, K)`` int32 positions into the dataset pools, ``weights
      (S, K)`` f32 FedAvg p_k, ``active (S, K)`` f32 padding mask
      (subsets sized n±δ are padded to a static K with actives first),
      ``round_ids (S,)`` int32 global round indices (PRNG folding —
      chunking-invariant randomness), plus — only under a lifecycle
      fault plan — ``arrival (S, K)`` f32 marking clients that reported
      by the round's collect close (late/dead clients are masked out of
      the aggregate on device; see docs/robustness.md),
    - ``base_key`` seeds batch sampling + dropout via per-(round, slot)
      key folds (fl.device_data.sample_positions).

    Each scan step gathers the round's client batches on device, draws
    the dropout mask on device, runs E local steps per client, and
    applies the fused aggregation+quality pass. Outputs stack across the
    chunk: ``(params', {"masks": (S,K), "q_values": (S,K),
    "client_losses": (S,K), "mean_loss": (S,)})``. The host only sees
    params/metrics at chunk boundaries (core.service round_chunk knob).

    Compressed update plane (docs/compression.md):

    - ``compression`` — a spec string / :class:`CompressionSpec`
      (``TaskRequest.compression``). When active, each round's stacked
      deltas are encoded per the spec, the server aggregates *from the
      compressed payloads* (fused int8 kernel, or densified top-k) and
      quality cosines are computed on the decoded updates; the per-round
      metrics gain a ``"bytes"`` column (arrived clients × per-client
      wire bytes). ``None``/"none" leaves the trace **bit-identical** to
      the uncompressed plane (asserted in tests/test_compression.py).
    - ``server_opt`` — a ``repro.optim`` Optimizer applied server-side
      to the pseudo-gradient Δ_t (FedAdam/FedYogi). The carry becomes
      ``(params, opt_state)``: ``chunk_fn((params, opt_state), ...)``
      returns ``((params', opt_state'), infos)``. ``server_lr`` is
      ignored in this mode (fold it into the optimizer's lr). ``None``
      keeps the plain SGD server step and the 1-ary carry.
    - ``gather_fn(data, rows, pos_u) -> batch tree`` — batch assembly
      hook; defaults to the image gather
      (:func:`repro.fl.device_data.gather_batches`). The LM plane passes
      :func:`repro.fl.device_data.gather_lm_batches`.
    """
    from repro.fl.compression import (CompressionSpec, aggregate_compressed,
                                      bytes_per_client)
    client_update = _make_client_update(loss_fn, local_lr)
    spec = CompressionSpec.parse(compression)
    gather = device_data.gather_batches if gather_fn is None else gather_fn

    @functools.partial(jax.jit, donate_argnums=(0,))
    def chunk_fn(carry, data, schedule, base_key):
        K = schedule["rows"].shape[1]
        # fault-mode schedules carry a per-round arrival mask (lifecycle
        # first-k collect, docs/robustness.md); its presence is a trace-
        # time pytree property, so the no-fault trace is unchanged
        has_arrival = "arrival" in schedule

        def one_round(carry, per_round):
            if server_opt is None:
                params, opt_state = carry, None
            else:
                params, opt_state = carry
            if has_arrival:
                rows, weights, active, rnd, arrival = per_round
            else:
                rows, weights, active, rnd = per_round
                arrival = None
            # a scheduled client with an empty pool cannot return an
            # update: treat its slot as inactive (b_t = 0, weight 0)
            # rather than silently training on the index-0 fallback.
            active = active * (jnp.take(data.sizes, rows, axis=0) > 0)
            mask_u, pos_u = device_data.sample_positions(
                base_key, rnd, K, local_steps, batch_size)
            mask = device_data.dropout_mask(mask_u, active, dropout_rate,
                                            arrival=arrival)
            batch = gather(data, rows, pos_u)
            deltas, losses = jax.vmap(client_update, in_axes=(None, 0))(
                params, batch)
            w = weights * mask
            w = w / jnp.maximum(w.sum(), 1e-9)
            if spec.active:
                flat, unflatten = flatten_stacked(deltas)
                agg_flat, dots, sq, asq = aggregate_compressed(flat, w, spec)
                q = dots / jnp.maximum(jnp.sqrt(sq) * jnp.sqrt(asq), 1e-12)
                agg = unflatten(agg_flat)
                per_client = bytes_per_client(spec, flat.shape[1],
                                              flat.dtype.itemsize)
            else:
                agg, q = _aggregate_and_quality(deltas, w, use_agg_kernel,
                                                fused_quality)
            if server_opt is None:
                params = jax.tree_util.tree_map(
                    lambda p, d: (p - server_lr * d).astype(p.dtype),
                    params, agg)
            else:
                # Δ_t is the server pseudo-gradient (FedOpt): the
                # adaptive optimizer's update replaces −server_lr·Δ_t
                upd, opt_state = server_opt.update(agg, opt_state, params)
                params = apply_updates(params, upd)
            info = {"masks": mask, "q_values": q * mask,
                    "client_losses": losses,
                    "mean_loss": jnp.sum(losses * w)}
            if spec.active:
                info["bytes"] = mask.sum() * jnp.float32(per_client)
            carry = params if server_opt is None else (params, opt_state)
            return carry, info

        xs = (schedule["rows"], schedule["weights"], schedule["active"],
              schedule["round_ids"])
        if has_arrival:
            xs = xs + (schedule["arrival"],)
        return jax.lax.scan(one_round, carry, xs)

    return chunk_fn


def make_fl_rounds_scan_sharded(loss_fn: Callable, local_lr: float = 0.05,
                                local_steps: int = 1, batch_size: int = 16,
                                server_lr: float = 1.0,
                                gather_fn: Callable | None = None,
                                mesh=None):
    """Client-sharded variant of :func:`make_fl_rounds_scan` for large
    models: the round's client axis K is split over the mesh's data
    axes with ``shard_map``, each shard runs its K/n clients' local
    updates, and the weighted aggregate Δ_t (plus the weight and loss
    normalizers) is ``psum``'d across shards — the HomebrewNLP-style
    psum aggregation the ROADMAP names, finally wiring
    ``launch/mesh.py`` + ``sharding/specs.py`` into the FL path.

    Same ``chunk_fn(params, data, schedule, base_key)`` contract and
    the same slot-keyed randomness as the unsharded scan (each shard
    draws its *global* slots via ``sample_positions(slot_offset=...)``),
    so per-client batches, masks and deltas are identical; only the
    f32 reduction order of the aggregate differs (allclose, not
    bit-equal — asserted in tests/test_placement.py). K must divide by
    the data-axis size (pad subsets up — ``DeviceFLSim`` rounds its
    static K up when handed a mesh).

    ``mesh=None`` builds :func:`repro.launch.mesh.make_host_mesh` (all
    local devices on "data"; force N CPU devices with
    ``REPRO_HOST_DEVICES=N tools/run.sh ...``). Scope: the uncompressed
    plain-SGD-server plane only — ``compression`` / ``server_opt`` stay
    on the unsharded scan, and client dropout is not simulated here
    (its all-dropped fallback election is global across K; a per-shard
    election would diverge). Fault-mode ``arrival`` masks are
    supported — they shard with the schedule.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh
    from repro.sharding import specs as sharding_specs

    if mesh is None:
        mesh = make_host_mesh()
    dax = sharding_specs.data_axes(mesh)
    axis = dax if len(dax) > 1 else dax[0]
    n_shard = sharding_specs.mesh_axis_size(mesh, dax)
    client_update = _make_client_update(loss_fn, local_lr)
    gather = device_data.gather_batches if gather_fn is None else gather_fn

    @functools.partial(jax.jit, donate_argnums=(0,))
    def chunk_fn(params, data, schedule, base_key):
        K = schedule["rows"].shape[1]
        if K % n_shard:
            raise ValueError(
                f"client axis K={K} must be divisible by the data-axis "
                f"size {n_shard}; pad subsets (pad_subset_to) up")
        K_local = K // n_shard
        has_arrival = "arrival" in schedule

        def body(params, data, schedule, base_key):
            shard = jnp.int32(0)
            for a in dax:
                shard = shard * sharding_specs.mesh_axis_size(mesh, a) \
                    + jax.lax.axis_index(a)
            offset = shard * K_local

            def one_round(params, per_round):
                if has_arrival:
                    rows, weights, active, rnd, arrival = per_round
                else:
                    rows, weights, active, rnd = per_round
                    arrival = None
                active = active * (jnp.take(data.sizes, rows, axis=0) > 0)
                mask_u, pos_u = device_data.sample_positions(
                    base_key, rnd, K_local, local_steps, batch_size,
                    slot_offset=offset)
                mask = device_data.dropout_mask(mask_u, active, 0.0,
                                                arrival=arrival)
                batch = gather(data, rows, pos_u)
                deltas, losses = jax.vmap(client_update, in_axes=(None, 0))(
                    params, batch)
                w = weights * mask
                wsum = jax.lax.psum(w.sum(), axis)
                w = w / jnp.maximum(wsum, 1e-9)
                agg = jax.lax.psum(tree_weighted_sum(deltas, w), axis)
                q = _quality_cosines(deltas, agg)
                params = jax.tree_util.tree_map(
                    lambda p, d: (p - server_lr * d).astype(p.dtype),
                    params, agg)
                info = {"masks": mask, "q_values": q * mask,
                        "client_losses": losses,
                        "mean_loss": jax.lax.psum(jnp.sum(losses * w),
                                                  axis)}
                return params, info

            xs = (schedule["rows"], schedule["weights"],
                  schedule["active"], schedule["round_ids"])
            if has_arrival:
                xs = xs + (schedule["arrival"],)
            return jax.lax.scan(one_round, params, xs)

        sched_spec = {k: P(None, axis) for k in schedule}
        sched_spec["round_ids"] = P()
        shard_spec = {"masks": P(None, axis), "q_values": P(None, axis),
                      "client_losses": P(None, axis), "mean_loss": P()}
        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), sched_spec, P()),
            out_specs=(P(), shard_spec),
            check_rep=False)
        return mapped(params, data, schedule, base_key)

    return chunk_fn


def make_fedsgd_step(loss_fn: Callable, optimizer, microbatches: int = 1,
                     unroll_microbatches: bool = False):
    """Datacenter-scale train_step (the dry-run target).

    batch carries per-example ``weights`` = p_{k(example)} / examples_of_k,
    so the weighted CE gradient equals the paper's Δ_t = Σ_k p_k Δ_t^(k)
    for one local step. Sharding in/out specs come from sharding/specs.py.

    ``microbatches > 1`` (§Perf): gradient accumulation — the global batch
    splits along dim0 into M microbatches scanned sequentially; live
    activation memory shrinks ~M× at the cost of f32 grad-accumulator
    state. Weighted-loss semantics are preserved by accumulating
    (Σ w·loss, Σ w)-weighted grads. ``unroll_microbatches`` uses a Python
    loop instead of lax.scan (dry-run cost fidelity).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            w_tot = jnp.maximum(batch.get(
                "weights", jnp.ones(())).sum(), 1e-9)

            def one(mb):
                loss, metrics, grads = grads_of(params, mb)
                # per-microbatch loss is weight-normalized inside loss_fn;
                # re-scale so the accumulated grad matches the full batch.
                scale = (mb["weights"].sum() / w_tot) if "weights" in mb \
                    else 1.0 / microbatches
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32) * scale, grads)
                return loss * scale, grads

            if unroll_microbatches:
                loss = 0.0
                grads = None
                for i in range(microbatches):
                    mb = jax.tree_util.tree_map(lambda x: x[i], split)
                    l, g = one(mb)
                    loss = loss + l
                    grads = g if grads is None else jax.tree_util.tree_map(
                        jnp.add, grads, g)
            else:
                def body(acc, mb):
                    l, g = one(mb)
                    return (acc[0] + l,
                            jax.tree_util.tree_map(jnp.add, acc[1], g)), None
                zero = (jnp.zeros((), jnp.float32),
                        jax.tree_util.tree_map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params))
                (loss, grads), _ = jax.lax.scan(body, zero, split)
            metrics = {"loss": loss}
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step
