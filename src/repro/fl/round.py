"""Federated rounds in JAX — two scales (DESIGN.md §4):

- ``make_fl_round``: true FedAvg semantics at simulation scale — every
  scheduled client gets its own parameter copy (vmap over the client
  axis), runs E local SGD steps, and the server aggregates weighted
  deltas (Pallas ``fedavg_agg`` on TPU) and applies the server LR
  (paper §III: w_{t+1} = w_t − η Δ_t).

- ``make_fedsgd_step``: datacenter-scale one-local-step equivalent —
  per-client weights fold into the loss so a single data-parallel
  backward implements the paper's weighted aggregation exactly; this is
  the ``train_step`` that the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim import apply_updates, sgd


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_weighted_sum(trees_stacked, weights, use_kernel: bool = False):
    """Σ_k w_k · leaf[k] for every leaf with leading client axis K."""
    if use_kernel:
        return kops.fedavg_agg_tree(trees_stacked, weights)
    return jax.tree_util.tree_map(
        lambda leaf: jnp.tensordot(weights.astype(jnp.float32),
                                   leaf.astype(jnp.float32), axes=1
                                   ).astype(leaf.dtype),
        trees_stacked)


def make_fl_round(loss_fn: Callable, local_lr: float = 0.05,
                  local_steps: int = 1, server_lr: float = 1.0,
                  use_agg_kernel: bool = False):
    """Build a jit'd FedAvg round.

    loss_fn(params, batch) -> (loss, metrics). Client batches arrive
    stacked: every leaf (K, local_steps, ...). Returns
    round_fn(params, client_batches, weights, mask) -> (params, info)
    where ``mask`` (K,) zeroes out dropped clients (behavior b_t = 0) and
    info carries per-client deltas' cosine-to-global q_t (paper §IV-C).
    """
    opt = sgd(local_lr)

    def client_update(params, batches):
        """E local steps; returns (delta, mean_loss)."""
        state = opt.init(params)

        def step(carry, batch):
            p, s = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            upd, s = opt.update(grads, s, p)
            return (apply_updates(p, upd), s), loss

        (new_params, _), losses = jax.lax.scan(step, (params, state), batches)
        return tree_sub(params, new_params), losses.mean()

    @jax.jit
    def round_fn(params, client_batches, weights, mask):
        deltas, losses = jax.vmap(client_update, in_axes=(None, 0))(
            params, client_batches)
        w = weights * mask
        w = w / jnp.maximum(w.sum(), 1e-9)
        agg = tree_weighted_sum(deltas, w, use_agg_kernel)
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p - server_lr * d).astype(p.dtype), params, agg)

        # per-client model quality q_t = cos(delta_k, agg) (paper §IV-C)
        def dot(a, b):
            return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
                       for x, y in zip(jax.tree_util.tree_leaves(a),
                                       jax.tree_util.tree_leaves(b)))

        def cos_one(k):
            dk = jax.tree_util.tree_map(lambda leaf: leaf[k], deltas)
            num = dot(dk, agg)
            na = jnp.sqrt(dot(dk, dk))
            nb = jnp.sqrt(dot(agg, agg))
            return num / jnp.maximum(na * nb, 1e-12)
        q = jax.vmap(cos_one)(jnp.arange(mask.shape[0]))
        info = {"client_losses": losses, "q_values": q * mask,
                "mean_loss": jnp.sum(losses * w)}
        return new_params, info

    return round_fn


def make_fedsgd_step(loss_fn: Callable, optimizer, microbatches: int = 1,
                     unroll_microbatches: bool = False):
    """Datacenter-scale train_step (the dry-run target).

    batch carries per-example ``weights`` = p_{k(example)} / examples_of_k,
    so the weighted CE gradient equals the paper's Δ_t = Σ_k p_k Δ_t^(k)
    for one local step. Sharding in/out specs come from sharding/specs.py.

    ``microbatches > 1`` (§Perf): gradient accumulation — the global batch
    splits along dim0 into M microbatches scanned sequentially; live
    activation memory shrinks ~M× at the cost of f32 grad-accumulator
    state. Weighted-loss semantics are preserved by accumulating
    (Σ w·loss, Σ w)-weighted grads. ``unroll_microbatches`` uses a Python
    loop instead of lax.scan (dry-run cost fidelity).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            w_tot = jnp.maximum(batch.get(
                "weights", jnp.ones(())).sum(), 1e-9)

            def one(mb):
                loss, metrics, grads = grads_of(params, mb)
                # per-microbatch loss is weight-normalized inside loss_fn;
                # re-scale so the accumulated grad matches the full batch.
                scale = (mb["weights"].sum() / w_tot) if "weights" in mb \
                    else 1.0 / microbatches
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32) * scale, grads)
                return loss * scale, grads

            if unroll_microbatches:
                loss = 0.0
                grads = None
                for i in range(microbatches):
                    mb = jax.tree_util.tree_map(lambda x: x[i], split)
                    l, g = one(mb)
                    loss = loss + l
                    grads = g if grads is None else jax.tree_util.tree_map(
                        jnp.add, grads, g)
            else:
                def body(acc, mb):
                    l, g = one(mb)
                    return (acc[0] + l,
                            jax.tree_util.tree_map(jnp.add, acc[1], g)), None
                zero = (jnp.zeros((), jnp.float32),
                        jax.tree_util.tree_map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params))
                (loss, grads), _ = jax.lax.scan(body, zero, split)
            metrics = {"loss": loss}
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step
