"""Non-iid client data partitioners (paper §VIII-A).

Type 1: each client holds one label.
Type 2: two labels, 9:1.
Type 3: mostly three labels 5:4:1; a few clients 5:1 or 4:1.
Plus 'iid' and Dirichlet partitions for extra experiments.
"""
from __future__ import annotations

import numpy as np


def _ratios(kind: str, rng) -> np.ndarray:
    if kind == "type1":
        return np.array([1.0])
    if kind == "type2":
        return np.array([0.9, 0.1])
    if kind == "type3":
        if rng.uniform() < 0.1:
            r = rng.choice([5.0, 4.0])
            return np.array([r, 1.0]) / (r + 1.0)
        return np.array([0.5, 0.4, 0.1])
    raise ValueError(kind)


def partition_labels(labels: np.ndarray, n_clients: int, kind: str,
                     num_classes: int, seed: int = 0,
                     samples_per_client: int | None = None) -> list[np.ndarray]:
    """Assign sample indices to clients per the paper's non-iid types.

    Returns a list of index arrays (one per client). Sampling is done
    with replacement-free draws from per-class pools; pools recycle if
    exhausted (keeps client sizes equal, matching the paper's setup).
    """
    rng = np.random.default_rng(seed)
    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for c in range(num_classes):
        rng.shuffle(by_class[c])
    cursors = [0] * num_classes
    spc = samples_per_client or len(labels) // n_clients

    def draw(c, k):
        nonlocal cursors
        pool = by_class[c]
        if len(pool) == 0:
            return np.array([], dtype=np.int64)
        out = []
        while k > 0:
            take = min(k, len(pool) - cursors[c])
            if take <= 0:
                cursors[c] = 0   # recycle
                rng.shuffle(pool)
                continue
            out.append(pool[cursors[c]:cursors[c] + take])
            cursors[c] += take
            k -= take
        return np.concatenate(out)

    clients = []
    for _ in range(n_clients):
        if kind == "iid":
            per = np.maximum(spc // num_classes, 1)
            idx = np.concatenate([draw(c, per) for c in range(num_classes)])
        else:
            ratios = _ratios(kind, rng)
            cls = rng.choice(num_classes, size=len(ratios), replace=False)
            counts = np.maximum((ratios * spc).astype(int), 1)
            idx = np.concatenate([draw(c, k) for c, k in zip(cls, counts)])
        rng.shuffle(idx)
        clients.append(idx)
    return clients


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        num_classes: int, seed: int = 0) -> list[np.ndarray]:
    """Standard Dirichlet(alpha) non-iid partition (beyond-paper extra)."""
    rng = np.random.default_rng(seed)
    props = rng.dirichlet([alpha] * n_clients, size=num_classes)  # (C, K)
    clients = [[] for _ in range(n_clients)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        splits = (np.cumsum(props[c])[:-1] * len(idx)).astype(int)
        for k, part in enumerate(np.split(idx, splits)):
            clients[k].append(part)
    return [np.concatenate(p) if p else np.array([], np.int64)
            for p in clients]


def client_histograms(labels: np.ndarray, parts: list[np.ndarray],
                      num_classes: int) -> dict[int, np.ndarray]:
    return {i: np.bincount(labels[p], minlength=num_classes).astype(np.float64)
            for i, p in enumerate(parts)}


def dense_index_pools(parts: list[np.ndarray],
                      cap: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Ragged per-client sample-index lists -> dense device-friendly form.

    Returns ``(pools, sizes)`` where ``pools`` is ``(n_clients, cap)``
    int32 (each row the client's sample indices, padded by cycling the
    row's own indices so every slot is a valid sample of that client)
    and ``sizes`` is ``(n_clients,)`` int32 true pool sizes. This is the
    staging format of the device-resident data plane (fl.device_data):
    batch sampling draws positions in ``[0, sizes[k])`` so the padding
    never biases the draw.
    """
    n = len(parts)
    cap = cap or max((len(p) for p in parts), default=1)
    cap = max(cap, 1)
    pools = np.zeros((n, cap), dtype=np.int32)
    sizes = np.zeros(n, dtype=np.int32)
    for k, idx in enumerate(parts):
        m = len(idx)
        sizes[k] = m
        if m == 0:
            continue
        if m > cap:
            raise ValueError(f"client {k} has {m} samples > cap={cap}")
        reps = -(-cap // m)                    # ceil-div: cycle the row
        pools[k] = np.tile(np.asarray(idx, dtype=np.int32), reps)[:cap]
    return pools, sizes
