from .compression import (CompressionSpec, aggregate_compressed,
                          bytes_per_client, compress, decompress, roundtrip)
from .device_data import DeviceDataset, DeviceLMDataset, gather_lm_batches
from .partition import (client_histograms, dense_index_pools,
                        dirichlet_partition, partition_labels)
from .round import (make_fedsgd_step, make_fl_round, make_fl_rounds_scan,
                    tree_weighted_sum)
from .simulation import (DeviceFLSim, FLClassificationSim, SimConfig,
                         profiles_from_partition, run_fl_experiment)
from .transformer_task import (LoraConfig, TransformerFLSim, init_adapters,
                               make_transformer_fl, merge_adapters,
                               reduced_lm_config)
