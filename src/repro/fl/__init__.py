from .partition import (client_histograms, dirichlet_partition,
                        partition_labels)
from .round import make_fedsgd_step, make_fl_round, tree_weighted_sum
from .simulation import (FLClassificationSim, SimConfig,
                         profiles_from_partition, run_fl_experiment)
