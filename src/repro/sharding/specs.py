"""Sharding rules: parameter / optimizer-state / batch / cache
PartitionSpecs for every architecture on the production mesh.

Axes: "data" (+ optional "pod") = batch/client parallel; "model" =
tensor/expert parallel. Rules are name+shape based and *divisibility
guarded*: a dim is only sharded when its size divides the mesh axis —
e.g. starcoder2's 4 KV heads stay replicated on a 16-way model axis
while its 48 Q heads shard; qwen2-moe's 60 experts don't divide 16 so
its expert weights shard on the ff dim instead (tensor-parallel experts)
whereas llama4's 16 experts shard expert-parallel.

Optimizer state (Adam m/v, f32) is additionally ZeRO-1-sharded over the
data axis on the largest still-unsharded divisible dim.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return int(np.prod([mesh_axis_size(mesh, n) for n in name]))
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(size: int, n: int) -> bool:
    return n > 0 and size % n == 0


def param_spec(path: tuple, shape: tuple, mesh: Mesh,
               expert_2d: bool = False) -> P:
    """PartitionSpec for one parameter, identified by its tree path.

    ``expert_2d``: additionally shard expert ff dims over the data axes
    (FSDP-style weight sharding — §Perf serving iteration for very large
    MoE; XLA all-gathers one layer's experts at a time)."""
    tp = mesh_axis_size(mesh, "model")
    dax = data_axes(mesh)
    dsize = mesh_axis_size(mesh, dax)
    daxis = dax if len(dax) > 1 else dax[0]
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""

    # scan-stacked layer params have a leading L dim; unrolled (list)
    # stacks have an integer path element instead.
    stacked = False
    if "layers" in names:
        i = names.index("layers")
        stacked = not (len(names) > i + 1 and names[i + 1].isdigit())

    off = 1 if stacked else 0
    rank = len(shape) - off          # logical (per-layer) rank

    def spec(*dims):
        assert len(dims) == rank, (name, shape, dims)
        return P(*([None] * off + list(dims)))

    def tp_if(size):
        return "model" if _div(size, tp) else None

    if name == "embed":
        return P(tp_if(shape[0]), None)
    if name == "lm_head":
        return P(None, tp_if(shape[1]))

    if parent == "moe" and name in ("w_gate", "w_up", "w_down") and rank == 3:
        E = shape[off]
        ff_dim = 2 if name in ("w_gate", "w_up") else 1
        if _div(E, tp):                          # expert parallel
            dims = ["model", None, None]
            if expert_2d and _div(shape[off + ff_dim], dsize):
                dims[ff_dim] = daxis             # + FSDP over data
            return spec(*dims)
        dims = [None, None, None]
        dims[ff_dim] = tp_if(shape[off + ff_dim])  # tensor-parallel experts
        return spec(*dims)
    if name == "router":
        return spec(*([None] * rank))

    if name in ("wq", "wk", "wv"):
        if rank == 3:    # attention projections (d, H|G, hd): shard heads
            return spec(None, tp_if(shape[off + 1]), None)
        if rank == 2:    # mlstm square projections (inner, inner)
            return spec(None, tp_if(shape[off + 1]))
    if name == "wo" and rank == 3:
        return spec(tp_if(shape[off]), None, None)

    if name in ("w_gate", "w_up", "w_ff_gate", "w_ff_up", "w_in", "w1") \
            and rank == 2:           # column parallel
        return spec(None, tp_if(shape[off + 1]))
    if name in ("w_down", "w_ff_down", "w_out", "w2") and rank == 2:
        return spec(tp_if(shape[off]), None)      # row parallel

    return P(*([None] * len(shape)))   # norms, biases, gates, convs: replicate


def params_shardings(params, mesh: Mesh, expert_2d: bool = False):
    """NamedSharding tree matching a params pytree (works on
    ShapeDtypeStructs)."""
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf.shape, mesh,
                                              expert_2d=expert_2d))
    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(params, mesh: Mesh):
    """Adam state: m/v shard like params plus ZeRO-1 over the data axis on
    the largest remaining divisible dim; count replicated."""
    dp = mesh_axis_size(mesh, "data")
    dax = data_axes(mesh)
    dp_total = mesh_axis_size(mesh, dax)

    def zero1(path, leaf):
        spec = list(param_spec(path, leaf.shape, mesh))
        spec += [None] * (len(leaf.shape) - len(spec))
        # pick the largest unsharded dim divisible by the full data size
        best, best_size = None, 0
        for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
            if s is None and _div(dim, dp_total) and dim > best_size:
                best, best_size = i, dim
        if best is not None:
            spec[best] = dax if len(dax) > 1 else dax[0]
        return NamedSharding(mesh, P(*spec))

    m = jax.tree_util.tree_map_with_path(zero1, params)
    return {"count": NamedSharding(mesh, P()), "m": m, "v": m}


def batch_shardings(batch, mesh: Mesh, batch_sharded: bool = True):
    """Batch leaves shard dim0 over (pod, data) when divisible."""
    dax = data_axes(mesh)
    n = mesh_axis_size(mesh, dax)
    axis = dax if len(dax) > 1 else dax[0]

    def one(leaf):
        shape = leaf.shape
        if batch_sharded and shape and _div(shape[0], n):
            return NamedSharding(mesh, P(axis, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree_util.tree_map(one, batch)


def cache_shardings(cache, mesh: Mesh, batch: int,
                    seq_over_model: bool = False):
    """Decode caches: batch dim over data axes when divisible; otherwise
    (long_500k, B=1) shard the KV sequence axis over "data" — the
    flash-decoding layout (partial-softmax combine happens inside XLA's
    sharded softmax reduction). SSM states follow the batch rule.

    ``seq_over_model=True`` (§Perf iteration 1): additionally shard the
    cache sequence axis over "model" when KV heads don't divide it —
    GQA head counts (4-20) never divide a 16-way model axis, so without
    this the model axis holds a full cache replica per shard.
    """
    dax = data_axes(mesh)
    n = mesh_axis_size(mesh, dax)
    axis = dax if len(dax) > 1 else dax[0]
    tp = mesh_axis_size(mesh, "model")

    def one(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        shape = leaf.shape
        name = names[-1]
        # kv k/v: (L, B, W, G, hd) or (B, W, G, hd)
        if name in ("k", "v") and len(shape) >= 4:
            b_dim = len(shape) - 4
            w_dim = b_dim + 1
            g_dim = b_dim + 2
            spec = [None] * len(shape)
            if _div(shape[b_dim], n) and shape[b_dim] > 1:
                spec[b_dim] = axis
            elif _div(shape[w_dim], mesh_axis_size(mesh, "data")):
                spec[w_dim] = "data"     # sequence-sharded cache (B too small)
            if _div(shape[g_dim], tp):
                spec[g_dim] = "model"
            elif seq_over_model and spec[w_dim] is None \
                    and _div(shape[w_dim], tp):
                spec[w_dim] = "model"    # flash-decoding over the model axis
            return NamedSharding(mesh, P(*spec))
        if name == "pos":
            return NamedSharding(mesh, P(*([None] * len(shape))))
        # ssm states / conv caches: (L, B, ...) — batch over data if divisible
        spec = [None] * len(shape)
        for i, dim in enumerate(shape[:2]):
            if _div(dim, n) and dim > 1:
                spec[i] = axis
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree)
