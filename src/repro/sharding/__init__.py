from .specs import (batch_shardings, cache_shardings, data_axes,
                    mesh_axis_size, opt_state_shardings, param_spec,
                    params_shardings, replicated)
