"""InternVL2-26B [arXiv:2404.16821] — InternLM2-20B-class language
backbone consuming InternViT patch embeddings. The ViT is a STUB (the
assignment's carve-out): input_specs() feeds precomputed patch
embeddings (256 per image tile) through a 2-layer MLP projector."""
from repro.models.common import ModelConfig

PATCH_TOKENS = 256   # InternVL pixel-shuffled tokens per tile


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=92553, head_dim=128,
        rope_theta=1_000_000.0,
        frontend="vision", frontend_seq=PATCH_TOKENS, frontend_dim=1024,
        source="arXiv:2404.16821",
    )
