"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder; mel+conv
frontend is a STUB (the carve-out): input_specs() feeds 1500 precomputed
frame embeddings to the encoder; the decoder cross-attends.

Decode shapes exercise the decoder with a KV cache; 500k decoder context
is out-of-domain for whisper but mechanically supported via the window
variant (EXPERIMENTS.md flags it)."""
from repro.models.common import ModelConfig

NUM_FRAMES = 1500    # 30 s of audio after the conv frontend's 2x stride


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866, head_dim=64,
        encoder_layers=32, cross_attention=True,
        block_pattern=tuple(["xattn"] * 32),
        positional="sinusoidal", norm="layernorm", act="gelu",
        frontend="audio", frontend_seq=NUM_FRAMES, frontend_dim=128,
        source="arXiv:2212.04356",
    )
