"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks, no separate FFN
(d_ff=0; blocks carry their own projections). Block pattern follows the
paper's mostly-mLSTM ratio with sLSTM at positions 3 and 7.

Sub-quadratic natively (recurrent state): long_500k runs."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    pattern = tuple("slstm" if i in (3, 7) else "mlstm" for i in range(12))
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        ssm_state=16, block_pattern=pattern, positional="none",
        source="arXiv:2405.04517",
    )
