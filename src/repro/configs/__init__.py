"""Architecture registry: every assigned architecture is a selectable
config (``--arch <id>``). Each file pins the exact assigned shape and
cites its source in ``source=``."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = (
    "starcoder2-15b",
    "qwen2-moe-a2.7b",
    "mistral-nemo-12b",
    "llama4-scout-17b-a16e",
    "internlm2-1.8b",
    "hymba-1.5b",
    "smollm-360m",
    "internvl2-26b",
    "xlstm-125m",
    "whisper-large-v3",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg = mod.config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
