"""Hymba-1.5B [arXiv:2411.13676] — hybrid: parallel attention + mamba
(SSD) heads in every block, sliding-window attention (meta tokens and
cross-layer KV sharing simplified away; see DESIGN.md §8).

Sub-quadratic natively: SSM state + windowed attention -> long_500k runs.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        ssm_state=16, sliding_window=1024,
        source="arXiv:2411.13676",
    )
