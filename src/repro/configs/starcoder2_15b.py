"""StarCoder2-15B [arXiv:2402.19173] — dense, GQA kv=4, RoPE.

long_500k runs via our generic sliding-window variant (window 8192),
recorded as beyond-paper-config in EXPERIMENTS.md.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
        d_ff=24576, vocab_size=49152, head_dim=128,
        rope_theta=100_000.0, norm="layernorm", act="gelu",
        source="arXiv:2402.19173",
    )
