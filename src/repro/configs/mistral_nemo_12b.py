"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA
kv=8, 128k context; the sliding-window variant (8192) powers long_500k."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=131072, head_dim=128,
        rope_theta=1_000_000.0,
        source="hf:mistralai/Mistral-Nemo-Base-2407",
    )
