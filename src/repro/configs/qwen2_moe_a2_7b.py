"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts
top-4 + 4 shared experts, per-expert d_ff=1408."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936, head_dim=128,
        num_experts=60, num_shared_experts=4, top_k=4, moe_d_ff=1408,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
