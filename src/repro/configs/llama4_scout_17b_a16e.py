"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E] — MoE 16
experts top-1 + 1 shared expert, early fusion (modality prefix tokens
via the stub vision frontend)."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048, head_dim=128,
        num_experts=16, num_shared_experts=1, top_k=1, moe_d_ff=8192,
        rope_theta=500_000.0,
        frontend="vision", frontend_seq=0, frontend_dim=1408,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
