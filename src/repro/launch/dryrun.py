import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# NOTE: the two lines above MUST run before any jax import (device count
# locks on first init). Everything below is ordinary code.
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) pair, lower + compile the step on
the production mesh (16x16 single-pod and 2x16x16 multi-pod), print
memory_analysis() (proves fit) and cost_analysis() (roofline §g), parse
the post-SPMD HLO for collective bytes, and write a JSON artifact under
artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch import roofline as R
from repro.launch.inputs import (SHAPES, input_specs, make_prefill_step,
                                 make_serve_step, make_train_step,
                                 model_flops_for, shape_config)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.sharding import (batch_shardings, cache_shardings,
                            opt_state_shardings, params_shardings)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# long_500k applicability notes (DESIGN.md §5): who runs it and why.
LONG_OK = {a: "window-8192 variant" for a in ARCH_IDS}
LONG_OK["xlstm-125m"] = "native recurrent state"
LONG_OK["hymba-1.5b"] = "native: SSM state + window-1024 attention"
LONG_OK["whisper-large-v3"] = ("window-8192 variant; out-of-domain for "
                               "whisper's decoder, mechanical support only")


def _struct_with_sharding(struct_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, sharding_tree)


def build_lowerable(arch: str, shape: str, mesh, unroll: bool = False,
                    opt_level: int = 0):
    """Returns (fn, args_structs, out_shardings, meta).

    ``unroll=True`` unrolls the layer stack: XLA's cost_analysis counts
    while-loop (scan) bodies ONCE, so scan-based lowerings undercount
    FLOPs/bytes/collectives by ~num_layers. The roofline pass therefore
    compiles the unrolled variant; the scan variant remains the runtime
    path (and is also compiled to prove the production graph).
    """
    cfg = shape_config(get_config(arch), shape)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll_layers=True)
    fn, args, out_sh, donate = _build_from_cfg(cfg, shape, mesh,
                                               opt_level=opt_level)
    return fn, args, out_sh, donate, cfg


def _probe_cfg(cfg, L: int):
    """A structurally identical model with L (unrolled) layers — used to
    measure exact per-layer cost deltas (see build_lowerable docstring)."""
    return dataclasses.replace(
        cfg, num_layers=L,
        encoder_layers=min(L, cfg.encoder_layers) if cfg.encoder_layers else 0,
        block_pattern=cfg.block_pattern[:L] if cfg.block_pattern else (),
        unroll_layers=True)


def _lower_compile(fn, args, out_sh, mesh, donate=()):
    with mesh:
        kw = {"donate_argnums": donate} if donate else {}
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        return jax.jit(fn, **kw).lower(*args).compile()


def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() returns a dict in newer jax and a
    one-element list of dicts in older versions; normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cost_record(compiled):
    cost = _cost_analysis(compiled)
    coll = R.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0) or 0),
            "bytes_accessed": float(cost.get("bytes accessed", 0) or 0),
            "coll": coll}


def probe_corrected_cost(arch: str, shape: str, mesh, cfg,
                         opt_level: int = 0) -> dict | None:
    """XLA cost_analysis counts scan (while) bodies ONCE, so the scan
    lowering undercounts layer-stack costs by ~num_layers. Correction:
    compile tiny UNROLLED probes at L=1 and L=2; the delta is the exact
    per-layer cost at full batch/seq/mesh, and
        corrected = f(1) + (L_full - 1) · (f(2) - f(1)).
    Heterogeneous stacks (xlstm) already lower unrolled — no correction.
    """
    if not _is_scan_stack(cfg):
        return None
    recs = []
    for L in (1, 2):
        kind = SHAPES[shape][2]
        pcfg = _probe_cfg(cfg, L)
        fn, args, out_sh, donate = _build_from_cfg(pcfg, shape, mesh,
                                                   opt_level=opt_level)
        compiled = _lower_compile(fn, args, out_sh, mesh, donate)
        recs.append(_cost_record(compiled))
    f1, f2 = recs
    Lf = cfg.num_layers
    out = {
        "method": "probe L=1/L=2 unrolled, corrected = f1 + (L-1)(f2-f1)",
        "flops": f1["flops"] + (Lf - 1) * (f2["flops"] - f1["flops"]),
        "bytes_accessed": f1["bytes_accessed"]
        + (Lf - 1) * (f2["bytes_accessed"] - f1["bytes_accessed"]),
    }
    c1 = f1["coll"]["total_bytes"]
    c2 = f2["coll"]["total_bytes"]
    out["coll_total_bytes"] = c1 + (Lf - 1) * (c2 - c1)
    out["coll_per_layer"] = {
        k: f1["coll"]["bytes"][k] + (Lf - 1)
        * (f2["coll"]["bytes"][k] - f1["coll"]["bytes"][k])
        for k in f1["coll"]["bytes"]}
    return out


def _is_scan_stack(cfg) -> bool:
    types = set(cfg.layer_types)
    return len(types) == 1 and not cfg.unroll_layers


def _build_from_cfg(cfg, shape: str, mesh, opt_level: int = 0):
    """build_lowerable body for an explicit cfg (probes).

    opt_level >= 1 (§Perf): KV-cache seq axis sharded over "model" when
    heads don't divide it, and donated buffers (cache / params+opt) so
    updates happen in place instead of round-tripping.
    Returns (fn, args, out_shardings, donate).
    """
    kind = SHAPES[shape][2]
    if opt_level >= 4 and kind == "decode":
        # §Perf: unroll the decode stack — the scan's ys cache double-
        # buffers (in+out copies alive across the loop); unrolled layers
        # let XLA alias each layer's cache update in place.
        cfg = dataclasses.replace(cfg, unroll_layers=True)
    if opt_level >= 2 and cfg.is_moe:
        # §Perf: pad experts up to a multiple of the model axis so expert-
        # parallel sharding applies (function-preserving; DESIGN.md §8)
        from repro.sharding.specs import mesh_axis_size
        tp = mesh_axis_size(mesh, "model")
        if cfg.num_experts % tp:
            cfg = dataclasses.replace(
                cfg, pad_experts_to=-(-cfg.num_experts // tp) * tp)
    params_struct = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    # §Perf opt 3: FSDP-style 2D expert sharding on serving shapes
    expert_2d = opt_level >= 3 and kind != "train"
    p_sh = params_shardings(params_struct, mesh, expert_2d=expert_2d)
    specs = _input_specs_for(cfg, shape)
    b_sh = batch_shardings(specs["batch"], mesh)
    batch_struct = _struct_with_sharding(specs["batch"], b_sh)
    params_in = _struct_with_sharding(params_struct, p_sh)
    donate = ()
    if kind == "train":
        micro = 8 if opt_level >= 2 else 1   # §Perf: grad accumulation
        step, optimizer = make_train_step(cfg, microbatches=micro)
        opt_struct = jax.eval_shape(optimizer.init, params_struct)
        o_sh = opt_state_shardings(params_struct, mesh)
        opt_in = _struct_with_sharding(opt_struct, o_sh)
        if opt_level >= 1:
            donate = (0, 1)            # params, opt_state updated in place
        return step, (params_in, opt_in, batch_struct), (p_sh, o_sh, None), donate
    if kind == "prefill":
        return make_prefill_step(cfg), (params_in, batch_struct), None, donate
    c_sh = cache_shardings(specs["cache"], mesh, batch=SHAPES[shape][1],
                           seq_over_model=opt_level >= 1)
    cache_in = _struct_with_sharding(specs["cache"], c_sh)
    if opt_level >= 1:
        donate = (2,)                  # cache updated in place
    return (make_serve_step(cfg), (params_in, batch_struct, cache_in),
            (None, c_sh), donate)


def _input_specs_for(cfg, shape):
    return input_specs(cfg, shape)


def run_one(arch: str, shape: str, multi_pod: bool = False,
            out_dir: str | None = None, verbose: bool = True,
            unroll: bool = False, probes: bool = True,
            opt_level: int = 0) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(map(str, mesh.devices.shape))
    if unroll:
        mesh_name += "-unrolled"
    if opt_level:
        mesh_name += f"-opt{opt_level}"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
           "ok": False}
    t0 = time.time()
    try:
        fn, args, out_sh, donate, cfg = build_lowerable(
            arch, shape, mesh, unroll=unroll, opt_level=opt_level)
        with mesh:
            kw = {"donate_argnums": donate} if donate else {}
            if out_sh is not None:
                kw["out_shardings"] = out_sh
            lowered = jax.jit(fn, **kw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                v = getattr(mem, field, None)
                if v is not None:
                    mem_rec[field] = int(v)
        cost = _cost_analysis(compiled)
        coll = R.collective_bytes(compiled.as_text())
        mf = model_flops_for(cfg, shape)

        # scan-body cost correction via unrolled L=1/L=2 probes
        corrected = None
        if probes and not multi_pod:
            try:
                corrected = probe_corrected_cost(arch, shape, mesh, cfg,
                                                 opt_level=opt_level)
            except Exception as e:
                corrected = {"error": f"{type(e).__name__}: {e}"}
        if corrected and "flops" in corrected:
            eff_cost = {"flops": corrected["flops"],
                        "bytes accessed": corrected["bytes_accessed"]}
            eff_coll = {"total_bytes": corrected["coll_total_bytes"],
                        "bytes": corrected["coll_per_layer"],
                        "counts": coll["counts"]}
        else:
            eff_cost, eff_coll = cost, coll
        terms = R.derive_terms(eff_cost, eff_coll, chips, mf)
        rec.update(
            ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=mem_rec,
            cost_raw={"flops": float(cost.get("flops", 0) or 0),
                      "bytes_accessed": float(cost.get("bytes accessed", 0) or 0)},
            cost_corrected=corrected,
            collectives=coll, roofline=terms.as_dict(),
            note=LONG_OK.get(arch, "") if shape == "long_500k" else "")
        if verbose:
            bpd = mem_rec.get("argument_size_in_bytes", 0) + \
                mem_rec.get("temp_size_in_bytes", 0)
            print(f"[OK] {arch:24s} {shape:12s} {mesh_name:8s} "
                  f"compile={t_compile:6.1f}s bytes/dev={bpd/2**30:7.2f}GiB "
                  f"flops/dev={terms.flops:.3e} coll/dev={terms.coll_bytes:.3e} "
                  f"bottleneck={terms.bottleneck}", flush=True)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} {shape} {mesh_name}: {rec['error']}",
                  flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer stack (roofline cost fidelity)")
    ap.add_argument("--opt", type=int, default=0,
                    help="optimization level (1: 2D cache sharding + donation)")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in SHAPES]
    elif args.arch and args.shape:
        combos = [(args.arch, args.shape)]
    else:
        ap.error("need --all or (--arch and --shape)")

    results = [run_one(a, s, multi_pod=args.multi_pod, out_dir=args.out,
                       unroll=args.unroll, opt_level=args.opt)
               for a, s in combos]
    ok = sum(r["ok"] for r in results)
    print(f"\n{ok}/{len(results)} combos compiled OK")
    raise SystemExit(0 if ok == len(results) else 1)


if __name__ == "__main__":
    main()
