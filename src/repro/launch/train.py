"""Training driver.

Two modes:
- default (CPU-runnable): trains a REDUCED variant of --arch on synthetic
  federated LM data with the paper's scheduler choosing the per-round
  client subsets (end-to-end example driver, deliverable b).
- --dryrun: delegates to launch.dryrun for the production-mesh lowering.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.core import generate_subsets, participation_weights
from repro.data import make_lm_data
from repro.fl.partition import client_histograms, partition_labels
from repro.fl.round import make_fedsgd_step
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import adam, warmup_cosine


def make_extras(cfg, B, rng):
    extras = {}
    if cfg.family == "vlm" and cfg.frontend_seq:
        extras["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_seq, cfg.frontend_dim)),
            jnp.float32)
    if cfg.is_enc_dec:
        extras["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_seq, cfg.frontend_dim)),
            jnp.float32)
    return extras


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--subset", type=int, default=4)
    ap.add_argument("--noniid", default="type2",
                    choices=["type1", "type2", "type3", "iid"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(args.seed)
    data = make_lm_data(args.clients * 64, args.seq, cfg.vocab_size,
                        seed=args.seed)
    parts = partition_labels(data.labels, args.clients, args.noniid,
                             data.num_classes, seed=args.seed)
    hists = client_histograms(data.labels, parts, data.num_classes)

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    optimizer = adam(warmup_cosine(args.lr, 10, args.steps), grad_clip=1.0)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_fedsgd_step(
        lambda p, b: T.loss_fn(cfg, p, b), optimizer))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    sched = generate_subsets(hists, n=args.subset, delta=1, x_star=3)
    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree_util.tree_leaves(params)):,} "
          f"rounds/period={sched.num_rounds}")

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        subset = sched.subsets[step % sched.num_rounds]
        w = participation_weights(hists, subset)
        # each scheduled client contributes batch/|subset| examples
        per = max(args.batch // len(subset), 1)
        idx, wts = [], []
        for cid, pk in zip(subset, w):
            take = rng.choice(parts[cid], size=per,
                              replace=len(parts[cid]) < per)
            idx.extend(take)
            wts.extend([pk / per] * per)
        toks = data.tokens[np.asarray(idx)]
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "targets": jnp.asarray(toks[:, 1:]),
                 "weights": jnp.asarray(np.asarray(wts), jnp.float32)}
        batch.update(make_extras(cfg, batch["tokens"].shape[0], rng))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.1f}s)")
        if mgr and (step + 1) % 25 == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(first {np.mean(losses[:5]):.4f})")
    return losses


if __name__ == "__main__":
    main()
