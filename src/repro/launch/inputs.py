"""Input specifications for every (architecture × input shape) pair.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input — shardable, no device allocation — the dry-run's raw
material. ``make_step`` builds the step function each shape lowers:
train_4k -> train_step (FedSGD round), prefill_32k -> prefill_step,
decode_32k / long_500k -> serve_step (one token against a seq_len cache).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.fl.round import make_fedsgd_step
from repro.models import transformer as T
from repro.models.common import ModelConfig, model_flops_per_token
from repro.optim import adam

SHAPES = {
    #               seq_len  global_batch  kind
    "train_4k":    (4_096,   256,          "train"),
    "prefill_32k": (32_768,  32,           "prefill"),
    "decode_32k":  (32_768,  128,          "decode"),
    "long_500k":   (524_288, 1,            "decode"),
}

LONG_WINDOW = 8_192   # generic sliding-window variant for long_500k


def shape_config(cfg: ModelConfig, shape: str, *, remat: bool = True) -> ModelConfig:
    """Per-shape config adjustments (window for long-context, remat for
    training)."""
    kind = SHAPES[shape][2]
    upd = {}
    if shape == "long_500k" and cfg.family not in ("ssm",):
        # sub-quadratic rule: windowed attention unless natively recurrent.
        if not cfg.sliding_window or cfg.sliding_window > LONG_WINDOW:
            upd["sliding_window"] = LONG_WINDOW
    if kind == "train" and remat:
        upd["remat"] = True
    return dataclasses.replace(cfg, **upd) if upd else cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: str) -> dict:
    """Train-batch ShapeDtypeStructs. For VLM archs the vision prefix
    occupies part of the sequence budget so total length == seq_len."""
    S, B, kind = SHAPES[shape]
    assert kind == "train"
    S_text = S
    batch = {}
    if cfg.family == "vlm" and cfg.frontend_seq:
        S_text = S - cfg.frontend_seq
        batch["patch_embeds"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                     jnp.float32)
    if cfg.is_enc_dec:
        batch["frames"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                               jnp.float32)
    batch["tokens"] = _sds((B, S_text), jnp.int32)
    batch["targets"] = _sds((B, S_text), jnp.int32)
    batch["weights"] = _sds((B,), jnp.float32)   # federated p_k per example
    return batch


def prefill_specs(cfg: ModelConfig, shape: str) -> dict:
    S, B, _ = SHAPES[shape]
    batch = {"tokens": _sds((B, S if cfg.family != "vlm"
                             else S - cfg.frontend_seq), jnp.int32)}
    if cfg.family == "vlm" and cfg.frontend_seq:
        batch["patch_embeds"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                     jnp.float32)
    if cfg.is_enc_dec:
        batch["frames"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                               jnp.float32)
    return batch


def decode_specs(cfg: ModelConfig, shape: str) -> tuple:
    """Returns (batch_specs, cache_specs): one new token against a KV
    cache of seq_len (ring-buffer of `window` for windowed archs)."""
    S, B, _ = SHAPES[shape]
    batch = {"tokens": _sds((B, 1), jnp.int32),
             "index": _sds((), jnp.int32)}
    if cfg.is_enc_dec:
        batch["memory"] = _sds((B, cfg.frontend_seq, cfg.d_model),
                               cfg.param_dtype)
    cache = jax.eval_shape(
        functools.partial(T.init_decode_cache, cfg, B, S))
    return batch, cache


def input_specs(cfg: ModelConfig, shape: str):
    kind = SHAPES[shape][2]
    if kind == "train":
        return {"batch": batch_specs(cfg, shape)}
    if kind == "prefill":
        return {"batch": prefill_specs(cfg, shape)}
    batch, cache = decode_specs(cfg, shape)
    return {"batch": batch, "cache": cache}


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, lr: float = 1e-4,
                    microbatches: int = 1):
    optimizer = adam(lr, grad_clip=1.0)
    loss = functools.partial(T.loss_fn, cfg)
    step = make_fedsgd_step(loss, optimizer, microbatches=microbatches,
                            unroll_microbatches=cfg.unroll_layers)
    return step, optimizer


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        extras = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
        logits, cache, memory = T.prefill(cfg, params, batch["tokens"], extras)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, batch, cache):
        logits, new_cache = T.decode_step(
            cfg, params, batch["tokens"], cache, batch["index"],
            memory=batch.get("memory"))
        return logits, new_cache
    return serve_step


def model_flops_for(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS = 6·N·D for training (fwd+bwd), 2·N·D inference."""
    S, B, kind = SHAPES[shape]
    per_tok = model_flops_per_token(cfg)       # already includes the 6x
    if kind == "train":
        return per_tok * B * S
    if kind == "prefill":
        return per_tok / 3.0 * B * S           # forward only: 2·N·D
    return per_tok / 3.0 * B * 1               # one token per sequence
