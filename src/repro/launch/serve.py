"""Serving driver: batched prefill + decode loop on a reduced arch
(CPU-runnable example of the serve path the dry-run lowers at scale).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def serve(arch: str, batch: int = 4, prompt_len: int = 32,
          new_tokens: int = 16, seed: int = 0, greedy: bool = True,
          verbose: bool = True):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, key)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)
    extras = {}
    if cfg.family == "vlm" and cfg.frontend_seq:
        extras["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_seq, cfg.frontend_dim)),
            jnp.float32)
    if cfg.is_enc_dec:
        extras["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_seq, cfg.frontend_dim)),
            jnp.float32)

    t0 = time.time()
    logits, cache, memory = T.prefill(cfg, params, prompts, extras)
    cache = T.grow_cache(cfg, cache, extra=new_tokens)
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i,
                                                      memory=memory))
    n_prefix = cfg.frontend_seq if cfg.family == "vlm" else 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for step in range(new_tokens - 1):
        idx = jnp.asarray(prompt_len + n_prefix + step, jnp.int32)
        logits, cache = decode(params, tok, cache, idx)
        tok = (jnp.argmax(logits[:, -1:], -1) if greedy else
               jax.random.categorical(jax.random.fold_in(key, step),
                                      logits[:, -1:])).astype(jnp.int32)
        out.append(tok.reshape(batch, 1))
    t_decode = time.time() - t0
    tokens = jnp.concatenate([o.reshape(batch, 1) for o in out], axis=1)
    if verbose:
        print(f"arch={cfg.name} prefill({batch}x{prompt_len})={t_prefill:.2f}s "
              f"decode {new_tokens} toks={t_decode:.2f}s "
              f"({batch * new_tokens / max(t_decode, 1e-9):.1f} tok/s)")
        print("generated:", np.asarray(tokens[0, :12]))
    return tokens


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args(argv)
    serve(args.arch, args.batch, args.prompt, args.tokens)


if __name__ == "__main__":
    main()
