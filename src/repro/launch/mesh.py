"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
initialization; tests and benches see 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    REPRO_MESH="d,m" (env) overrides the per-pod shape for fast in-CI
    smoke runs of the dry-run machinery on few host devices.
    """
    import os
    override = os.environ.get("REPRO_MESH")
    if override:
        d, m = (int(x) for x in override.split(","))
        shape = (2, d, m) if multi_pod else (d, m)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-host mesh for CPU smoke runs: all local devices on 'data'."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
