"""Roofline-term derivation from compiled dry-run artifacts.

Terms (per device, seconds) on the TPU v5e target:
  compute    = HLO_FLOPs / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw            (819 GB/s)
  collective = collective_bytes / link_bw    (~50 GB/s/link ICI)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device after
SPMD). Collective bytes are NOT in cost_analysis: we parse the
post-partitioning HLO and sum the output shapes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,384]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z-]+)\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind (incl. -start/-done fusion
    variants; '-start' counted, '-done' skipped to avoid double counts)."""
    out = {k: 0 for k in COLLECTIVE_KINDS}
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_shapes, single_shape, opname = m.groups()
        op = opname.lower()
        if op.endswith("-start"):
            op = op[:-6]
        elif op.endswith("-done"):
            continue
        if op not in out:
            continue
        shape_str = tuple_shapes if tuple_shapes is not None else single_shape
        out[op] += shape_bytes(shape_str)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per device
    hbm_bytes: float           # per device
    coll_bytes: float          # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float  # 6·N·D (or 2·N·D inference)
    useful_ratio: float        # model_flops / (hlo_flops × chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def derive_terms(cost: dict, coll: dict, chips: int,
                 model_flops_global: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0) or 0.0)
    hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    cb = float(coll["total_bytes"])
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": cb / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    total_hlo = flops * chips
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=cb,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops_global=model_flops_global,
        useful_ratio=(model_flops_global / total_hlo) if total_hlo else 0.0)
