"""Optimizers in pure JAX (no optax offline): SGD, momentum, Adam, AdamW,
and the server-side federated pair FedAdam/FedYogi.

Interface mirrors optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. States are pytrees that shard like their params.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def _scalar_lr(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"count": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        count = state["count"] + 1
        step = _scalar_lr(lr, count)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            if nesterov:
                upd = jax.tree_util.tree_map(
                    lambda m, g: -(step * (momentum * m + g)), mu, grads)
            else:
                upd = jax.tree_util.tree_map(lambda m: -step * m, mu)
            return upd, {"count": count, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -step * g, grads)
        return upd, {"count": count}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, grad_clip: float = 0.0) -> Optimizer:
    """Adam/AdamW with optional global-norm clipping."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        step = _scalar_lr(lr, count)
        if grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -step * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay and p is not None:
                u = u - step * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(lambda m_, v_: upd(m_, v_, None), m, v)
        else:
            updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    return adam(lr, b1, b2, eps, weight_decay, grad_clip)


def _fedopt(lr, b1: float, b2: float, eps: float, yogi: bool) -> Optimizer:
    """Shared FedAdam/FedYogi core (Reddi et al., *Adaptive Federated
    Optimization*, 2021). The "gradient" fed in is the server
    pseudo-gradient Δ_t = Σ_k p_k (w_t − w_t^(k)); no bias correction,
    per the paper's server-side variant. FedYogi's second moment moves
    additively toward g² (``v − (1−b2)·sign(v − g²)·g²``) instead of the
    exponential average, which keeps v from inflating under the sparse,
    bursty pseudo-gradients that compressed client updates produce.
    """

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        step = _scalar_lr(lr, count)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        if yogi:
            def vupd(v_, g):
                g2 = jnp.square(g.astype(jnp.float32))
                return v_ - (1 - b2) * jnp.sign(v_ - g2) * g2
        else:
            def vupd(v_, g):
                g2 = jnp.square(g.astype(jnp.float32))
                return b2 * v_ + (1 - b2) * g2
        v = jax.tree_util.tree_map(vupd, state["v"], grads)
        updates = jax.tree_util.tree_map(
            lambda m_, v_: -step * m_ / (jnp.sqrt(v_) + eps), m, v)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def fedadam(lr, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    """Server-side Adam over the FedAvg pseudo-gradient Δ_t."""
    return _fedopt(lr, b1, b2, eps, yogi=False)


def fedyogi(lr, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    """Server-side Yogi over the FedAvg pseudo-gradient Δ_t."""
    return _fedopt(lr, b1, b2, eps, yogi=True)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


GETTERS = {"sgd": sgd, "adam": adam, "adamw": adamw,
           "fedadam": fedadam, "fedyogi": fedyogi}


def make(name: str, lr, **kw) -> Optimizer:
    return GETTERS[name](lr, **kw)
