from .optimizers import (Optimizer, adam, adamw, apply_updates, fedadam,
                         fedyogi, global_norm, make, sgd)
from .schedules import constant, inverse_sqrt, warmup_cosine
