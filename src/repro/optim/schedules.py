"""LR schedules as callables of the (1-based) step count."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: lr


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def f(count):
        c = count.astype(jnp.float32) if hasattr(count, "astype") else float(count)
        warm = peak * c / max(warmup_steps, 1)
        t = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(c < warmup_steps, warm, cos)
    return f


def inverse_sqrt(peak: float, warmup_steps: int):
    def f(count):
        c = count.astype(jnp.float32) if hasattr(count, "astype") else float(count)
        warm = peak * c / max(warmup_steps, 1)
        decay = peak * (warmup_steps / jnp.maximum(c, warmup_steps)) ** 0.5
        return jnp.where(c < warmup_steps, warm, decay)
    return f
