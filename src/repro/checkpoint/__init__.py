from .checkpoint import (CheckpointManager, reset_narrowing_warnings,
                         restore, restore_dict, save, tree_from_arrays,
                         tree_to_arrays)
