from .checkpoint import CheckpointManager, restore, save
