from .checkpoint import CheckpointManager, restore, restore_dict, save
