"""Pytree checkpointing: msgpack + zstd, with step rotation.

Layout: <dir>/step_<n>.ckpt, each file a zstd-compressed msgpack map
{treedef_json, leaves: [{dtype, shape, data}]}. Arrays round-trip
exactly (raw little-endian bytes); bfloat16 is stored via uint16 view.
Restore targets an example pytree (for structure) or the stored
structure alone.

``zstandard`` is an optional dependency: without it, checkpoints are
written as raw msgpack (restore auto-detects either format via the zstd
frame magic, so compressed and uncompressed files interoperate).
"""
from __future__ import annotations

import json
import os
import re
import warnings

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ModuleNotFoundError:      # optional: fall back to uncompressed
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _leaf_to_record(x) -> dict:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _record_to_leaf(rec: dict):
    shape = tuple(rec["shape"])
    if rec["dtype"] == "bfloat16":
        raw = np.frombuffer(rec["data"], np.uint16).reshape(shape)
        return jnp.asarray(raw).view(jnp.bfloat16)
    return jnp.asarray(np.frombuffer(rec["data"],
                                     np.dtype(rec["dtype"])).reshape(shape))


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save(path: str, tree) -> None:
    keys, leaves, _ = _paths(tree)
    payload = {"keys": keys, "leaves": [_leaf_to_record(x) for x in leaves]}
    packed = msgpack.packb(payload, use_bin_type=True)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    if zstandard is not None:
        packed = zstandard.ZstdCompressor(level=3).compress(packed)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(packed)
    os.replace(tmp, path)  # atomic


def _read_payload(path: str) -> dict:
    with open(path, "rb") as f:
        packed = f.read()
    if packed[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ModuleNotFoundError(
                f"{path} is zstd-compressed but the optional 'zstandard' "
                "module is not installed")
        packed = zstandard.ZstdDecompressor().decompress(packed)
    return msgpack.unpackb(packed, raw=False)


def _record_to_numpy(rec: dict):
    """Exact-dtype numpy leaf (no ``jnp.asarray``, which would truncate
    float64/int64 payloads to 32 bit under JAX's default x64=off and
    hand back immutable device arrays). bfloat16 stays numpy via
    ``ml_dtypes`` (a jax dependency)."""
    shape = tuple(rec["shape"])
    if rec["dtype"] == "bfloat16":
        import ml_dtypes
        return np.frombuffer(rec["data"], np.uint16).reshape(shape) \
            .copy().view(ml_dtypes.bfloat16)
    return np.frombuffer(rec["data"],
                         np.dtype(rec["dtype"])).reshape(shape).copy()


def restore_dict(path: str) -> dict:
    """Structure-free restore: the stored leaves as a flat
    ``{key: numpy array}`` mapping (keys are the "/"-joined tree paths),
    with dtypes preserved exactly.

    Unlike :func:`restore` this needs no ``like`` tree, so it fits
    payloads whose array shapes are unknowable a priori — e.g. a
    ``core.lifecycle.TaskState`` whose pending-schedule matrices vary
    per period (``lifecycle.load_state``).
    """
    payload = _read_payload(path)
    return {k: _record_to_numpy(rec)
            for k, rec in zip(payload["keys"], payload["leaves"])}


# key sets already warned about this process: a long-running service
# restoring the same state layout every period would otherwise emit the
# identical narrowing warning once per restore call (it used to fire
# per call; with per-leaf formatting that read as once per leaf).
# Distinct layouts (different narrowed-key sets) still warn once each.
_NARROWED_WARNED: set[frozenset] = set()


def reset_narrowing_warnings() -> None:
    """Forget which narrowed-key sets were already warned about (the
    once-per-run dedup in :func:`restore`). Test hook."""
    _NARROWED_WARNED.clear()


def restore(path: str, like):
    """Restore into the structure of ``like`` (keys must match).

    Leaves come back as jnp arrays, so under JAX's default ``x64=off``
    a float64/int64/uint64 payload is silently narrowed to 32 bit by
    ``jnp.asarray``. That is usually fine for model pytrees (which were
    32-bit on device to begin with) but wrong for exact host-side state
    — when it happens a ``UserWarning`` names the narrowed keys and
    points at :func:`restore_dict`, the structure-free entry point that
    preserves dtypes exactly, so the two entry points cannot disagree
    silently. The warning fires once per run per narrowed-key set
    (:func:`reset_narrowing_warnings` clears the dedup).
    """
    payload = _read_payload(path)
    keys, like_leaves, treedef = _paths(like)
    stored = dict(zip(payload["keys"], payload["leaves"]))
    missing = [k for k in keys if k not in stored]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves = [_record_to_leaf(stored[k]) for k in keys]
    narrowed = [k for k, rec, leaf in
                ((k, stored[k], leaf) for k, leaf in zip(keys, leaves))
                if str(leaf.dtype) != rec["dtype"]]
    if narrowed and frozenset(narrowed) not in _NARROWED_WARNED:
        _NARROWED_WARNED.add(frozenset(narrowed))
        warnings.warn(
            f"checkpoint.restore narrowed the stored dtype of "
            f"{len(narrowed)} leaves (e.g. "
            f"{narrowed[0]!r}: {stored[narrowed[0]]['dtype']} -> "
            f"{leaves[keys.index(narrowed[0])].dtype}) because JAX runs "
            f"with x64 disabled; use checkpoint.restore_dict for "
            f"exact-dtype numpy restore", UserWarning, stacklevel=2)
    for k, new, old in zip(keys, leaves, like_leaves):
        if tuple(new.shape) != tuple(np.shape(old)):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{new.shape} vs {np.shape(old)}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_to_arrays(tree, prefix: str = "") -> dict:
    """Flatten a pytree to ``{"/"-joined path: numpy array}``.

    The flat form trainers use to export server state (params +
    optimizer moments) into ``TaskState.trainer_state`` for format-4
    lifecycle checkpoints; invert with :func:`tree_from_arrays`.
    """
    keys, leaves, _ = _paths(tree)
    pre = prefix + "/" if prefix else ""
    return {pre + k: np.asarray(leaf) for k, leaf in zip(keys, leaves)}


def tree_from_arrays(like, arrays: dict, prefix: str = ""):
    """Rebuild a pytree structured like ``like`` from a
    :func:`tree_to_arrays` mapping (missing keys raise KeyError).
    Leaves come back as jnp arrays cast to the ``like`` leaf dtypes."""
    keys, like_leaves, treedef = _paths(like)
    pre = prefix + "/" if prefix else ""
    leaves = []
    for k, old in zip(keys, like_leaves):
        arr = arrays[pre + k]
        if tuple(arr.shape) != tuple(np.shape(old)):
            raise ValueError(f"shape mismatch at {k}: "
                             f"{arr.shape} vs {np.shape(old)}")
        leaves.append(jnp.asarray(arr).astype(np.asarray(old).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """step-numbered checkpoints with rotation."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.ckpt")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.ckpt", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, tree) -> str:
        p = self._step_path(step)
        save(p, tree)
        for old in self.steps()[:-self.keep]:
            os.remove(self._step_path(old))
        return p

    def restore_latest(self, like):
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return steps[-1], restore(self._step_path(steps[-1]), like)
