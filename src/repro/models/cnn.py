"""The paper's experiment model: a small CNN classifier for MNIST-like
(1x28x28) and CIFAR-like (3x32x32) data (paper §VIII), in pure JAX.

Mirrors the reference repo the paper builds on [14] (two conv blocks +
two dense layers).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "cnn-mnist"
    height: int = 28
    width: int = 28
    channels: int = 1
    num_classes: int = 10
    conv1: int = 16
    conv2: int = 32
    hidden: int = 128
    dtype: str = "float32"


MNIST_CNN = CNNConfig()
CIFAR_CNN = CNNConfig(name="cnn-cifar", height=32, width=32, channels=3,
                      conv1=32, conv2=64, hidden=256)


def init_params(cfg: CNNConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    h2, w2 = cfg.height // 4, cfg.width // 4         # two 2x2 maxpools
    flat = h2 * w2 * cfg.conv2

    def conv_init(k, shape):  # HWIO
        fan_in = shape[0] * shape[1] * shape[2]
        return (jax.random.normal(k, shape) * (2.0 / fan_in) ** 0.5).astype(dt)

    return {
        "conv1": {"w": conv_init(k1, (3, 3, cfg.channels, cfg.conv1)),
                  "b": jnp.zeros(cfg.conv1, dt)},
        "conv2": {"w": conv_init(k2, (3, 3, cfg.conv1, cfg.conv2)),
                  "b": jnp.zeros(cfg.conv2, dt)},
        "fc1": {"w": (jax.random.normal(k3, (flat, cfg.hidden)) * flat ** -0.5).astype(dt),
                "b": jnp.zeros(cfg.hidden, dt)},
        "fc2": {"w": (jax.random.normal(k4, (cfg.hidden, cfg.num_classes))
                      * cfg.hidden ** -0.5).astype(dt),
                "b": jnp.zeros(cfg.num_classes, dt)},
    }


def _conv_block(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    return jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def forward(cfg: CNNConfig, params, images):
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    x = _conv_block(images, params["conv1"])
    x = _conv_block(x, params["conv2"])
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(cfg: CNNConfig, params, batch):
    """batch: images (B,H,W,C), labels (B,), weights optional (B,)."""
    logits = forward(cfg, params, batch["images"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    w = batch.get("weights")
    loss = nll.mean() if w is None else jnp.sum(nll * w) / jnp.maximum(w.sum(), 1e-9)
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"loss": loss, "accuracy": acc}
