"""The paper's experiment model: a small CNN classifier for MNIST-like
(1x28x28) and CIFAR-like (3x32x32) data (paper §VIII), in pure JAX.

Mirrors the reference repo the paper builds on [14] (two conv blocks +
two dense layers).

``forward``/``loss_fn`` accept an ``impl`` knob selecting the lowering:

- ``"reference"`` (default): ``lax.conv_general_dilated`` +
  ``lax.reduce_window`` max-pool — the original formulation.
- ``"fast"``: identical math, CPU-friendly lowering — the first conv
  (few input channels) via im2col patches + matmul and 2x2 max-pool via
  a reshape + max. Forward outputs are bit-identical to "reference";
  gradients agree up to max-pool tie-breaking and f32 reduction order.
  On XLA CPU the backward pass avoids SelectAndScatter, which dominates
  the reference formulation's round time (~3x faster grads).
- ``"auto"``: "fast" off-TPU, "reference" on TPU (where the native
  conv/reduce_window path is the tuned one).

The device-resident FL data plane (fl.round.make_fl_rounds_scan) trains
with ``impl="auto"``; everything else keeps the reference lowering.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "cnn-mnist"
    height: int = 28
    width: int = 28
    channels: int = 1
    num_classes: int = 10
    conv1: int = 16
    conv2: int = 32
    hidden: int = 128
    dtype: str = "float32"


MNIST_CNN = CNNConfig()
CIFAR_CNN = CNNConfig(name="cnn-cifar", height=32, width=32, channels=3,
                      conv1=32, conv2=64, hidden=256)


def init_params(cfg: CNNConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    h2, w2 = cfg.height // 4, cfg.width // 4         # two 2x2 maxpools
    flat = h2 * w2 * cfg.conv2

    def conv_init(k, shape):  # HWIO
        fan_in = shape[0] * shape[1] * shape[2]
        return (jax.random.normal(k, shape) * (2.0 / fan_in) ** 0.5).astype(dt)

    return {
        "conv1": {"w": conv_init(k1, (3, 3, cfg.channels, cfg.conv1)),
                  "b": jnp.zeros(cfg.conv1, dt)},
        "conv2": {"w": conv_init(k2, (3, 3, cfg.conv1, cfg.conv2)),
                  "b": jnp.zeros(cfg.conv2, dt)},
        "fc1": {"w": (jax.random.normal(k3, (flat, cfg.hidden)) * flat ** -0.5).astype(dt),
                "b": jnp.zeros(cfg.hidden, dt)},
        "fc2": {"w": (jax.random.normal(k4, (cfg.hidden, cfg.num_classes))
                      * cfg.hidden ** -0.5).astype(dt),
                "b": jnp.zeros(cfg.num_classes, dt)},
    }


def _conv_direct(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_im2col(x, w):
    """3x3 SAME conv as 9 shifted slices + one matmul (im2col).

    Bit-identical to :func:`_conv_direct`; much faster on XLA CPU when
    the input channel count is small (the GEMM replaces a skinny conv).
    """
    B, H, W, Cin = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, i:i + H, j:j + W, :] for i in range(3) for j in range(3)]
    patches = jnp.concatenate(cols, axis=-1)            # (B,H,W,9*Cin)
    out = patches.reshape(B * H * W, 9 * Cin) @ w.reshape(9 * Cin, -1)
    return out.reshape(B, H, W, -1)


def _pool_window(y):
    return jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def _pool_reshape(y):
    """2x2 max-pool via reshape+max: same forward values as
    ``reduce_window`` (odd trailing rows/cols dropped, matching VALID
    windows); its VJP avoids XLA's SelectAndScatter (the CPU bottleneck
    of the reference formulation's backward pass)."""
    B, H, W, C = y.shape
    y = y[:, :H - H % 2, :W - W % 2, :]
    return y.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "reference" if jax.default_backend() == "tpu" else "fast"
    if impl not in ("reference", "fast"):
        raise ValueError(f"unknown cnn impl {impl!r}")
    return impl


def _conv_block(x, p, impl: str = "reference"):
    conv = _conv_im2col if impl == "fast" else _conv_direct
    pool = _pool_reshape if impl == "fast" else _pool_window
    y = jax.nn.relu(conv(x, p["w"]) + p["b"])
    return pool(y)


def forward(cfg: CNNConfig, params, images, impl: str = "reference"):
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    impl = _resolve_impl(impl)
    x = _conv_block(images, params["conv1"], impl)
    x = _conv_block(x, params["conv2"], impl)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(cfg: CNNConfig, params, batch, impl: str = "reference"):
    """batch: images (B,H,W,C), labels (B,), weights optional (B,)."""
    logits = forward(cfg, params, batch["images"], impl=impl)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    w = batch.get("weights")
    loss = nll.mean() if w is None else jnp.sum(nll * w) / jnp.maximum(w.sum(), 1e-9)
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"loss": loss, "accuracy": acc}
