"""Model configuration shared by all assigned architectures.

One ``ModelConfig`` covers the six architecture families (dense / moe /
hybrid / ssm / vlm / audio). Family-specific fields are zero/None when
unused. Configs are frozen dataclasses so they hash (usable as jit
static args).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    pad_experts_to: int = 0          # pad expert count for even EP sharding
                                     # (padded experts get -inf router logits
                                     # — function-preserving layout trick)

    # --- SSM / hybrid ---
    ssm_state: int = 0               # N (state dim per channel)
    ssm_expand: int = 2              # inner expansion for mamba/mLSTM blocks
    conv_kernel: int = 4             # depthwise causal conv width
    block_pattern: tuple = ()        # per-layer types for heterogeneous stacks
    chunk_size: int = 256            # chunkwise-parallel scan chunk

    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 -> full attention
    positional: str = "rope"         # rope | sinusoidal | none
    logit_soft_cap: float = 0.0

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    cross_attention: bool = False

    # --- stub modality frontend (vlm/audio carve-out) ---
    frontend: Optional[str] = None   # "vision" | "audio"
    frontend_seq: int = 0            # patches / frames fed to the backbone
    frontend_dim: int = 0            # embedding dim produced by the stub

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    use_pallas: bool = False         # route hot paths through Pallas kernels
    remat: bool = False              # activation checkpointing over layers
    unroll_layers: bool = False      # unroll the stack (dry-run cost fidelity:
                                     # XLA cost_analysis counts while bodies
                                     # once — see launch/dryrun.py)
    source: str = ""                 # citation for the assigned config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def layer_types(self) -> tuple:
        """Per-layer block types; homogeneous stacks return one type."""
        if self.block_pattern:
            if len(self.block_pattern) != self.num_layers:
                raise ValueError("block_pattern length != num_layers")
            return tuple(self.block_pattern)
        default = {
            "dense": "attn", "moe": "moe", "vlm": "attn", "audio": "attn",
            "hybrid": "hymba", "ssm": "mlstm",
        }[self.family]
        return tuple([default] * self.num_layers)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                num_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """CPU-smoke-test variant of the same family (prompt: <=2 layers,
        d_model<=512, <=4 experts)."""
        hd = min(self.resolved_head_dim, 64)
        heads = max(2, min(self.num_heads, d_model // hd))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        pattern = self.block_pattern[:num_layers] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=heads * hd if self.family != "hybrid" else heads * hd,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 2 * d_model) if self.d_ff else 0,
            vocab_size=vocab,
            num_experts=min(self.num_experts, num_experts) if self.is_moe else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            moe_d_ff=min(self.moe_d_ff, d_model) if self.is_moe else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            block_pattern=pattern,
            chunk_size=32,
            encoder_layers=min(self.encoder_layers, num_layers),
            frontend_seq=min(self.frontend_seq, 16) if self.frontend_seq else 0,
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
        )


def count_params(params) -> int:
    import jax
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS ≈ 6·N (dense) or 6·N_active per token (for §Roofline's
    useful-compute ratio). N excludes embeddings, includes active experts."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    att = d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)  # qkvo
    if cfg.is_moe:
        act_experts = cfg.top_k + cfg.num_shared_experts
        ffn = act_experts * 3 * d * cfg.moe_d_ff + d * cfg.num_experts  # + router
    elif cfg.d_ff:
        ffn = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    else:  # ssm blocks carry their own projections
        inner = cfg.ssm_expand * d
        ffn = 2 * d * inner + 3 * inner * inner // max(cfg.num_heads, 1)
    n_active = cfg.num_layers * (att + ffn)
    if cfg.is_enc_dec:
        n_active += cfg.encoder_layers * (att + ffn + att)  # + cross-attn
    return 6.0 * n_active
