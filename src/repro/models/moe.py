"""Mixture-of-Experts FFN (qwen2-moe: 60e top-4 + 4 shared; llama4-scout:
16e top-1 + 1 shared).

Token-choice top-k routing with capacity-bounded scatter dispatch — the
TPU-friendly formulation (DESIGN.md §4): tokens are scattered into a
dense per-expert buffer (E, C, d) so the expert matmuls are plain
batched GEMMs that shard cleanly with experts over the "model" mesh
axis (expert parallelism); overflow tokens are dropped, recovered by the
residual connection, exactly as in MaxText/Switch. An auxiliary
load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def padded_experts(cfg) -> int:
    return max(cfg.pad_experts_to, cfg.num_experts)


def moe_params(cfg, key):
    d, E = cfg.d_model, padded_experts(cfg)
    ff = cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, ff), dt),
        "w_up": dense_init(ks[2], (E, d, ff), dt),
        "w_down": dense_init(ks[3], (E, ff, d), dt),
    }
    if cfg.num_shared_experts:
        sff = cfg.num_shared_experts * ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, sff), dt),
            "w_up": dense_init(k2, (d, sff), dt),
            "w_down": dense_init(k3, (sff, d), dt),
        }
    return p


def _capacity(tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    cap = int(tokens * top_k * factor / num_experts)
    return max(cap, top_k)


def moe_ffn(cfg, p, x):
    """x: (B, S, d). Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, K = padded_experts(cfg), cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])         # (T, E)
    if E > cfg.num_experts:   # padded experts never receive probability
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # renormalize top-k

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros(E).at[expert_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    C = _capacity(T, E, K, cfg.capacity_factor)

    # position of each (token, k) within its expert, via cumsum of one-hot
    flat_e = expert_idx.reshape(T * K)                       # route-major order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (TK, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)                   # 0-based
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C

    # scatter tokens into (E, C, d)
    safe_pos = jnp.where(keep, pos_in_e, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype)
    src = jnp.repeat(xt, K, axis=0)                          # (TK, d)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[flat_e, safe_pos].add(src, mode="drop")

    # expert FFN: batched GEMMs (E, C, ff)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])     # (E, C, d)

    # gather back and combine with gates
    y = out_buf[flat_e, safe_pos]                            # (TK, d)
    w = (gate_vals.reshape(T * K) * keep).astype(x.dtype)
    y = (y * w[:, None]).reshape(T, K, d).sum(axis=1)

    if cfg.num_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    return y.reshape(B, S, d), aux


def moe_ffn_dense(cfg, p, x):
    """Oracle: every token through every expert, weighted by its top-k
    gates (no capacity drops). O(E·T·ff) — tests only."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    full = jnp.zeros((xt.shape[0], E), jnp.float32)
    for k in range(K):
        full = full.at[jnp.arange(xt.shape[0]), expert_idx[:, k]].add(gate_vals[:, k])
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) \
        * jnp.einsum("td,edf->tef", xt, p["w_up"])
    per_expert = jnp.einsum("tef,efd->ted", h, p["w_down"])
    y = jnp.einsum("ted,te->td", per_expert, full.astype(x.dtype))
    if cfg.num_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        y = y + hs @ sp["w_down"]
    return y.reshape(B, S, d)
