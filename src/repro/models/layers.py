"""Shared neural layers: norms, RoPE, GQA attention (causal / sliding
window / cross), MLPs. Pure functional JAX; params are plain dicts.

Weight layout conventions (chosen for clean tensor-parallel sharding,
see sharding/specs.py):
  wq: (d_model, H, hd)    wk/wv: (d_model, G, hd)    wo: (H, hd, d_model)
  w_gate/w_up: (d_model, d_ff)    w_down: (d_ff, d_model)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -2.0 ** 30  # large finite negative (bf16-safe masking)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, d_model: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    emb = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return emb[:, :d_model].astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_mask(q_positions, k_positions, causal: bool, window: int):
    """(..., Sq, Sk) boolean mask: True = attend."""
    qp = q_positions[..., :, None]
    kp = k_positions[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    return mask


def dot_product_attention(q, k, v, mask=None, soft_cap: float = 0.0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,G,hd) with H = G*rep (GQA).

    ``mask`` is boolean, broadcastable to (B, 1, Sq, Sk); True = attend.
    """
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    rep = H // G
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    qf = qf.reshape(B, Sq, G, rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k.astype(jnp.float32))
    if soft_cap > 0:
        scores = soft_cap * jnp.tanh(scores / soft_cap)
    if mask is not None:
        scores = jnp.where(mask[:, :, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def chunked_attention(q, k, v, positions, *, causal: bool, window: int,
                      soft_cap: float = 0.0, q_chunk: int = 1024):
    """Q-chunked attention: identical math to dot_product_attention but
    scores materialize one (B,H,q_chunk,Sk) block at a time (lax.scan over
    query blocks, jax.checkpoint'd so backward re-materializes per block).

    This is the XLA-level flash-attention fallback used on long sequences
    when the Pallas kernel isn't available (CPU dry-run / non-TPU), keeping
    the memory roofline term honest at 32k+ contexts.
    """
    B, Sq, H, hd = q.shape
    C = min(q_chunk, Sq)
    if Sq % C:
        pad = C - Sq % C
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)   # padded queries mask all
    nq = q.shape[1] // C
    qc = q.reshape(B, nq, C, H, hd).swapaxes(0, 1)            # (nq,B,C,H,hd)
    pc = positions.reshape(B, nq, C).swapaxes(0, 1)           # (nq,B,C)
    k_pos = positions[:, :k.shape[1]]

    def block(carry, xs):
        qb, pb = xs
        mask = attention_mask(pb, k_pos, causal, window)[:, None]
        mask &= (pb >= 0)[:, None, :, None]
        o = dot_product_attention(qb, k, v, mask, soft_cap)
        return carry, o

    _, outs = jax.lax.scan(jax.checkpoint(block), None, (qc, pc))
    out = outs.swapaxes(0, 1).reshape(B, nq * C, H, hd)
    return out[:, :Sq]


def qkv_project(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    return q, k, v


def out_project(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def build_kv_cache(k, v, positions, window: int = 0):
    """Build a (ring-buffer) KV cache from prefill K/V.

    k/v: (B, S, G, hd); positions: (B, S). With a sliding ``window`` the
    cache keeps only the last min(S, window) entries at slot
    ``pos % window`` (ring layout); otherwise capacity == S at slot = pos.
    ``pos`` records each slot's absolute position (-1 = empty).
    """
    B, S = k.shape[:2]
    if window <= 0 or window >= S:
        cap = S if window <= 0 else window
        pad = cap - S
        if pad:
            zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
            k, v = zeros(k), zeros(v)
            cpos = jnp.concatenate([positions[0],
                                    jnp.full((pad,), -1, jnp.int32)])
        else:
            cpos = positions[0]
        return {"k": k, "v": v, "pos": cpos}
    # ring layout: the last `window` tokens, slot = pos % window (unique)
    kw, vw = k[:, -window:], v[:, -window:]
    pos = positions[0, -window:]
    slots = pos % window
    ck = jnp.zeros((B, window) + k.shape[2:], k.dtype).at[:, slots].set(kw)
    cv = jnp.zeros((B, window) + v.shape[2:], v.dtype).at[:, slots].set(vw)
    cpos = jnp.full((window,), -1, jnp.int32).at[slots].set(pos)
    return {"k": ck, "v": cv, "pos": cpos}


def cache_attend(cfg, q, kv_cache, q_positions, window: int,
                 new_k=None, new_v=None):
    """Attend queries against a KV cache, optionally inserting this step's
    K/V first (decode). q: (B,Sq,H,hd); q_positions: (B,Sq)."""
    ck, cv, cpos = kv_cache["k"], kv_cache["v"], kv_cache["pos"]
    cap = ck.shape[1]
    if new_k is not None:
        wpos = q_positions[0]                       # (Sq,) new absolute pos
        slots = wpos % cap
        ck = ck.at[:, slots].set(new_k.astype(ck.dtype))
        cv = cv.at[:, slots].set(new_v.astype(cv.dtype))
        cpos = cpos.at[slots].set(wpos)
    valid = (cpos[None, None, :] >= 0) \
        & (cpos[None, None, :] <= q_positions[:, :, None])
    if window > 0:
        valid &= cpos[None, None, :] > q_positions[:, :, None] - window
    o = dot_product_attention(q, ck, cv, valid[:, None], cfg.logit_soft_cap)
    return o, {"k": ck, "v": cv, "pos": cpos}


def self_attention(cfg, p, x, positions, *, causal=True, window=None,
                   kv_cache=None, build_cache=False, flash_fn=None):
    """Self-attention sublayer.

    Returns (out, cache): cache is None in plain training mode, a fresh
    cache dict when ``build_cache`` (prefill), or the updated cache when
    ``kv_cache`` is given (decode).
    """
    window = cfg.sliding_window if window is None else window
    q, k, v = qkv_project(p, x)
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:   # decode: insert new K/V, attend to cache
        o, new_cache = cache_attend(cfg, q, kv_cache, positions, window,
                                    new_k=k, new_v=v)
        return out_project(p, o), new_cache

    if flash_fn is not None:
        o = flash_fn(q, k, v, causal=causal, window=window)
    elif x.shape[1] >= 4096:
        # long sequences: q-chunked attention (no (S,S) materialization)
        o = chunked_attention(q, k, v, positions, causal=causal,
                              window=window, soft_cap=cfg.logit_soft_cap)
    else:
        mask = attention_mask(positions, positions, causal, window)[:, None]
        o = dot_product_attention(q, k, v, mask, cfg.logit_soft_cap)
    cache = build_kv_cache(k, v, positions, window) if build_cache else None
    return out_project(p, o), cache


def cross_attention(cfg, p, x, memory):
    """Decoder->encoder attention (whisper). memory: (B, S_enc, D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", memory, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", memory, p["wv"])
    o = dot_product_attention(q, k, v, mask=None, soft_cap=cfg.logit_soft_cap)
    return out_project(p, o)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(cfg, p, x, swiglu_fn=None):
    if cfg.act == "swiglu":
        if swiglu_fn is not None:
            h = swiglu_fn(x, p["w_gate"], p["w_up"])
        else:
            h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) <= 2 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def norm_params(cfg):
    p = {"scale": jnp.ones(cfg.d_model, cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(cfg.d_model, cfg.param_dtype)
    return p


def attn_params(cfg, key):
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    return {
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, G, hd), dt),
        "wv": dense_init(ks[2], (d, G, hd), dt),
        "wo": dense_init(ks[3], (H, hd, d), dt, scale=(H * hd) ** -0.5),
    }


def mlp_params(cfg, key, d_ff=None):
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p = {"w_up": dense_init(ks[1], (d, d_ff), dt),
         "w_down": dense_init(ks[2], (d_ff, d), dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[0], (d, d_ff), dt)
    return p
