"""Generic decoder / encoder-decoder stack covering all assigned
architectures (dense GQA, MoE, hybrid attn+mamba, xLSTM, VLM and audio
backbones).

Homogeneous stacks are scanned over layers (stacked params, small HLO);
heterogeneous stacks (xLSTM's sLSTM/mLSTM pattern) unroll a Python loop
over per-layer param dicts.

Public API (used by fl/, launch/ and the examples):
  init_params(cfg, key)
  loss_fn(cfg, params, batch)                  -> (loss, metrics)
  forward(cfg, params, tokens, extras)         -> (logits, aux)
  prefill(cfg, params, tokens, extras)         -> (logits, cache, memory)
  init_decode_cache(cfg, B, cache_len)         -> cache (zeros)
  decode_step(cfg, params, tokens, cache, index) -> (logits, cache)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm as ssm_lib
from .common import ModelConfig
from .layers import (apply_norm, attn_params, cross_attention, dense_init,
                     mlp, mlp_params, norm_params, self_attention,
                     sinusoidal_embedding)

SCANNABLE = {"attn", "moe", "hymba", "xattn", "mlstm"}


# ---------------------------------------------------------------------------
# Per-layer params
# ---------------------------------------------------------------------------

def layer_params(cfg: ModelConfig, ltype: str, key):
    ks = jax.random.split(key, 6)
    if ltype == "mlstm":
        return ssm_lib.mlstm_block_params(cfg, key)
    if ltype == "slstm":
        return ssm_lib.slstm_block_params(cfg, key)
    p = {"norm1": norm_params(cfg), "attn": attn_params(cfg, ks[0]),
         "norm2": norm_params(cfg)}
    if ltype == "attn":
        p["mlp"] = mlp_params(cfg, ks[1])
    elif ltype == "moe":
        p["moe"] = moe_lib.moe_params(cfg, ks[1])
    elif ltype == "hymba":
        p["mamba"] = ssm_lib.mamba_head_params(cfg, ks[1])
        p["mlp"] = mlp_params(cfg, ks[2])
    elif ltype == "xattn":
        p["norm_x"] = norm_params(cfg)
        p["xattn"] = attn_params(cfg, ks[3])
        p["mlp"] = mlp_params(cfg, ks[1])
    else:
        raise ValueError(f"unknown layer type {ltype}")
    return p


# ---------------------------------------------------------------------------
# Per-layer apply. All types share the signature
#   (p, x, positions, cache, memory) -> (x, new_cache, aux)
# cache=None in train mode; build_cache=True => prefill returns fresh cache;
# decode=True => Sq==1 update against the given cache.
# ---------------------------------------------------------------------------

def layer_apply(cfg: ModelConfig, ltype: str, p, x, positions, cache=None,
                memory=None, *, decode=False, build_cache=False,
                flash_fn=None, swiglu_fn=None):
    aux = jnp.zeros((), jnp.float32)
    if ltype == "mlstm":
        state = conv = None
        if cache is not None:
            state, conv = cache["state"], cache["conv"]
        x, (state, conv) = ssm_lib.mlstm_block_apply(
            cfg, p, x, state, conv, decode=decode, build_cache=build_cache)
        newc = {"state": state, "conv": conv} if (cache is not None or
                                                  build_cache) else None
        return x, newc, aux

    if ltype == "slstm":
        state = cache["state"] if cache is not None else None
        x, state = ssm_lib.slstm_block_apply(cfg, p, x, state)
        newc = {"state": state} if (cache is not None or build_cache) else None
        return x, newc, aux

    if ltype == "hymba":
        h = apply_norm(cfg, p["norm1"], x)
        kv = cache["kv"] if cache is not None else None
        attn_o, new_kv = self_attention(cfg, p["attn"], h, positions,
                                        causal=True, kv_cache=kv,
                                        build_cache=build_cache,
                                        flash_fn=flash_fn)
        state = conv = None
        if cache is not None:
            state, conv = cache["state"], cache["conv"]
        mamba_o, (state, conv) = ssm_lib.mamba_head_apply(
            cfg, p["mamba"], h, state, conv, decode=decode,
            build_cache=build_cache)
        x = x + 0.5 * (attn_o + mamba_o)          # parallel-head fusion
        h = apply_norm(cfg, p["norm2"], x)
        x = x + mlp(cfg, p["mlp"], h, swiglu_fn)
        newc = ({"kv": new_kv, "state": state, "conv": conv}
                if (cache is not None or build_cache) else None)
        return x, newc, aux

    # attention-based layers (attn / moe / xattn)
    kv = cache["kv"] if cache is not None else None
    h = apply_norm(cfg, p["norm1"], x)
    o, new_kv = self_attention(cfg, p["attn"], h, positions, causal=True,
                               kv_cache=kv, build_cache=build_cache,
                               flash_fn=flash_fn)
    x = x + o
    if ltype == "xattn":
        h = apply_norm(cfg, p["norm_x"], x)
        x = x + cross_attention(cfg, p["xattn"], h, memory)
    h = apply_norm(cfg, p["norm2"], x)
    if ltype == "moe":
        y, aux = moe_lib.moe_ffn(cfg, p["moe"], h)
        x = x + y
    else:
        x = x + mlp(cfg, p["mlp"], h, swiglu_fn)
    newc = {"kv": new_kv} if (cache is not None or build_cache) else None
    return x, newc, aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _is_homogeneous(cfg) -> bool:
    if cfg.unroll_layers:
        return False
    types = set(cfg.layer_types)
    return len(types) == 1 and next(iter(types)) in SCANNABLE


def stack_params(cfg: ModelConfig, key, num_layers=None, ltype=None):
    """Stacked (scan) params for homogeneous stacks, list otherwise."""
    L = num_layers or cfg.num_layers
    types = [ltype] * L if ltype else list(cfg.layer_types)
    keys = jax.random.split(key, L)
    if (len(set(types)) == 1 and types[0] in SCANNABLE
            and not cfg.unroll_layers):
        return jax.vmap(lambda k: layer_params(cfg, types[0], k))(keys)
    return [layer_params(cfg, t, k) for t, k in zip(types, keys)]


def stack_apply(cfg, params, x, positions, cache=None, memory=None, *,
                decode=False, build_cache=False, flash_fn=None,
                swiglu_fn=None):
    """Apply the layer stack. Returns (x, new_cache, aux)."""
    types = list(cfg.layer_types)
    zero = jnp.zeros((), jnp.float32)

    if isinstance(params, list):  # heterogeneous: unrolled loop
        new_cache, aux = [], zero
        for i, (t, p) in enumerate(zip(types, params)):
            c = cache[i] if cache is not None else None
            fn = functools.partial(layer_apply, cfg, t, decode=decode,
                                   build_cache=build_cache,
                                   flash_fn=flash_fn, swiglu_fn=swiglu_fn)
            if cfg.remat and not decode:
                fn = jax.checkpoint(fn)
            x, nc, a = fn(p, x, positions, c, memory)
            new_cache.append(nc)
            aux = aux + a
        has_cache = cache is not None or build_cache
        return x, (new_cache if has_cache else None), aux

    t = types[0]
    if cache is None:
        # train / prefill: scan over stacked params; the (optional) fresh
        # cache comes out as scan outputs.
        def body(carry, p):
            x, aux = carry
            x, nc, a = layer_apply(cfg, t, p, x, positions, None, memory,
                                   decode=False, build_cache=build_cache,
                                   flash_fn=flash_fn, swiglu_fn=swiglu_fn)
            return (x, aux + a), nc
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), ys = jax.lax.scan(body, (x, zero), params)
        return x, (ys if build_cache else None), aux

    # decode: scan over (stacked params, stacked cache)
    def body(carry, xs):
        x, aux = carry
        p, c = xs
        x, nc, a = layer_apply(cfg, t, p, x, positions, c, memory,
                               decode=decode, flash_fn=flash_fn,
                               swiglu_fn=swiglu_fn)
        return (x, aux + a), nc

    (x, aux), ys = jax.lax.scan(body, (x, zero), (params, cache))
    return x, ys, aux


# ---------------------------------------------------------------------------
# Cache construction (zeros — used for serve_step input specs and tests)
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, ltype: str, B: int, cache_len: int,
                     dtype):
    G, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    d = cfg.d_model

    def kv_cache(length):
        W = min(length, cfg.sliding_window) if cfg.sliding_window else length
        return {"k": jnp.zeros((B, W, G, hd), dtype),
                "v": jnp.zeros((B, W, G, hd), dtype),
                "pos": jnp.full((W,), -1, jnp.int32)}

    if ltype in ("attn", "moe", "xattn"):
        return {"kv": kv_cache(cache_len)}
    if ltype == "hymba":
        dh = d // H
        return {"kv": kv_cache(cache_len),
                "state": {"S": jnp.zeros((B, H, cfg.ssm_state, dh), jnp.float32),
                          "n": jnp.zeros((B, H, cfg.ssm_state), jnp.float32),
                          "m": jnp.zeros((B, H), jnp.float32)},
                "conv": jnp.zeros((B, cfg.conv_kernel - 1, d), dtype)}
    if ltype == "mlstm":
        inner = cfg.ssm_expand * d
        dh = inner // H
        return {"state": {"S": jnp.zeros((B, H, dh, dh), jnp.float32),
                          "n": jnp.zeros((B, H, dh), jnp.float32),
                          "m": jnp.zeros((B, H), jnp.float32)},
                "conv": jnp.zeros((B, cfg.conv_kernel - 1, inner), dtype)}
    if ltype == "slstm":
        dh = d // H
        z = jnp.zeros((B, H, dh), jnp.float32)
        return {"state": {"c": z, "n": z, "h": z, "m": z}}
    raise ValueError(ltype)


def init_decode_cache(cfg: ModelConfig, B: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.param_dtype
    types = list(cfg.layer_types)
    if _is_homogeneous(cfg):
        per = [init_layer_cache(cfg, types[0], B, cache_len, dtype)
               for _ in range(cfg.num_layers)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    return [init_layer_cache(cfg, t, B, cache_len, dtype) for t in types]


# ---------------------------------------------------------------------------
# Model init / top-level forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    p = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
        "layers": stack_params(cfg, ks[1]),
        "final_norm": norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.frontend:  # stub-frontend projector (vlm/audio carve-out)
        fd = cfg.frontend_dim
        p["projector"] = {
            "w1": dense_init(ks[3], (fd, cfg.d_model), dt),
            "w2": dense_init(ks[4], (cfg.d_model, cfg.d_model), dt),
        }
    if cfg.is_enc_dec:
        ek1, _ = jax.random.split(ks[5])
        p["encoder"] = {
            "layers": stack_params(cfg, ek1, cfg.encoder_layers, "attn"),
            "final_norm": norm_params(cfg),
        }
    return p


def _project_frontend(params, embeds):
    h = jax.nn.gelu(embeds @ params["projector"]["w1"], approximate=True)
    return h @ params["projector"]["w2"]


def _encode(cfg, params, frames):
    """Whisper-style encoder over stub frame embeddings (B, F, fd)."""
    x = _project_frontend(params, frames)
    S = x.shape[1]
    x = x + sinusoidal_embedding(S, cfg.d_model, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), x.shape[:2])

    def one_layer(x, p):
        h = apply_norm(cfg, p["norm1"], x)
        o, _ = self_attention(cfg, p["attn"], h, positions, causal=False,
                              window=0)
        x = x + o
        h = apply_norm(cfg, p["norm2"], x)
        return x + mlp(cfg, p["mlp"], h)

    enc_layers = params["encoder"]["layers"]
    if isinstance(enc_layers, list):       # unrolled (dry-run cost fidelity)
        for p in enc_layers:
            fn = jax.checkpoint(one_layer) if cfg.remat else one_layer
            x = fn(x, p)
    else:
        def body(carry, p):
            return one_layer(carry, p), None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, enc_layers)
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


def embed_inputs(cfg, params, tokens, extras=None):
    """Token embedding + optional modality prefix. Returns (x, positions,
    n_prefix, memory)."""
    extras = extras or {}
    x = params["embed"][tokens]
    memory = None
    n_prefix = 0
    if cfg.frontend == "vision" and "patch_embeds" in extras:
        prefix = _project_frontend(params, extras["patch_embeds"]).astype(x.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
        n_prefix = prefix.shape[1]
    if cfg.is_enc_dec:
        memory = _encode(cfg, params, extras["frames"])
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (x.shape[0], S))
    if cfg.positional == "sinusoidal":
        x = x + sinusoidal_embedding(S, cfg.d_model, x.dtype)[None]
    return x, positions, n_prefix, memory


def unembed(cfg, params, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def forward(cfg: ModelConfig, params, tokens, extras=None, flash_fn=None,
            swiglu_fn=None):
    """Full-sequence logits (train path). Returns (logits, aux)."""
    x, positions, n_prefix, memory = embed_inputs(cfg, params, tokens, extras)
    x, _, aux = stack_apply(cfg, params["layers"], x, positions, memory=memory,
                            flash_fn=flash_fn, swiglu_fn=swiglu_fn)
    x = apply_norm(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    return unembed(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params, batch, flash_fn=None, swiglu_fn=None):
    """Weighted next-token cross-entropy.

    batch: tokens (B,S) int32, targets (B,S) int32 (-1 = masked), weights
    (B,) federated per-client weights p_k (optional), plus modality extras.
    """
    extras = {k: batch[k] for k in ("patch_embeds", "frames") if k in batch}
    logits, aux = forward(cfg, params, batch["tokens"], extras,
                          flash_fn=flash_fn, swiglu_fn=swiglu_fn)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0] * mask
    per_ex = nll.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)     # (B,)
    w = batch.get("weights")
    if w is None:
        loss = per_ex.mean()
    else:
        loss = jnp.sum(per_ex * w) / jnp.maximum(jnp.sum(w), 1e-9)
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, extras=None, flash_fn=None,
            swiglu_fn=None):
    """Run the prompt, build the cache. Returns (last logits, cache, memory)."""
    x, positions, n_prefix, memory = embed_inputs(cfg, params, tokens, extras)
    x, cache, _ = stack_apply(cfg, params["layers"], x, positions,
                              memory=memory, build_cache=True,
                              flash_fn=flash_fn, swiglu_fn=swiglu_fn)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x[:, -1:])
    return logits, cache, memory


def grow_cache(cfg: ModelConfig, cache, extra: int):
    """Extend a full (non-ring) KV cache by ``extra`` decode slots."""
    def grow(leaf_path, leaf):
        return leaf

    def _grow_kv(c):
        if isinstance(c, dict) and "kv" in c and (not cfg.sliding_window):
            kv = c["kv"]
            pad = lambda a: jnp.pad(a, ((0, 0), (0, extra)) + ((0, 0),) * (a.ndim - 2))
            c = dict(c)
            c["kv"] = {"k": pad(kv["k"]), "v": pad(kv["v"]),
                       "pos": jnp.concatenate([kv["pos"],
                                               jnp.full((extra,), -1, jnp.int32)])}
        return c

    if isinstance(cache, list):
        return [_grow_kv(c) for c in cache]
    if isinstance(cache, dict) and "kv" in cache and not cfg.sliding_window:
        kv = cache["kv"]  # stacked (L, B, S, G, hd)
        pad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, extra)) + ((0, 0),) * (a.ndim - 3))
        cache = dict(cache)
        cache["kv"] = {"k": pad(kv["k"]), "v": pad(kv["v"]),
                       "pos": jnp.pad(kv["pos"], ((0, 0), (0, extra)),
                                      constant_values=-1)}
    return cache


def decode_step(cfg: ModelConfig, params, tokens, cache, index, memory=None,
                flash_fn=None, swiglu_fn=None):
    """One decode step. tokens: (B, 1); index: scalar int32 absolute
    position. Returns (logits, new_cache)."""
    x = params["embed"][tokens]
    if cfg.positional == "sinusoidal":
        x = x + _sin_at(jnp.asarray(index), cfg.d_model, x.dtype)[None, None]
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    x, cache, _ = stack_apply(cfg, params["layers"], x, positions, cache=cache,
                              memory=memory, decode=True, flash_fn=flash_fn,
                              swiglu_fn=swiglu_fn)
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(cfg, params, x), cache


def _sin_at(index, d_model, dtype):
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    angle = index.astype(jnp.float32) / jnp.power(10_000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])[:d_model].astype(dtype)
