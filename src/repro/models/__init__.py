"""Model zoo: generic transformer stack + paper CNN."""
from .common import ModelConfig, count_params, model_flops_per_token
from . import transformer, cnn, layers, moe, ssm
