"""Sequence-state models: chunkwise gated linear attention (mLSTM / SSD),
sLSTM, and the xLSTM / Hymba block definitions.

TPU adaptation (DESIGN.md §4): GPU selective-scan kernels don't port to
the MXU; instead we use the *chunkwise-parallel* form — intra-chunk work
is a small causal attention (MXU-friendly matmuls), inter-chunk state is
a short ``lax.scan`` over chunk boundaries. Hymba's mamba heads use the
Mamba-2/SSD simplification (scalar per-head decay), which is exactly the
same primitive as mLSTM without the input-gate/normalizer machinery.

``gated_linear_attention`` is the pure-jnp oracle mirrored by
``kernels/mlstm_scan.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# Chunkwise gated linear attention
#   S_t = f_t · S_{t-1} + i_t · k_t v_tᵀ         (state  (dk, dv))
#   n_t = f_t · n_{t-1} + i_t · k_t              (normalizer, mLSTM only)
#   h_t = (q_tᵀ S_t) / max(|q_tᵀ n_t|, 1)        (mLSTM) or q_tᵀ S_t (SSD)
# computed with exp-gate stabilization in log space (xLSTM appendix).
# ---------------------------------------------------------------------------

def gated_linear_attention(q, k, v, log_f, log_i=None, *, chunk: int = 64,
                           normalize: bool = True, initial_state=None):
    """q,k: (B,S,H,dk) v: (B,S,H,dv); log_f/log_i: (B,S,H).

    Returns (out (B,S,H,dv), final_state dict{S,n,m}).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        zf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v, log_f = map(zf, (q, k, v, log_f))
        if log_i is not None:
            log_i = zf(log_i)
        # padded steps must not change state: force f=1 (log 0), i=0 (-inf)
        mask_t = jnp.arange(q.shape[1])[None, :, None] < S
        log_f = jnp.where(mask_t, log_f, 0.0)
        if log_i is None:
            log_i = jnp.where(mask_t, 0.0, -jnp.inf)
            log_i = jnp.broadcast_to(log_i, log_f.shape)
        else:
            log_i = jnp.where(mask_t, log_i, -jnp.inf)
    elif log_i is None:
        log_i = jnp.zeros_like(log_f)
    Sp = q.shape[1]
    NC = Sp // chunk

    # (B, NC, C, H, d) -> transpose to (NC, B, H, C, d) for the scan
    def chunked(x, d_last):
        x = x.reshape(B, NC, chunk, H, -1) if d_last else x.reshape(B, NC, chunk, H)
        return jnp.moveaxis(jnp.moveaxis(x, 3, 2), 0, 1)  # (NC,B,H,C,[d])

    qc, kc, vc = chunked(q, True), chunked(k, True), chunked(v, True)
    fc, ic = chunked(log_f, False), chunked(log_i, False)

    f32 = jnp.float32
    if initial_state is None:
        S0 = jnp.zeros((B, H, dk, dv), f32)
        n0 = jnp.zeros((B, H, dk), f32)
        m0 = jnp.zeros((B, H), f32)
    else:
        S0, n0, m0 = (initial_state["S"].astype(f32),
                      initial_state["n"].astype(f32),
                      initial_state["m"].astype(f32))

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, xs):
        Sm, nm, m_prev = carry
        qj, kj, vj, fj, ij = xs            # (B,H,C,d)/(B,H,C)
        qj, kj, vj = qj.astype(f32), kj.astype(f32), vj.astype(f32)
        g = jnp.cumsum(fj, axis=-1)        # inclusive cumulative log-decay
        G = g[..., -1]                     # (B,H)
        # log-weights
        inter = g + m_prev[..., None]                           # (B,H,C)
        intra = g[..., :, None] - g[..., None, :] + ij[..., None, :]  # (B,H,C,C)
        intra = jnp.where(causal, intra, -jnp.inf)
        M = jnp.maximum(inter, intra.max(axis=-1))              # (B,H,C)
        M = jnp.where(jnp.isfinite(M), M, 0.0)
        if not normalize:
            # no denominator to cancel the stabilizer -> must emit true
            # values. Decays are <= 0 in the SSD case, so exp() is safe.
            M = jnp.zeros_like(M)
        w_inter = jnp.exp(inter - M)                            # (B,H,C)
        w_intra = jnp.exp(intra - M[..., None])                 # (B,H,C,C)
        qk = jnp.einsum("bhcd,bhed->bhce", qj, kj)
        scores = qk * w_intra
        y = jnp.einsum("bhce,bhed->bhcd", scores, vj) \
            + w_inter[..., None] * jnp.einsum("bhcd,bhde->bhce", qj, Sm)
        if normalize:
            nrm = scores.sum(axis=-1) \
                + w_inter * jnp.einsum("bhcd,bhd->bhc", qj, nm)
            denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-M))
            out = y / denom[..., None]
        else:
            out = y
        # state update
        m_new = jnp.maximum(G + m_prev, (G[..., None] - g + ij).max(axis=-1))
        m_new = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        decay_state = jnp.exp(G + m_prev - m_new)               # (B,H)
        w_k = jnp.exp(G[..., None] - g + ij - m_new[..., None])  # (B,H,C)
        S_new = decay_state[..., None, None] * Sm \
            + jnp.einsum("bhc,bhcd,bhce->bhde", w_k, kj, vj)
        n_new = decay_state[..., None] * nm \
            + jnp.einsum("bhc,bhcd->bhd", w_k, kj)
        return (S_new, n_new, m_new), out

    (Sf, nf, mf), outs = jax.lax.scan(step, (S0, n0, m0), (qc, kc, vc, fc, ic))
    # outs: (NC,B,H,C,dv) -> (B,H,NC*C,dv) -> (B,S,H,dv)
    out = jnp.transpose(outs, (1, 2, 0, 3, 4)).reshape(B, H, Sp, dv)
    out = jnp.moveaxis(out, 1, 2)[:, :S]
    return out.astype(v.dtype), {"S": Sf, "n": nf, "m": mf}


def gla_decode_step(q, k, v, log_f, log_i, state, *, normalize: bool = True):
    """Single-token recurrent update. q,k: (B,H,dk), v: (B,H,dv),
    log_f/log_i: (B,H); state dict{S,n,m}. Returns (out (B,H,dv), state)."""
    f32 = jnp.float32
    out_dtype = v.dtype
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    Sm, nm, m_prev = state["S"], state["n"], state["m"]
    if log_i is None:
        log_i = jnp.zeros_like(log_f)
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_s = jnp.exp(log_f + m_prev - m_new)
    i_s = jnp.exp(log_i - m_new)
    S_new = f_s[..., None, None] * Sm + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = f_s[..., None] * nm + i_s[..., None] * k
    y = jnp.einsum("bhd,bhde->bhe", q, S_new)
    if normalize:
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                            jnp.exp(-m_new))
        y = y / denom[..., None]
    else:
        # state is stored stabilized (S_true = e^m S); undo for raw output
        y = y * jnp.exp(m_new)[..., None]
    return y.astype(out_dtype), {"S": S_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# Causal depthwise conv (pre-QK conv used by mamba/xLSTM blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, cache=None):
    """x: (B,S,D), w: (K,D) depthwise. Returns (y, new_cache).

    cache (decode): (B, K-1, D) last inputs."""
    K = w.shape[0]
    if cache is not None:
        window = jnp.concatenate([cache, x], axis=1)      # (B, K-1+S, D)
        y = jnp.einsum("bkd,kd->bd", window[:, -K:], w)[:, None]
        return jax.nn.silu(y), window[:, -(K - 1):]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(y), None


def conv_cache_from(x, K: int):
    """The last K-1 inputs, left-padded — a fresh decode cache after
    prefill over x (B,S,D)."""
    B, S, D = x.shape
    if S >= K - 1:
        return x[:, S - (K - 1):]
    return jnp.pad(x, ((0, 0), (K - 1 - S, 0), (0, 0)))


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory recurrent, xLSTM §2.1) — sequential scan over time
# ---------------------------------------------------------------------------

def slstm_apply(p, x, H, state=None):
    """x: (B,S,D). Gates from input + block-diagonal recurrent R per head.
    Returns (out (B,S,D), state)."""
    B, S, D = x.shape
    dh = D // H
    gates_x = jnp.einsum("bsd,dg->bsg", x, p["w_gates"]) + p["b_gates"]  # (B,S,4D)
    gates_x = gates_x.reshape(B, S, 4, H, dh)
    if state is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        state = {"c": zeros, "n": zeros, "h": zeros,
                 "m": jnp.zeros((B, H, dh), jnp.float32)}

    R = p["r_gates"]  # (H, dh, 4, dh) block-diagonal recurrent weights

    def step(carry, g_t):
        c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
        g = g_t + jnp.einsum("bhd,hdge->bghe", h.astype(x.dtype), R).astype(jnp.float32)
        i_t, f_t, z_t, o_t = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(z_t)
        n_new = jnp.maximum(f_s * n + i_s, 1.0)
        h_new = jax.nn.sigmoid(o_t) * c_new / n_new
        new = {"c": c_new, "n": n_new, "h": h_new, "m": m_new}
        return new, h_new

    gx = jnp.moveaxis(gates_x.astype(jnp.float32), 1, 0)  # (S,B,4,H,dh)
    state, hs = jax.lax.scan(step, state, gx)
    out = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    return out, state


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def mlstm_block_params(cfg, key):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    return {
        "norm": {"scale": jnp.ones(d, dt)},
        "w_up": dense_init(ks[0], (d, inner), dt),
        "w_gate": dense_init(ks[1], (d, inner), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, inner), dt, scale=0.5),
        "wq": dense_init(ks[3], (inner, inner), dt),
        "wk": dense_init(ks[4], (inner, inner), dt),
        "wv": dense_init(ks[5], (inner, inner), dt),
        "w_if": dense_init(ks[6], (inner, 2 * H), dt, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros(H), jnp.linspace(3.0, 6.0, H)]).astype(dt),
        "head_norm": jnp.ones((H, inner // H), dt),
        "w_down": dense_init(ks[7], (inner, d), dt),
    }


def mlstm_block_apply(cfg, p, x, state=None, conv_cache=None, decode=False,
                      build_cache=False):
    """xLSTM mLSTM block. Returns (out, (state, conv_cache))."""
    B, S, d = x.shape
    H = cfg.num_heads
    inner = cfg.ssm_expand * d
    dh = inner // H
    h = rmsnorm(x, p["norm"]["scale"])
    u = h @ p["w_up"]
    z = h @ p["w_gate"]
    c, conv_cache = causal_conv1d(u, p["conv_w"], conv_cache)
    q = (c @ p["wq"]).reshape(B, S, H, dh)
    k = (c @ p["wk"]).reshape(B, S, H, dh) * (dh ** -0.5)
    v = (u @ p["wv"]).reshape(B, S, H, dh)
    gates = (u @ p["w_if"] + p["b_if"]).astype(jnp.float32)    # (B,S,2H)
    log_i = gates[..., :H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])
    if decode:
        y, state = gla_decode_step(q[:, 0], k[:, 0], v[:, 0],
                                   log_f[:, 0], log_i[:, 0], state)
        y = y[:, None]
    else:
        y, state = gated_linear_attention(q, k, v, log_f, log_i,
                                          chunk=cfg.chunk_size,
                                          initial_state=state)
        if build_cache:
            conv_cache = conv_cache_from(u, cfg.conv_kernel)
    y = rmsnorm(y, p["head_norm"]).reshape(B, S, inner)
    out = (y * jax.nn.silu(z)) @ p["w_down"]
    return x + out, (state, conv_cache)


def slstm_block_params(cfg, key):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    ff = max(1, int(d * 4 / 3) // 8 * 8)
    return {
        "norm": {"scale": jnp.ones(d, dt)},
        "w_gates": dense_init(ks[0], (d, 4 * d), dt),
        "b_gates": jnp.tile(jnp.concatenate(
            [jnp.zeros(d), jnp.ones(d) * 3.0, jnp.zeros(2 * d)]), (1,)).astype(dt).reshape(4 * d),
        "r_gates": dense_init(ks[1], (H, dh, 4, dh), dt, scale=dh ** -0.5),
        "head_norm": jnp.ones((H, dh), dt),
        "ffn_norm": {"scale": jnp.ones(d, dt)},
        "w_ff_gate": dense_init(ks[2], (d, ff), dt),
        "w_ff_up": dense_init(ks[3], (d, ff), dt),
        "w_ff_down": dense_init(ks[4], (ff, d), dt),
    }


def slstm_block_apply(cfg, p, x, state=None):
    B, S, d = x.shape
    H = cfg.num_heads
    h = rmsnorm(x, p["norm"]["scale"])
    y, state = slstm_apply({k: p[k] for k in ("w_gates", "b_gates", "r_gates")},
                           h, H, state)
    y = rmsnorm(y.reshape(B, S, H, d // H), p["head_norm"]).reshape(B, S, d)
    x = x + y
    h = rmsnorm(x, p["ffn_norm"]["scale"])
    ff = jax.nn.silu(h @ p["w_ff_gate"]) * (h @ p["w_ff_up"])
    return x + ff @ p["w_ff_down"], state


def mamba_head_params(cfg, key):
    """Hymba's mamba heads (Mamba-2/SSD form, scalar per-head decay)."""
    d = cfg.d_model
    H = cfg.num_heads
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "w_in": dense_init(ks[0], (d, d), dt),
        "w_gate": dense_init(ks[1], (d, d), dt),
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, d), dt, scale=0.5),
        "w_bc": dense_init(ks[3], (d, 2 * H * N), dt),
        "w_dt": dense_init(ks[4], (d, H), dt, scale=0.01),
        "b_dt": jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, H))).astype(dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "d_skip": jnp.ones(H, dt),
        "head_norm": jnp.ones((H, d // H), dt),
        "w_out": dense_init(ks[5], (d, d), dt),
    }


def mamba_head_apply(cfg, p, x, state=None, conv_cache=None, decode=False,
                     build_cache=False):
    """x: (B,S,D) (already normed by the caller). Returns (out, state)."""
    B, S, d = x.shape
    H, N = cfg.num_heads, cfg.ssm_state
    dh = d // H
    u = x @ p["w_in"]
    g = x @ p["w_gate"]
    c, conv_cache = causal_conv1d(u, p["conv_w"], conv_cache)
    bc = (c @ p["w_bc"]).reshape(B, S, 2, H, N)
    Bt, Ct = bc[:, :, 0], bc[:, :, 1]                     # (B,S,H,N)
    dt_ = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32)
                          + p["b_dt"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))          # (H,) negative
    log_decay = dt_ * A                                    # (B,S,H) <= 0
    v = u.reshape(B, S, H, dh) * dt_[..., None].astype(u.dtype)
    if decode:
        y, state = gla_decode_step(Ct[:, 0], Bt[:, 0], v[:, 0],
                                   log_decay[:, 0], None, state,
                                   normalize=False)
        y = y[:, None]
    else:
        y, state = gated_linear_attention(Ct, Bt, v, log_decay, None,
                                          chunk=cfg.chunk_size,
                                          normalize=False,
                                          initial_state=state)
        if build_cache:
            conv_cache = conv_cache_from(u, cfg.conv_kernel)
    y = y + u.reshape(B, S, H, dh) * p["d_skip"][:, None]
    y = rmsnorm(y, p["head_norm"]).reshape(B, S, d)
    return (y * jax.nn.silu(g)) @ p["w_out"], (state, conv_cache)
