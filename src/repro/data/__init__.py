from .synthetic import (ClassificationData, LMData, histogram,
                        make_classification_data, make_lm_data)
