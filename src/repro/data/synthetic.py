"""Synthetic datasets (offline container — DESIGN.md §2).

``make_classification_data`` produces MNIST-like / CIFAR-like image
classification data: each class has a smooth random prototype image;
samples are prototype + noise (+ random shift for the CIFAR-like
difficulty bump). A CNN can learn it, accuracy ordering matches the
paper's (CIFAR-like harder), and labels are explicit so the paper's
non-iid partitions (Type 1/2/3) apply exactly.

``make_lm_data`` produces token streams from a class-conditional bigram
process so LM architectures have a learnable federated task whose
"label" histogram (bigram-bucket histogram) feeds the scheduler.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClassificationData:
    images: np.ndarray      # (N, H, W, C) float32 in [0,1]
    labels: np.ndarray      # (N,) int32
    num_classes: int

    def subset(self, idx):
        return ClassificationData(self.images[idx], self.labels[idx],
                                  self.num_classes)


def make_classification_data(kind: str, n: int, seed: int = 0,
                             num_classes: int = 10) -> ClassificationData:
    """kind: 'mnist' (28x28x1, easy) or 'cifar' (32x32x3, harder)."""
    rng = np.random.default_rng(seed)
    if kind == "mnist":
        H = W = 28
        C, noise, shift = 1, 0.30, 0
    elif kind == "cifar":
        H = W = 32
        C, noise, shift = 3, 0.55, 4
    else:
        raise ValueError(kind)

    # smooth class prototypes: low-frequency random fields
    freq = 4
    base = rng.normal(size=(num_classes, freq, freq, C))
    protos = np.zeros((num_classes, H, W, C), np.float32)
    for c in range(num_classes):
        for ch in range(C):
            up = np.kron(base[c, :, :, ch], np.ones((H // freq + 1,
                                                     W // freq + 1)))
            protos[c, :, :, ch] = up[:H, :W]
    protos = (protos - protos.min()) / (np.ptp(protos) + 1e-9)

    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    images = protos[labels].copy()
    if shift:  # random translations make the task harder (CIFAR-like)
        for i in range(n):
            sx, sy = rng.integers(-shift, shift + 1, size=2)
            images[i] = np.roll(np.roll(images[i], sx, 0), sy, 1)
    images += rng.normal(scale=noise, size=images.shape).astype(np.float32)
    images = np.clip(images, 0.0, 1.0)
    return ClassificationData(images.astype(np.float32), labels, num_classes)


@dataclasses.dataclass
class LMData:
    tokens: np.ndarray      # (N, S+1) int32; input = [:, :-1], target = [:, 1:]
    labels: np.ndarray      # (N,) int32 latent class of each sequence
    num_classes: int
    vocab_size: int


def make_lm_data(n: int, seq_len: int, vocab_size: int, seed: int = 0,
                 num_classes: int = 10) -> LMData:
    """Class-conditional deterministic-ish bigram streams.

    Each latent class c has its own random permutation pi_c; sequences
    follow t_{k+1} = pi_c(t_k) with occasional noise. The latent class is
    the scheduler's 'label'."""
    rng = np.random.default_rng(seed)
    perms = np.stack([rng.permutation(vocab_size) for _ in range(num_classes)])
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    toks = np.zeros((n, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab_size, size=n)
    noise = rng.uniform(size=(n, seq_len)) < 0.05
    for k in range(seq_len):
        nxt = perms[labels, toks[:, k]]
        rand = rng.integers(0, vocab_size, size=n)
        toks[:, k + 1] = np.where(noise[:, k], rand, nxt)
    return LMData(toks, labels, num_classes, vocab_size)


def histogram(labels: np.ndarray, num_classes: int) -> np.ndarray:
    return np.bincount(labels, minlength=num_classes).astype(np.float64)
