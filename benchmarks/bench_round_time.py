"""ISSUE-2 round data-plane study: legacy host-loop trainer vs the
device-resident chunked round driver, at the simulation scale of the
paper's Figs. 5/6 runs (30 clients / subset 8 / 24 rounds, MNIST CNN).

Three paths over the SAME schedule/PRNG stream:

- ``legacy``:        PR-1 host-loop trainer — per-round host batch
                     assembly + host→device transfer, one dispatch per
                     round, reference model lowering, two-pass
                     aggregation+cosine.
- ``device_chunk1``: device-resident gather + fused agg/quality, but
                     still one dispatch per round.
- ``device_chunkN``: the full chunked driver — ``round_chunk`` rounds
                     per ``lax.scan`` dispatch, zero per-round host
                     transfers.

Each path serves the task three times through the service lifecycle
(``lifecycle.submit`` + ``drain``): a COLD pass
(first task on a fresh trainer — includes every jit compile) and two
WARM passes (the same trainer serving further identical tasks — the
steady state a deployed provider sustains; min of the two on this
shared box). Besides end-to-end wall-clock, the trainer calls are timed
separately: the ROUND-LOOP time, which excludes the stage-2 scheduling
control plane that is identical in (and shared by) both paths — this
isolated data-plane number is the ≥5× ISSUE-2 target; total wall-clock
speedups (warm and cold) are reported alongside. Everything goes
through the harness ``report`` AND into machine-readable
``BENCH_round.json`` at the repo root (perf trajectory across PRs).

Reproduce locally:
    PYTHONPATH=src python -m benchmarks.run --only bench_round_time
Set ``REPRO_BENCH_SMOKE=1`` for the CI-sized configuration.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FLServiceProvider, TaskRequest, lifecycle
from repro.data.synthetic import make_classification_data
from repro.fl.partition import partition_labels
from repro.fl.simulation import (DeviceFLSim, FLClassificationSim, SimConfig,
                                 pool_from_partition)
from repro.models import cnn

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_round.json")


def _setup(smoke: bool):
    if smoke:
        cfg = dict(n_clients=12, rounds=6, subset_size=4, n_train=1200,
                   n_test=300, round_chunk=3,
                   sim=SimConfig(batch_size=8, local_steps=1, local_lr=0.15,
                                 eval_every=10_000, dropout_rate=0.05, seed=0))
    else:
        cfg = dict(n_clients=30, rounds=24, subset_size=8, n_train=3000,
                   n_test=800, round_chunk=8,
                   sim=SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                                 eval_every=10_000, dropout_rate=0.05, seed=0))
    full = make_classification_data(
        "mnist", cfg["n_train"] + cfg["n_test"], seed=0)
    data = full.subset(np.arange(cfg["n_train"]))
    test = full.subset(np.arange(cfg["n_train"],
                                 cfg["n_train"] + cfg["n_test"]))
    parts = partition_labels(data.labels, cfg["n_clients"], "type2", 10,
                             seed=0)
    pool = pool_from_partition(data.labels, parts, data.num_classes, seed=0)
    return cfg, data, test, parts, pool


class _TimedTrainer:
    """Wraps a trainer, accumulating time spent inside trainer calls —
    the round loop proper, without the (shared) scheduling control
    plane. Exposes ``run_rounds`` only when the inner trainer does, so
    the lifecycle treats it exactly like the inner trainer."""

    def __init__(self, inner):
        self.inner = inner
        self.seconds = 0.0
        if hasattr(inner, "run_rounds"):
            self.run_rounds = self._timed(inner.run_rounds)

    def _timed(self, fn):
        def wrapped(*args):
            t0 = time.perf_counter()
            out = fn(*args)
            self.seconds += time.perf_counter() - t0
            return out
        return wrapped

    def __call__(self, *args):
        return self._timed(self.inner)(*args)


def _run_one(path: str, cfg, data, test, parts, pool):
    """Build a fresh trainer+provider and time run_task for 'rounds'."""
    delta = 3
    chunk = {"legacy": 1, "device_chunk1": 1,
             "device_chunkN": cfg["round_chunk"]}[path]
    if path == "legacy":
        simul = FLClassificationSim(cnn.MNIST_CNN, data, parts, test,
                                    cfg["sim"])
        trainer = _TimedTrainer(simul.trainer)
    else:
        simul = DeviceFLSim(cnn.MNIST_CNN, data, parts, test, cfg["sim"],
                            pad_subset_to=cfg["subset_size"] + delta)
        trainer = _TimedTrainer(simul)
    rounds = cfg["rounds"]
    task = TaskRequest(budget=1e9, n_star=cfg["n_clients"],
                       subset_size=cfg["subset_size"], subset_delta=delta,
                       x_star=3, max_periods=10_000, scheduler="mkp",
                       seed=0, round_chunk=chunk, max_rounds=rounds)

    def serve_once():
        """One full task on a fresh provider (trainer jit caches persist
        across tasks, as they would in the deployed service)."""
        provider = FLServiceProvider(pool)
        loop0 = trainer.seconds
        t0 = time.perf_counter()
        state = lifecycle.submit(provider, task)
        state, _ = lifecycle.drain(
            provider, state, trainer,
            stop_fn=lambda m: m["round"] + 1 >= rounds)
        result = lifecycle.as_run_result(state)
        elapsed = time.perf_counter() - t0
        assert result.num_rounds == rounds, (path, result.num_rounds)
        return (elapsed, trainer.seconds - loop0,
                [r.metrics["loss"] for r in result.rounds])

    cold_s, _, losses = serve_once()    # includes every jit compile
    # steady state: best of two warm tasks (this box is shared; min is
    # the standard noise-robust wall-clock estimator)
    w1_total, w1_loop, _ = serve_once()
    w2_total, w2_loop, _ = serve_once()
    warm_s, warm_loop = min(w1_total, w2_total), min(w1_loop, w2_loop)
    return {"cold_total_s": round(cold_s, 3),
            "warm_total_s": round(warm_s, 3),
            "warm_round_loop_s": round(warm_loop, 3),
            "warm_per_round_ms": round(1e3 * warm_loop / rounds, 1),
            "first_loss": round(losses[0], 4),
            "last_loss": round(losses[-1], 4)}


def run(report):
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    cfg, data, test, parts, pool = _setup(smoke)
    record = {"smoke": smoke,
              "config": {"n_clients": cfg["n_clients"],
                         "rounds": cfg["rounds"],
                         "subset_size": cfg["subset_size"],
                         "round_chunk": cfg["round_chunk"],
                         "batch_size": cfg["sim"].batch_size,
                         "local_steps": cfg["sim"].local_steps,
                         "model": "MNIST_CNN"},
              "paths": {}}
    for path in ("legacy", "device_chunk1", "device_chunkN"):
        res = _run_one(path, cfg, data, test, parts, pool)
        record["paths"][path] = res
        report(f"{path}_cold_total_s", res["cold_total_s"],
               f"{cfg['rounds']} rounds incl. all jit compiles")
        report(f"{path}_warm_total_s", res["warm_total_s"],
               "steady-state end-to-end (later task, caches warm)")
        report(f"{path}_warm_round_loop_s", res["warm_round_loop_s"],
               "trainer time only (scheduling control plane excluded)")
        report(f"{path}_warm_per_round_ms", res["warm_per_round_ms"], "")
    legacy = record["paths"]["legacy"]
    chunked = record["paths"]["device_chunkN"]
    record["speedup_chunked_vs_legacy"] = round(
        legacy["warm_round_loop_s"] / chunked["warm_round_loop_s"], 2)
    record["speedup_chunked_vs_legacy_total"] = round(
        legacy["warm_total_s"] / chunked["warm_total_s"], 2)
    record["speedup_chunked_vs_legacy_cold"] = round(
        legacy["cold_total_s"] / chunked["cold_total_s"], 2)
    record["speedup_chunk1_vs_legacy"] = round(
        legacy["warm_round_loop_s"]
        / record["paths"]["device_chunk1"]["warm_round_loop_s"], 2)
    report("speedup_chunked_vs_legacy", record["speedup_chunked_vs_legacy"],
           "steady-state round loop; ISSUE-2 target >= 5x")
    report("speedup_chunked_vs_legacy_total",
           record["speedup_chunked_vs_legacy_total"],
           "steady-state end-to-end incl. shared scheduling")
    report("speedup_chunked_vs_legacy_cold",
           record["speedup_chunked_vs_legacy_cold"],
           "first task on a fresh trainer (compiles included)")
    # losses should tell the same training story on both planes
    drift = abs(record["paths"]["legacy"]["last_loss"]
                - record["paths"]["device_chunkN"]["last_loss"])
    report("final_loss_abs_drift", round(drift, 4),
           "legacy vs device, same seeds")
    # merge-write: other benches own sibling keys in the same artifact
    # (e.g. bench_compression's "compression" section) — preserve them
    merged = {}
    if os.path.exists(_JSON_PATH):
        try:
            with open(_JSON_PATH) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(record)
    with open(_JSON_PATH, "w") as f:
        json.dump(merged, f, indent=2)
    report("json_written", 1.0, _JSON_PATH)
