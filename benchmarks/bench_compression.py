"""ISSUE-9 compressed update-plane study: bytes on the wire vs model
quality for the federated transformer fine-tuning task.

Five codecs over the SAME schedule/PRNG stream (the control plane is
seed-identical across variants, so the runs differ only in what crosses
the wire): raw f32 (``none``), per-chunk symmetric ``int8``, magnitude
top-k at two sparsity levels (``topk:0.1``, ``topk:0.05``), and the
composed ``topk:0.05+int8``. Each serves one task through the service
lifecycle (``lifecycle.submit`` + ``drain``) on a fresh
:class:`~repro.fl.transformer_task.TransformerFLSim` (LoRA adapter
deltas on a reduced-SmolLM backbone — the payload a production
cross-device system would actually ship).

Reported per variant: wire bytes per round (from the round metrics'
``bytes`` column; the raw plane's figure is computed from the same
arrival counts), compression ratio, final next-token accuracy, final
training loss. Two assertions ride along:

- ``compression="none"`` is *bit-identical* to ``compression=None``
  (same params out, asserted here in addition to the test suite);
- the composed codec moves >= 8x fewer bytes than raw at a bounded
  accuracy cost (ACC_LOSS_BOUND absolute next-token accuracy).

Everything goes through the harness ``report`` AND merges into
machine-readable ``BENCH_round.json`` under the ``"compression"`` key
(sibling sections — bench_round_time's perf trajectory — are
preserved).

Reproduce locally:
    PYTHONPATH=src python -m benchmarks.run --only bench_compression
Set ``REPRO_BENCH_SMOKE=1`` for the CI-sized configuration.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core import FLServiceProvider, TaskRequest, lifecycle
from repro.fl.compression import CompressionSpec, bytes_per_client
from repro.fl.transformer_task import make_transformer_fl

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_round.json")

VARIANTS = ("none", "int8", "topk:0.1", "topk:0.05", "topk:0.05+int8")
BYTES_TARGET = 8.0       # composed codec: >= 8x fewer bytes than raw
ACC_LOSS_BOUND = 0.05    # max absolute next-token accuracy loss vs raw
# (aggressive sparsification without error feedback costs accuracy —
# the measured deltas are -0.021 smoke / -0.039 full, deterministic at
# seed 0; int8 alone is accuracy-neutral, see BENCH_round.json)


def _config(smoke: bool) -> dict:
    if smoke:
        return dict(n_clients=10, n_train=100, n_test=30, seq_len=8,
                    rounds=6, subset_size=4, round_chunk=3)
    return dict(n_clients=20, n_train=240, n_test=60, seq_len=16,
                rounds=40, subset_size=6, round_chunk=10)


def _serve(cfg: dict, compression: str | None) -> dict:
    b = make_transformer_fl(n_clients=cfg["n_clients"],
                            n_train=cfg["n_train"], n_test=cfg["n_test"],
                            seq_len=cfg["seq_len"], seed=0,
                            compression=compression)
    provider = FLServiceProvider(b["pool"])
    task = TaskRequest(budget=1e9, n_star=cfg["n_clients"],
                       subset_size=cfg["subset_size"], subset_delta=2,
                       x_star=4, max_periods=10_000, seed=0,
                       round_chunk=cfg["round_chunk"],
                       max_rounds=cfg["rounds"], compression=compression)
    state = lifecycle.submit(provider, task)
    state, events = lifecycle.drain(provider, state, b["trainer"])
    assert len(events) == cfg["rounds"], (compression, len(events))
    hist = b["trainer"].history
    # arrivals per round back out of the bytes column (or the subset
    # sizes for the raw plane, which reports none)
    arrived = [len(e.subset) for e in events]
    return {"trainer": b["trainer"], "history": hist, "arrived": arrived,
            "losses": [h["loss"] for h in hist],
            "bytes_rounds": [h.get("bytes") for h in hist],
            "accuracy": b["trainer"].evaluate(),
            "flat_p": sum(int(np.prod(np.shape(x))) for x in
                          jax.tree_util.tree_leaves(b["trainer"].params))}


def run(report):
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    cfg = _config(smoke)

    # bit-identity gate: the "none" codec string must not perturb the
    # trace of the default (compression=None) plane
    base = _serve(cfg, None)
    named = _serve(cfg, "none")
    for a, b in zip(jax.tree_util.tree_leaves(base["trainer"].params),
                    jax.tree_util.tree_leaves(named["trainer"].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    report("none_bit_identical", 1.0,
           'compression="none" == compression=None, exact params')

    p = base["flat_p"]
    raw_per_client = bytes_per_client(CompressionSpec.parse(None), p)
    record = {"smoke": smoke,
              "config": {**{k: v for k, v in cfg.items()},
                         "flat_update_size": p,
                         "model": "reduced_smollm_lora"},
              "acc_loss_bound": ACC_LOSS_BOUND,
              "variants": {}}

    for name in VARIANTS:
        res = named if name == "none" else _serve(cfg, name)
        spec = CompressionSpec.parse(name)
        per_client = bytes_per_client(spec, p)
        # raw plane reports no bytes column; compute from arrivals
        if spec.active:
            per_round = float(np.mean([x for x in res["bytes_rounds"]
                                       if x is not None]))
        else:
            per_round = float(np.mean(res["arrived"])) * per_client
        ratio = raw_per_client / per_client
        record["variants"][name] = {
            "bytes_per_client": per_client,
            "bytes_per_round": round(per_round, 1),
            "compression_ratio": round(ratio, 2),
            "final_accuracy": round(float(res["accuracy"]), 4),
            "final_loss": round(float(res["losses"][-1]), 4),
        }
        report(f"{name}_bytes_per_round", round(per_round, 1),
               f"{ratio:.1f}x vs raw f32")
        report(f"{name}_final_accuracy",
               round(float(res["accuracy"]), 4),
               f"final loss {res['losses'][-1]:.3f}")

    raw = record["variants"]["none"]
    composed = record["variants"]["topk:0.05+int8"]
    record["composed_bytes_reduction"] = round(
        raw["bytes_per_round"] / composed["bytes_per_round"], 2)
    record["composed_accuracy_delta"] = round(
        composed["final_accuracy"] - raw["final_accuracy"], 4)
    assert record["composed_bytes_reduction"] >= BYTES_TARGET, record
    assert composed["final_accuracy"] >= \
        raw["final_accuracy"] - ACC_LOSS_BOUND, record
    report("composed_bytes_reduction", record["composed_bytes_reduction"],
           f"topk:0.05+int8 vs raw; target >= {BYTES_TARGET:g}x")
    report("composed_accuracy_delta", record["composed_accuracy_delta"],
           f"bounded at -{ACC_LOSS_BOUND}")

    # merge-write: bench_round_time owns the sibling perf keys in the
    # same artifact — only the "compression" section is ours
    merged = {}
    if os.path.exists(_JSON_PATH):
        try:
            with open(_JSON_PATH) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["compression"] = record
    with open(_JSON_PATH, "w") as f:
        json.dump(merged, f, indent=2)
    report("json_written", 1.0, _JSON_PATH)
