"""Figs. 5/6 reproduction (reduced scale): federated CNN learning curves
under the three non-iid types, our MKP scheduling vs random selection.

The paper's qualitative claims validated here:
  (i) scheduling >= random in final accuracy for every non-iid type;
  (ii) the gain GROWS with non-iid severity (type1 > type2 > type3).
Full-size curves (100 clients, 200-400 rounds) run via
examples/train_noniid.py; the benchmark uses a budgeted configuration.
"""
from __future__ import annotations

import numpy as np

from repro.fl import run_fl_experiment
from repro.fl.simulation import SimConfig

ROUNDS = 24
CLIENTS = 30


def run(report):
    gains = {}
    for kind in ("type1", "type2", "type3"):
        accs = {}
        for sched in ("mkp", "random"):
            out = run_fl_experiment(
                "mnist", kind, n_clients=CLIENTS, rounds=ROUNDS,
                scheduler=sched, n_train=3000, n_test=800, subset_size=8,
                sim=SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                              eval_every=ROUNDS, dropout_rate=0.05, seed=0))
            accs[sched] = out["final_accuracy"]
            report(f"mnist_{kind}_{sched}_final_acc", accs[sched],
                   f"{ROUNDS} rounds, {CLIENTS} clients")
        gains[kind] = accs["mkp"] - accs["random"]
        report(f"mnist_{kind}_sched_gain", gains[kind],
               "paper: positive, larger for more non-iid")
    report("gain_monotone_in_noniid",
           float(gains["type1"] >= gains["type3"] - 0.02),
           f"type1={gains['type1']:.3f} type3={gains['type3']:.3f}")
