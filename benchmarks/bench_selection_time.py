"""Experiment 2 (paper Fig. 3): computation time of DP / greedy / random
vs number of candidate clients (budget proportional to n, as in the
paper) — plus the array-native scaling study this repo adds on top:

- legacy Python-loop greedy vs the vectorized ``engine.greedy_knapsack``
  at n ∈ {1k, 10k, 100k};
- the full Stage-1 pipeline (threshold filter + scoring + knapsack) on
  ``list[ClientProfile]`` vs ``ClientPoolState``;
- a multi-task batch-selection benchmark: T concurrent TaskRequests
  served sequentially (legacy) vs one jit+vmap sweep
  (``engine.greedy_knapsack_batch``).

Results are printed through the harness ``report`` callback AND written
to ``BENCH_selection.json`` at the repo root so the perf trajectory is
machine-readable across PRs.

ISSUE-6 adds the fleet-scale study ("fleet" key): n ∈ {1M, 10M} pools
built by chunked synthetic generation, the hierarchical device-mirror
pipeline (``core.device_pool`` + ``engine.hierarchical_greedy_knapsack``)
vs the flat host pipeline at a production-selective budget, plus a
churn-absorption benchmark (dirty-region sync events/s vs a full
restage).

Set ``REPRO_BENCH_SMOKE=1`` to cap the study at n=10k / 1 rep (CI);
smoke mode replaces the fleet sizes with one reduced-n (50k)
hierarchical parity row.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (linear_cost, overall_score, select_dp, select_greedy,
                        select_greedy_legacy, select_random,
                        select_initial_pool, threshold_filter)
from repro.core import device_pool, engine
from repro.core.criteria import (CRITERIA, NUM_CRITERIA, data_dist_score,
                                 random_histograms)
from repro.core.pool import ClientPoolState

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_selection.json")


def _time(fn, reps=5):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def _random_pool_chunked(n: int, n_classes: int, rng: np.random.Generator,
                         chunk: int = 1_000_000) -> ClientPoolState:
    """Fleet-size synthetic pool built ``chunk`` rows at a time:
    peak temporary memory stays O(chunk), not O(n) — the 10M pool never
    materializes a second copy of its (n, 11) score block. Data-size
    scores normalize by the distribution's max (``n_classes * 199``)
    instead of the observed pool max, so chunks are independent."""
    scores = np.empty((n, NUM_CRITERIA), dtype=np.float64)
    hists = np.empty((n, n_classes), dtype=np.float64)
    costs = np.empty(n, dtype=np.float64)
    i_size = CRITERIA.index("data_size")
    i_dist = CRITERIA.index("data_dist")
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        s = rng.uniform(0.0, 1.0, size=(hi - lo, NUM_CRITERIA))
        h = random_histograms(hi - lo, n_classes, rng)
        s[:, i_size] = h.sum(axis=1) / float(n_classes * 199)
        s[:, i_dist] = data_dist_score(h)
        scores[lo:hi] = s
        hists[lo:hi] = h
        costs[lo:hi] = linear_cost(overall_score(s), 2.0, 5.0, integer=True)
    return ClientPoolState(np.arange(n, dtype=np.int64), scores, hists, costs)


def _fleet_study(report, record, smoke: bool):
    """The ISSUE-6 million-client rows: hierarchical vs flat pipeline at
    a selective budget, plus churn absorption (sync vs restage)."""
    thresholds = np.full(9, 0.05)
    if smoke:
        sizes, events, reps = (50_000,), 500, 1
        shard_cap = 16_384                 # reduced n, still multi-shard
    else:
        sizes, events, reps = (1_000_000, 10_000_000), 5_000, 2
        shard_cap = device_pool.DEFAULT_SHARD_CAP
    record["fleet"] = []
    for n in sizes:
        rng = np.random.default_rng(n)
        pool = _random_pool_chunked(n, 10, rng)
        # production-selective regime: pick ~0.5% of the fleet
        B = round(0.005 * float(pool.costs.sum()), 1)

        t0 = time.perf_counter()
        mirror = pool.device_mirror(shard_cap=shard_cap)
        t_stage = (time.perf_counter() - t0) * 1e6
        stats: dict = {}
        rows, ts, tc, _ = engine.hierarchical_greedy_knapsack(
            pool, B, thresholds, mirror=mirror, stats=stats)  # warmup/jit
        t_hier = _time(lambda: engine.hierarchical_greedy_knapsack(
            pool, B, thresholds, mirror=mirror), reps=reps)
        frows, _, _, _ = engine._flat_pool_greedy(pool, B, thresholds)
        parity = bool(np.array_equal(rows, frows))
        t_flat = _time(lambda: engine._flat_pool_greedy(
            pool, B, thresholds), reps=1)

        # churn absorption: deregister + join waves (`events` dirty rows
        # per wave); the first wave warms the bucketed scatter compile
        # (steady-state production absorbs churn every sweep), the
        # second is timed
        def churn_wave(seed):
            step = max(1, n // (events // 2))
            alive = pool.client_ids[pool.registered]
            pool.deregister(alive[::step][: events // 2])
            k = events - min(events // 2, alive[::step].size)
            r2 = np.random.default_rng(seed)
            base = int(pool.client_ids.max()) + 1
            pool.register_arrays(np.arange(base, base + k),
                                 r2.random((k, NUM_CRITERIA)),
                                 random_histograms(k, 10, r2),
                                 r2.uniform(1.0, 5.0, k))

        churn_wave(n + 1)
        pool.device_mirror(shard_cap=shard_cap)       # warm the scatter
        churn_wave(n + 2)
        t0 = time.perf_counter()
        pool.device_mirror(shard_cap=shard_cap)       # incremental sync
        t_sync = (time.perf_counter() - t0) * 1e6
        t_restage = _time(lambda: device_pool.DevicePoolState.from_host(
            pool, shard_cap=shard_cap), reps=1)
        t_post = _time(lambda: engine.hierarchical_greedy_knapsack(
            pool, B, thresholds, mirror=mirror), reps=reps)

        row = {"n": n, "shard_cap": shard_cap, "shards": mirror.num_shards,
               "budget": B, "picks": int(rows.size), "parity": parity,
               "frontier": stats["frontier"],
               "escalations": stats["escalations"],
               "candidates": stats["candidates"],
               "mirror_stage_us": t_stage,
               "pipeline_hier_us": t_hier, "pipeline_flat_us": t_flat,
               "hier_speedup": t_flat / max(t_hier, 1e-9),
               "churn": {"events": int(events),
                         "sync_us": t_sync,
                         "events_per_s": events / max(t_sync * 1e-6, 1e-9),
                         "restage_us": t_restage,
                         "absorb_speedup": t_restage / max(t_sync, 1e-9),
                         "post_churn_select_us": t_post}}
        record["fleet"].append(row)
        tag = f"n{n//1000}k" if n < 10**6 else f"n{n//10**6}M"
        report(f"fleet_pipeline_hier_us_{tag}", t_hier,
               f"2-level frontier F={stats['frontier']}")
        report(f"fleet_pipeline_flat_us_{tag}", t_flat, "host argsort")
        report(f"fleet_hier_speedup_{tag}", round(row["hier_speedup"], 2),
               "x")
        report(f"fleet_parity_{tag}", int(parity), "hier == flat rows")
        report(f"fleet_churn_events_per_s_{tag}",
               round(row["churn"]["events_per_s"]),
               f"{events} events, dirty-region sync")
        report(f"fleet_churn_absorb_speedup_{tag}",
               round(row["churn"]["absorb_speedup"], 2), "vs full restage")
        del pool, mirror


def _legacy_pipeline(profiles, thresholds, budget):
    """The pre-refactor Stage-1: per-profile filter loop, per-profile
    score extraction, Python-loop greedy."""
    filtered = threshold_filter(profiles, thresholds)
    scores = np.array([p.score for p in filtered])
    costs = np.array([p.cost for p in filtered])
    ids = [p.client_id for p in filtered]
    return select_greedy_legacy(scores, costs, budget, ids)


def run(report):
    rng = np.random.default_rng(0)
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    record: dict = {"smoke": smoke, "scaling": [], "batch": {}}

    # -- paper Fig. 3: small-n DP / greedy / random -------------------------
    for n in (50, 100, 200, 400, 800):
        scores = overall_score(rng.uniform(0, 1, (n, 11)))
        costs = linear_cost(scores, 2, 5, integer=True)
        B = 10.0 * n                      # proportional budget (paper)
        t_dp = _time(lambda: select_dp(scores, costs, B), reps=3)
        t_gr = _time(lambda: select_greedy(scores, costs, B))
        t_rnd = _time(lambda: select_random(scores, costs, B, rng))
        report(f"time_us_dp_n{n}", t_dp, "O(nB)")
        report(f"time_us_greedy_n{n}", t_gr, "O(n log n) vectorized")
        report(f"time_us_random_n{n}", t_rnd, "O(n)")

    # -- legacy vs vectorized at scale --------------------------------------
    sizes = (1_000, 10_000) if smoke else (1_000, 10_000, 100_000)
    reps = 1 if smoke else 3
    thresholds = np.full(9, 0.05)
    for n in sizes:
        pool = ClientPoolState.random(n, 10, rng)
        profiles = pool.to_profiles()
        B = 10.0 * n
        scores, costs = pool.overall, pool.costs

        t_leg = _time(lambda: select_greedy_legacy(scores, costs, B),
                      reps=reps)
        t_vec = _time(lambda: select_greedy(scores, costs, B), reps=reps)
        # full Stage-1: dataclass path vs array-native path (steady state:
        # the pool's cached overall scores model the deployed registry)
        t_pipe_leg = _time(lambda: _legacy_pipeline(profiles, thresholds, B),
                           reps=reps)
        t_pipe_vec = _time(lambda: select_initial_pool(
            pool, budget=B, thresholds=thresholds), reps=reps)

        row = {"n": n,
               "greedy_legacy_us": t_leg, "greedy_vec_us": t_vec,
               "greedy_speedup": t_leg / max(t_vec, 1e-9),
               "pipeline_legacy_us": t_pipe_leg,
               "pipeline_vec_us": t_pipe_vec,
               "pipeline_speedup": t_pipe_leg / max(t_pipe_vec, 1e-9)}
        record["scaling"].append(row)
        report(f"greedy_us_legacy_n{n}", t_leg, "python loop")
        report(f"greedy_us_vec_n{n}", t_vec, "argsort+cumsum")
        report(f"greedy_speedup_n{n}", round(row["greedy_speedup"], 2), "x")
        report(f"pipeline_us_legacy_n{n}", t_pipe_leg, "profile loops")
        report(f"pipeline_us_vec_n{n}", t_pipe_vec, "ClientPoolState")
        report(f"pipeline_speedup_n{n}", round(row["pipeline_speedup"], 2),
               "x")

    # -- multi-task batch selection (multi-tenant serving) -------------------
    n = 10_000 if smoke else 100_000
    T = 8
    pool = ClientPoolState.random(n, 10, rng)
    scores, costs = pool.overall, pool.costs
    budgets = np.linspace(2.0 * n, 12.0 * n, T)

    def seq_legacy():
        return [select_greedy_legacy(scores, costs, b) for b in budgets]

    def batched():
        return engine.greedy_knapsack_batch(scores, costs, budgets)

    batched()                                     # jit warmup (compile once)
    t_seq = _time(seq_legacy, reps=reps)
    t_batch = _time(batched, reps=reps)
    record["batch"] = {"n": n, "tasks": T,
                       "sequential_legacy_us": t_seq,
                       "batched_us": t_batch,
                       "speedup": t_seq / max(t_batch, 1e-9)}
    report(f"batch{T}_us_sequential_n{n}", t_seq, "legacy loop per task")
    report(f"batch{T}_us_batched_n{n}", t_batch,
           "shared-order batch (jit+vmap on TPU)")
    report(f"batch{T}_speedup_n{n}",
           round(record["batch"]["speedup"], 2), "x")

    # -- fleet-scale hierarchical selection + churn absorption ---------------
    _fleet_study(report, record, smoke)

    # merge-write: BENCH_selection.json is shared with the policy
    # study (bench_policies.py owns the "policies" key)
    data = {}
    if os.path.exists(_JSON_PATH):
        try:
            with open(_JSON_PATH) as f:
                data = json.load(f)
        except json.JSONDecodeError:
            data = {}
    data.update(record)
    with open(_JSON_PATH, "w") as f:
        json.dump(data, f, indent=1)
    report("json_written", 1, os.path.abspath(_JSON_PATH))


if __name__ == "__main__":
    run(lambda k, v, note="": print(f"{k},{v},{note}"))
