"""Experiment 2 (paper Fig. 3): computation time of DP / greedy / random
vs number of candidate clients (budget proportional to n, as in the
paper) — plus the array-native scaling study this repo adds on top:

- legacy Python-loop greedy vs the vectorized ``engine.greedy_knapsack``
  at n ∈ {1k, 10k, 100k};
- the full Stage-1 pipeline (threshold filter + scoring + knapsack) on
  ``list[ClientProfile]`` vs ``ClientPoolState``;
- a multi-task batch-selection benchmark: T concurrent TaskRequests
  served sequentially (legacy) vs one jit+vmap sweep
  (``engine.greedy_knapsack_batch``).

Results are printed through the harness ``report`` callback AND written
to ``BENCH_selection.json`` at the repo root so the perf trajectory is
machine-readable across PRs.

Set ``REPRO_BENCH_SMOKE=1`` to cap the study at n=10k / 1 rep (CI).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (linear_cost, overall_score, select_dp, select_greedy,
                        select_greedy_legacy, select_random,
                        select_initial_pool, threshold_filter)
from repro.core import engine
from repro.core.pool import ClientPoolState

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_selection.json")


def _time(fn, reps=5):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def _legacy_pipeline(profiles, thresholds, budget):
    """The pre-refactor Stage-1: per-profile filter loop, per-profile
    score extraction, Python-loop greedy."""
    filtered = threshold_filter(profiles, thresholds)
    scores = np.array([p.score for p in filtered])
    costs = np.array([p.cost for p in filtered])
    ids = [p.client_id for p in filtered]
    return select_greedy_legacy(scores, costs, budget, ids)


def run(report):
    rng = np.random.default_rng(0)
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    record: dict = {"smoke": smoke, "scaling": [], "batch": {}}

    # -- paper Fig. 3: small-n DP / greedy / random -------------------------
    for n in (50, 100, 200, 400, 800):
        scores = overall_score(rng.uniform(0, 1, (n, 11)))
        costs = linear_cost(scores, 2, 5, integer=True)
        B = 10.0 * n                      # proportional budget (paper)
        t_dp = _time(lambda: select_dp(scores, costs, B), reps=3)
        t_gr = _time(lambda: select_greedy(scores, costs, B))
        t_rnd = _time(lambda: select_random(scores, costs, B, rng))
        report(f"time_us_dp_n{n}", t_dp, "O(nB)")
        report(f"time_us_greedy_n{n}", t_gr, "O(n log n) vectorized")
        report(f"time_us_random_n{n}", t_rnd, "O(n)")

    # -- legacy vs vectorized at scale --------------------------------------
    sizes = (1_000, 10_000) if smoke else (1_000, 10_000, 100_000)
    reps = 1 if smoke else 3
    thresholds = np.full(9, 0.05)
    for n in sizes:
        pool = ClientPoolState.random(n, 10, rng)
        profiles = pool.to_profiles()
        B = 10.0 * n
        scores, costs = pool.overall, pool.costs

        t_leg = _time(lambda: select_greedy_legacy(scores, costs, B),
                      reps=reps)
        t_vec = _time(lambda: select_greedy(scores, costs, B), reps=reps)
        # full Stage-1: dataclass path vs array-native path (steady state:
        # the pool's cached overall scores model the deployed registry)
        t_pipe_leg = _time(lambda: _legacy_pipeline(profiles, thresholds, B),
                           reps=reps)
        t_pipe_vec = _time(lambda: select_initial_pool(
            pool, budget=B, thresholds=thresholds), reps=reps)

        row = {"n": n,
               "greedy_legacy_us": t_leg, "greedy_vec_us": t_vec,
               "greedy_speedup": t_leg / max(t_vec, 1e-9),
               "pipeline_legacy_us": t_pipe_leg,
               "pipeline_vec_us": t_pipe_vec,
               "pipeline_speedup": t_pipe_leg / max(t_pipe_vec, 1e-9)}
        record["scaling"].append(row)
        report(f"greedy_us_legacy_n{n}", t_leg, "python loop")
        report(f"greedy_us_vec_n{n}", t_vec, "argsort+cumsum")
        report(f"greedy_speedup_n{n}", round(row["greedy_speedup"], 2), "x")
        report(f"pipeline_us_legacy_n{n}", t_pipe_leg, "profile loops")
        report(f"pipeline_us_vec_n{n}", t_pipe_vec, "ClientPoolState")
        report(f"pipeline_speedup_n{n}", round(row["pipeline_speedup"], 2),
               "x")

    # -- multi-task batch selection (multi-tenant serving) -------------------
    n = 10_000 if smoke else 100_000
    T = 8
    pool = ClientPoolState.random(n, 10, rng)
    scores, costs = pool.overall, pool.costs
    budgets = np.linspace(2.0 * n, 12.0 * n, T)

    def seq_legacy():
        return [select_greedy_legacy(scores, costs, b) for b in budgets]

    def batched():
        return engine.greedy_knapsack_batch(scores, costs, budgets)

    batched()                                     # jit warmup (compile once)
    t_seq = _time(seq_legacy, reps=reps)
    t_batch = _time(batched, reps=reps)
    record["batch"] = {"n": n, "tasks": T,
                       "sequential_legacy_us": t_seq,
                       "batched_us": t_batch,
                       "speedup": t_seq / max(t_batch, 1e-9)}
    report(f"batch{T}_us_sequential_n{n}", t_seq, "legacy loop per task")
    report(f"batch{T}_us_batched_n{n}", t_batch,
           "shared-order batch (jit+vmap on TPU)")
    report(f"batch{T}_speedup_n{n}",
           round(record["batch"]["speedup"], 2), "x")

    # merge-write: BENCH_selection.json is shared with the policy
    # study (bench_policies.py owns the "policies" key)
    data = {}
    if os.path.exists(_JSON_PATH):
        try:
            with open(_JSON_PATH) as f:
                data = json.load(f)
        except json.JSONDecodeError:
            data = {}
    data.update(record)
    with open(_JSON_PATH, "w") as f:
        json.dump(data, f, indent=1)
    report("json_written", 1, os.path.abspath(_JSON_PATH))


if __name__ == "__main__":
    run(lambda k, v, note="": print(f"{k},{v},{note}"))
