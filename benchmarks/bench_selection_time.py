"""Experiment 2 (paper Fig. 3): computation time of DP / greedy / random
vs number of candidate clients (budget proportional to n, as in the
paper)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (linear_cost, overall_score, select_dp, select_greedy,
                        select_random)


def _time(fn, reps=5):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6   # us


def run(report):
    rng = np.random.default_rng(0)
    for n in (50, 100, 200, 400, 800):
        scores = overall_score(rng.uniform(0, 1, (n, 11)))
        costs = linear_cost(scores, 2, 5, integer=True)
        B = 10.0 * n                      # proportional budget (paper)
        t_dp = _time(lambda: select_dp(scores, costs, B), reps=3)
        t_gr = _time(lambda: select_greedy(scores, costs, B))
        t_rnd = _time(lambda: select_random(scores, costs, B, rng))
        report(f"time_us_dp_n{n}", t_dp, "O(nB)")
        report(f"time_us_greedy_n{n}", t_gr, "O(n log n)")
        report(f"time_us_random_n{n}", t_rnd, "O(n)")
