"""Roofline benchmark: reads the dry-run artifacts (artifacts/dryrun/)
and reports the three roofline terms + bottleneck per (arch × shape).
Run ``python -m repro.launch.dryrun --all [--unroll]`` first; this bench
prefers unrolled artifacts (cost fidelity) and falls back to scan ones.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_artifacts():
    recs = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            continue
        key = (r["arch"], r["shape"])
        unrolled = r["mesh"].endswith("-unrolled")
        multi = r["mesh"].startswith("2x")
        if multi:
            continue   # roofline table is single-pod
        # prefer unrolled artifacts for cost fidelity
        if key not in recs or unrolled:
            recs[key] = r
    return recs


def run(report):
    recs = load_artifacts()
    if not recs:
        report("roofline_artifacts_found", 0.0,
               "run `python -m repro.launch.dryrun --all --unroll` first")
        return
    for (arch, shape), r in sorted(recs.items()):
        t = r["roofline"]
        dom = {"compute": t["compute_s"], "memory": t["memory_s"],
               "collective": t["collective_s"]}
        report(f"{arch}.{shape}.bottleneck_s", max(dom.values()),
               f"{t['bottleneck']} "
               f"(c={t['compute_s']:.2e} m={t['memory_s']:.2e} "
               f"n={t['collective_s']:.2e}) useful={t['useful_ratio']:.2f} "
               f"[{r['mesh']}]")
    report("roofline_artifacts_found", float(len(recs)), "single-pod pairs")
