"""Benchmark harness: one module per paper table/figure (+ roofline).

``python -m benchmarks.run [--only NAME]`` prints ``name,value,note`` CSV.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

BENCHES = [
    "bench_selection",        # Tables II/III
    "bench_selection_time",   # Fig. 3
    "bench_policies",         # ISSUE-5 pluggable-policy comparison
    "bench_subsets",          # Fig. 4 + fairness §VII
    "bench_training",         # Figs. 5/6 (reduced)
    "bench_round_time",       # ISSUE-2 device-resident round data plane
    "bench_service_multitask",  # ISSUE-3 multi-tenant service lifecycle
    "bench_faults",           # ISSUE-7 fault injection + mitigation
    "bench_workload",         # ISSUE-8 online workload harness (SLA)
    "bench_compression",      # ISSUE-9 compressed update plane (bytes/acc)
    "bench_placement",        # ISSUE-10 multi-device tenant placement
    "bench_roofline",         # §Roofline (from dry-run artifacts)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (default: all)")
    ap.add_argument("--skip", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    if args.skip:
        names = [n for n in names if n not in set(args.skip.split(","))]

    print("name,value,note")
    failures = 0
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()

        def report(metric, value, note=""):
            print(f"{name}.{metric},{value},{note}", flush=True)

        try:
            mod.run(report)
            report("elapsed_s", round(time.time() - t0, 2))
        except Exception as e:  # keep the harness going
            failures += 1
            report("ERROR", 0.0, f"{type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
