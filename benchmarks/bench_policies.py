"""ISSUE-5 policy-comparison study: the pluggable selection/scheduling
registry A/B'd across non-iid partitions.

Five policy bundles run the *same* federated MNIST-like task (type2
non-iid partition, binding budget ≈ 45% of the pool's total cost) end
to end through the lifecycle, differing only in their
``TaskRequest.selection_policy`` / ``scheduling_policy``:

- ``paper``      — paper_greedy + iid_subsets (the paper's scheme, the
                   registry defaults);
- ``dp``         — exact-knapsack selection + iid_subsets;
- ``score_prop`` — score-proportional sampling + iid_subsets;
- ``random``     — uniform selection + random partition (the paper's
                   baseline pair);
- ``fair_ema``   — paper_greedy + the participation-EMA-penalized
                   scheduler (Shi et al. spirit).

Per bundle we record final test **accuracy**, the **Jain fairness
index** over realized per-client participation counts (all executed
rounds), stage-1 **selection latency** (µs, median), pool size/cost and
executed rounds — written into ``BENCH_selection.json`` under the
``"policies"`` key (merged; the stage-1 scaling study owns the other
keys).

Since ISSUE-8 the study also tracks the accuracy-vs-fairness frontier
across **partition kinds** (the PR 5 follow-up): the paper / random /
fair_ema bundles additionally run on the paper's **type1** (single
dominant class per client) and **type3** (two-class mixtures)
partitions, recorded under ``"policies"."partitions"`` alongside the
type2 ``"bundles"`` rows.

Since ISSUE-10 the study also records an **accuracy-vs-bytes
compression frontier** (``"policies"."compression_frontier"``): the
paper bundle re-run through the device data plane under the ISSUE-9
update codecs ``{none, int8, topk:0.1, topk:0.05+int8}``, with mean
wire bytes per round (from the round metrics' ``bytes`` column; the
raw plane's figure is ``param_count x 4 x mean arrivals``) against
final accuracy — the service-side counterpart of the transformer study
in ``benchmarks/bench_compression.py``.

Set ``REPRO_BENCH_SMOKE=1`` for the CI configuration: tiny data/rounds,
but still **all** bundles (every registered policy must at least run).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FLServiceProvider, TaskRequest, jain_index
from repro.core import policy as P
from repro.fl.simulation import SimConfig, pool_from_partition, \
    run_fl_experiment
from repro.data.synthetic import make_classification_data
from repro.fl.partition import partition_labels

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_selection.json")

BUNDLES = {
    "paper": ("paper_greedy", "iid_subsets"),
    "dp": ("dp", "iid_subsets"),
    "score_prop": ("score_prop", "iid_subsets"),
    "random": ("random", "random_partition"),
    "fair_ema": ("paper_greedy", "fair_ema"),
}


def _merge_json(path: str, key: str, value) -> None:
    """Update one top-level key of the shared record in place (the
    selection-time study owns the others). A corrupt/truncated file
    (e.g. an interrupted earlier run) is discarded, matching the
    sibling bench's recovery behaviour."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except json.JSONDecodeError:
            data = {}
    data[key] = value
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def _select_latency_us(pool, task, reps=5) -> float:
    policy = P.resolve_selection_policy(task)
    ts = []
    for r in range(reps):
        rng = np.random.default_rng(task.seed)
        t0 = time.perf_counter()
        policy.select(pool, task, rng)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


# the reduced bundle set for the cross-partition frontier rows (the
# interesting corners: the paper's scheme, the random baseline pair,
# and the fairness-first scheduler)
_PARTITION_KINDS = ("type1", "type3")
_PARTITION_BUNDLES = ("paper", "random", "fair_ema")


def _study(noniid, bundle_names, smoke, seed, report, prefix=""):
    """Run one partition kind's bundle A/B; returns (rows, budget)."""
    n_clients = 20 if smoke else 30
    rounds = 3 if smoke else 16
    n_train = 600 if smoke else 2400
    n_test = 200 if smoke else 600
    subset_size, subset_delta = 6, 3
    sim = SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                    eval_every=rounds, dropout_rate=0.05, seed=seed)

    # the shared pool the bundles compete on (same draws as inside
    # run_fl_experiment: same data/partition seed)
    full = make_classification_data("mnist", n_train + n_test, seed=seed)
    data = full.subset(np.arange(n_train))
    parts = partition_labels(data.labels, n_clients, noniid,
                             data.num_classes, seed=seed)
    pool = pool_from_partition(data.labels, parts, data.num_classes,
                               seed=seed)
    budget = float(np.round(0.45 * pool.costs.sum()))

    rows = {}
    for bundle in bundle_names:
        sel, sch = BUNDLES[bundle]
        out = run_fl_experiment(
            "mnist", noniid, n_clients=n_clients, rounds=rounds,
            n_train=n_train, n_test=n_test, subset_size=subset_size,
            subset_delta=subset_delta, sim=sim, seed=seed, budget=budget,
            n_star=1, selection_policy=sel, scheduling_policy=sch)
        svc = out["service"]
        counts: dict[int, int] = {}
        for r in svc.rounds:
            for c in r.subset:
                counts[c] = counts.get(c, 0) + 1
        jain = jain_index(np.array(sorted(counts.values()), dtype=np.float64))
        task = TaskRequest(budget=budget, n_star=1, seed=seed,
                           selection_policy=sel, scheduling_policy=sch)
        lat_us = _select_latency_us(pool, task)
        rows[bundle] = {
            "selection_policy": sel, "scheduling_policy": sch,
            "accuracy": float(out["final_accuracy"]),
            "jain_fairness": float(jain),
            "selection_latency_us": lat_us,
            "pool_size": len(svc.pool.selected),
            "pool_cost": float(svc.pool.total_cost),
            "rounds": svc.num_rounds,
        }
        report(f"{prefix}{bundle}_accuracy",
               round(rows[bundle]["accuracy"], 4), f"{sel}+{sch}")
        report(f"{prefix}{bundle}_jain", round(jain, 4),
               "participation fairness over executed rounds")
        if not prefix:
            report(f"{bundle}_select_us", round(lat_us, 1),
                   "stage-1 latency")
            report(f"{bundle}_pool", len(svc.pool.selected),
                   f"cost {svc.pool.total_cost:.0f}/{budget:.0f}")

    # every bundle must have actually trained: jain_index returns 1.0
    # on empty counts, so guard on rounds, not Jain
    assert all(r["rounds"] > 0 and r["pool_size"] > 0
               for r in rows.values())
    return rows, budget


# the ISSUE-9 codecs spanning the bytes/accuracy frontier corners: raw,
# quantize-only, sparsify-only, composed
_FRONTIER_VARIANTS = ("none", "int8", "topk:0.1", "topk:0.05+int8")


def _compression_frontier(smoke, seed, report):
    """Accuracy-vs-bytes rows: the paper bundle through the device data
    plane under each update codec. Dropout is off so every variant's
    arrival count equals its subset size and the raw plane's bytes are
    exact, not estimated."""
    import jax
    from repro.fl.compression import CompressionSpec, bytes_per_client
    from repro.models import cnn
    n_clients = 20 if smoke else 30
    rounds = 3 if smoke else 16
    n_train = 600 if smoke else 2400
    n_test = 200 if smoke else 600
    sim = SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                    eval_every=rounds, dropout_rate=0.0, seed=seed)
    params = cnn.init_params(cnn.MNIST_CNN, jax.random.PRNGKey(0))
    p = sum(int(np.prod(np.shape(x)))
            for x in jax.tree_util.tree_leaves(params))
    raw_per_client = bytes_per_client(CompressionSpec.parse(None), p)

    rows = {}
    for name in _FRONTIER_VARIANTS:
        out = run_fl_experiment(
            "mnist", "type2", n_clients=n_clients, rounds=rounds,
            n_train=n_train, n_test=n_test, subset_size=6, subset_delta=3,
            sim=sim, seed=seed, data_plane="device", round_chunk=4,
            compression=name)
        spec = CompressionSpec.parse(name)
        hist_bytes = [h.get("bytes") for h in out["history"]]
        if spec.active:
            per_round = float(np.mean([b for b in hist_bytes
                                       if b is not None]))
        else:
            arrived = float(np.mean([len(r.subset)
                                     for r in out["service"].rounds]))
            per_round = arrived * raw_per_client
        per_client = bytes_per_client(spec, p)
        rows[name] = {
            "bytes_per_client": per_client,
            "bytes_per_round": round(per_round, 1),
            "compression_ratio": round(raw_per_client / per_client, 2),
            "accuracy": round(float(out["final_accuracy"]), 4),
            "rounds": out["service"].num_rounds,
        }
        report(f"frontier_{name}_bytes_per_round", round(per_round, 1),
               f"{rows[name]['compression_ratio']:.1f}x vs raw f32")
        report(f"frontier_{name}_accuracy", rows[name]["accuracy"],
               "device plane, paper bundle")
    assert all(r["rounds"] == rounds for r in rows.values())
    # the frontier must actually be a frontier: monotone bytes ordering
    assert rows["topk:0.05+int8"]["bytes_per_round"] < \
        rows["topk:0.1"]["bytes_per_round"] < \
        rows["int8"]["bytes_per_round"] < rows["none"]["bytes_per_round"]
    return {"flat_update_size": p, "variants": rows}


def run(report):
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    seed = 0
    n_clients = 20 if smoke else 30
    rounds = 3 if smoke else 16

    rows, budget = _study("type2", list(BUNDLES), smoke, seed, report)
    report("budget", budget, f"45% of total pool cost, n={n_clients}")

    # cross-partition frontier (PR 5 follow-up): the same A/B on the
    # paper's other partition kinds, reduced bundle set
    partitions = {}
    for kind in _PARTITION_KINDS:
        p_rows, p_budget = _study(kind, _PARTITION_BUNDLES, smoke, seed,
                                  report, prefix=f"{kind}_")
        partitions[kind] = {"budget": p_budget, "bundles": p_rows}

    record = {"smoke": smoke, "noniid": "type2", "n_clients": n_clients,
              "rounds": rounds, "budget": budget,
              "subset_size": 6, "subset_delta": 3,
              "bundles": rows, "partitions": partitions,
              "compression_frontier": _compression_frontier(smoke, seed,
                                                            report)}
    _merge_json(_JSON_PATH, "policies", record)
    report("json_written", 1, os.path.abspath(_JSON_PATH))

    # sanity assertions the study is meant to demonstrate (skip the
    # accuracy ordering in smoke mode — 3 rounds prove plumbing, not
    # learning)
    if not smoke:
        assert rows["fair_ema"]["jain_fairness"] >= \
            rows["random"]["jain_fairness"] - 0.05, \
            "fairness-EMA scheduling should not be less fair than random"
        for kind, p in partitions.items():
            b = p["bundles"]
            assert b["fair_ema"]["jain_fairness"] >= \
                b["random"]["jain_fairness"] - 0.05, kind


if __name__ == "__main__":
    run(lambda k, v, note="": print(f"{k},{v},{note}"))
