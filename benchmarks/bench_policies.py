"""ISSUE-5 policy-comparison study: the pluggable selection/scheduling
registry A/B'd across non-iid partitions.

Five policy bundles run the *same* federated MNIST-like task (type2
non-iid partition, binding budget ≈ 45% of the pool's total cost) end
to end through the lifecycle, differing only in their
``TaskRequest.selection_policy`` / ``scheduling_policy``:

- ``paper``      — paper_greedy + iid_subsets (the paper's scheme, the
                   registry defaults);
- ``dp``         — exact-knapsack selection + iid_subsets;
- ``score_prop`` — score-proportional sampling + iid_subsets;
- ``random``     — uniform selection + random partition (the paper's
                   baseline pair);
- ``fair_ema``   — paper_greedy + the participation-EMA-penalized
                   scheduler (Shi et al. spirit).

Per bundle we record final test **accuracy**, the **Jain fairness
index** over realized per-client participation counts (all executed
rounds), stage-1 **selection latency** (µs, median), pool size/cost and
executed rounds — written into ``BENCH_selection.json`` under the
``"policies"`` key (merged; the stage-1 scaling study owns the other
keys).

Since ISSUE-8 the study also tracks the accuracy-vs-fairness frontier
across **partition kinds** (the PR 5 follow-up): the paper / random /
fair_ema bundles additionally run on the paper's **type1** (single
dominant class per client) and **type3** (two-class mixtures)
partitions, recorded under ``"policies"."partitions"`` alongside the
type2 ``"bundles"`` rows.

Set ``REPRO_BENCH_SMOKE=1`` for the CI configuration: tiny data/rounds,
but still **all** bundles (every registered policy must at least run).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import FLServiceProvider, TaskRequest, jain_index
from repro.core import policy as P
from repro.fl.simulation import SimConfig, pool_from_partition, \
    run_fl_experiment
from repro.data.synthetic import make_classification_data
from repro.fl.partition import partition_labels

_JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                          "BENCH_selection.json")

BUNDLES = {
    "paper": ("paper_greedy", "iid_subsets"),
    "dp": ("dp", "iid_subsets"),
    "score_prop": ("score_prop", "iid_subsets"),
    "random": ("random", "random_partition"),
    "fair_ema": ("paper_greedy", "fair_ema"),
}


def _merge_json(path: str, key: str, value) -> None:
    """Update one top-level key of the shared record in place (the
    selection-time study owns the others). A corrupt/truncated file
    (e.g. an interrupted earlier run) is discarded, matching the
    sibling bench's recovery behaviour."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except json.JSONDecodeError:
            data = {}
    data[key] = value
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def _select_latency_us(pool, task, reps=5) -> float:
    policy = P.resolve_selection_policy(task)
    ts = []
    for r in range(reps):
        rng = np.random.default_rng(task.seed)
        t0 = time.perf_counter()
        policy.select(pool, task, rng)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


# the reduced bundle set for the cross-partition frontier rows (the
# interesting corners: the paper's scheme, the random baseline pair,
# and the fairness-first scheduler)
_PARTITION_KINDS = ("type1", "type3")
_PARTITION_BUNDLES = ("paper", "random", "fair_ema")


def _study(noniid, bundle_names, smoke, seed, report, prefix=""):
    """Run one partition kind's bundle A/B; returns (rows, budget)."""
    n_clients = 20 if smoke else 30
    rounds = 3 if smoke else 16
    n_train = 600 if smoke else 2400
    n_test = 200 if smoke else 600
    subset_size, subset_delta = 6, 3
    sim = SimConfig(batch_size=16, local_steps=2, local_lr=0.15,
                    eval_every=rounds, dropout_rate=0.05, seed=seed)

    # the shared pool the bundles compete on (same draws as inside
    # run_fl_experiment: same data/partition seed)
    full = make_classification_data("mnist", n_train + n_test, seed=seed)
    data = full.subset(np.arange(n_train))
    parts = partition_labels(data.labels, n_clients, noniid,
                             data.num_classes, seed=seed)
    pool = pool_from_partition(data.labels, parts, data.num_classes,
                               seed=seed)
    budget = float(np.round(0.45 * pool.costs.sum()))

    rows = {}
    for bundle in bundle_names:
        sel, sch = BUNDLES[bundle]
        out = run_fl_experiment(
            "mnist", noniid, n_clients=n_clients, rounds=rounds,
            n_train=n_train, n_test=n_test, subset_size=subset_size,
            subset_delta=subset_delta, sim=sim, seed=seed, budget=budget,
            n_star=1, selection_policy=sel, scheduling_policy=sch)
        svc = out["service"]
        counts: dict[int, int] = {}
        for r in svc.rounds:
            for c in r.subset:
                counts[c] = counts.get(c, 0) + 1
        jain = jain_index(np.array(sorted(counts.values()), dtype=np.float64))
        task = TaskRequest(budget=budget, n_star=1, seed=seed,
                           selection_policy=sel, scheduling_policy=sch)
        lat_us = _select_latency_us(pool, task)
        rows[bundle] = {
            "selection_policy": sel, "scheduling_policy": sch,
            "accuracy": float(out["final_accuracy"]),
            "jain_fairness": float(jain),
            "selection_latency_us": lat_us,
            "pool_size": len(svc.pool.selected),
            "pool_cost": float(svc.pool.total_cost),
            "rounds": svc.num_rounds,
        }
        report(f"{prefix}{bundle}_accuracy",
               round(rows[bundle]["accuracy"], 4), f"{sel}+{sch}")
        report(f"{prefix}{bundle}_jain", round(jain, 4),
               "participation fairness over executed rounds")
        if not prefix:
            report(f"{bundle}_select_us", round(lat_us, 1),
                   "stage-1 latency")
            report(f"{bundle}_pool", len(svc.pool.selected),
                   f"cost {svc.pool.total_cost:.0f}/{budget:.0f}")

    # every bundle must have actually trained: jain_index returns 1.0
    # on empty counts, so guard on rounds, not Jain
    assert all(r["rounds"] > 0 and r["pool_size"] > 0
               for r in rows.values())
    return rows, budget


def run(report):
    smoke = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))
    seed = 0
    n_clients = 20 if smoke else 30
    rounds = 3 if smoke else 16

    rows, budget = _study("type2", list(BUNDLES), smoke, seed, report)
    report("budget", budget, f"45% of total pool cost, n={n_clients}")

    # cross-partition frontier (PR 5 follow-up): the same A/B on the
    # paper's other partition kinds, reduced bundle set
    partitions = {}
    for kind in _PARTITION_KINDS:
        p_rows, p_budget = _study(kind, _PARTITION_BUNDLES, smoke, seed,
                                  report, prefix=f"{kind}_")
        partitions[kind] = {"budget": p_budget, "bundles": p_rows}

    record = {"smoke": smoke, "noniid": "type2", "n_clients": n_clients,
              "rounds": rounds, "budget": budget,
              "subset_size": 6, "subset_delta": 3,
              "bundles": rows, "partitions": partitions}
    _merge_json(_JSON_PATH, "policies", record)
    report("json_written", 1, os.path.abspath(_JSON_PATH))

    # sanity assertions the study is meant to demonstrate (skip the
    # accuracy ordering in smoke mode — 3 rounds prove plumbing, not
    # learning)
    if not smoke:
        assert rows["fair_ema"]["jain_fairness"] >= \
            rows["random"]["jain_fairness"] - 0.05, \
            "fairness-EMA scheduling should not be less fair than random"
        for kind, p in partitions.items():
            b = p["bundles"]
            assert b["fair_ema"]["jain_fairness"] >= \
                b["random"]["jain_fairness"] - 0.05, kind


if __name__ == "__main__":
    run(lambda k, v, note="": print(f"{k},{v},{note}"))
